"""Beyond-paper benchmarks: DyDD applied to the LM framework layers
(DESIGN.md §4) — expert balancing and data-parallel token balancing."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dydd
from repro.data import pipeline


def moe_expert_balance():
    """DyDD expert balancing vs plain capacity clamping on a skewed router
    (tokens dropped per layer, balance ratio)."""
    from repro import configs
    from repro.models import moe, nn
    import dataclasses

    cfg = configs.get_smoke_config("olmoe_1b_7b").scaled(
        d_model=128, num_experts=16, experts_per_token=4,
        capacity_factor=1.0)
    b = nn.Builder("init", key=jax.random.PRNGKey(0), dtype=jnp.float32)
    p = moe.make_moe_params(b, cfg)
    router = np.array(p["router"], copy=True)
    rng = np.random.default_rng(0)
    router += rng.normal(size=router.shape) * 0.5   # skew
    p = dict(p, router=jnp.asarray(router))
    x = 0.2 * jax.random.normal(jax.random.PRNGKey(1), (4, 256, 128))

    rows = []
    for bal in (False, True):
        cfg2 = dataclasses.replace(cfg, moe_dydd_balance=bal)
        counts, target = moe.load_balance_stats(cfg2, p, x)
        counts = np.asarray(counts, dtype=np.float64)
        E_router = dydd.balance_ratio(counts)
        E_target = dydd.balance_ratio(np.asarray(target))
        y = moe.apply_moe(cfg2, p, x)
        mass = float(jnp.sum(jnp.abs(y)))
        rows.append((bal, E_router, E_target, mass))
        print(f"  dydd_balance={bal}: router E={E_router:.3f} "
              f"post-schedule E={E_target:.3f} output mass={mass:.1f}")
    return rows


def loader_balance(windows: int = 20):
    """Token-load balance ratio across DP shards with/without DyDD."""
    rows = []
    for bal in (False, True):
        ld = pipeline.BalancedLoader(vocab_size=32000, dp=16,
                                     batch_per_shard=2, seq=1024, seed=0,
                                     balance=bal)
        es, moved = [], 0
        t0 = time.perf_counter()
        for _ in range(windows):
            ld.next_batch()
            es.append(ld.last_stats.efficiency_after)
            moved += ld.last_stats.docs_moved
        t = time.perf_counter() - t0
        print(f"  balance={bal}: mean E={np.mean(es):.3f} "
              f"min E={np.min(es):.3f} docs moved={moved} "
              f"({t/windows*1e3:.1f} ms/window)")
        rows.append((bal, float(np.mean(es)), float(np.min(es))))
    return rows


def scheduling_scalability():
    """DyDD scheduling cost vs p on mesh-topology graphs (the '1000+
    nodes' sanity check: the p x p lstsq is microseconds up to p=4096)."""
    rows = []
    for p, edges_fn in [(64, dydd.ring_edges), (256, dydd.ring_edges),
                        (1024, dydd.ring_edges),
                        (256, lambda p: dydd.grid_edges(16, 16)),
                        (1024, lambda p: dydd.grid_edges(32, 32)),
                        (4096, lambda p: dydd.grid_edges(64, 64))]:
        rng = np.random.default_rng(p)
        loads = rng.integers(0, 2000, p)
        edges = edges_fn(p)
        t0 = time.perf_counter()
        final, scheds = dydd.balance(loads, edges, max_rounds=8)
        t = time.perf_counter() - t0
        print(f"  p={p:5d} |E|={len(edges):6d} rounds={len(scheds)} "
              f"E={dydd.balance_ratio(final):.3f} t={t*1e3:.1f} ms")
        rows.append((p, t, dydd.balance_ratio(final)))
    return rows


def dydd_2d_figures():
    """The paper's own 2D setting (Figures 1-4): clustered observations on
    an 8-subdomain 2D tiling, re-balanced to the average load."""
    from repro.core import dydd2d
    import time
    obs = dydd2d.make_observations_2d(2000, kind="clustered", seed=0)
    t0 = time.perf_counter()
    res = dydd2d.dydd_2d(obs, pr=2, pc=4)
    t = time.perf_counter() - t0
    print(f"  2D (2x4): l_in={res.loads_initial.reshape(-1)} ->"
          f" l_fin={res.loads_final.reshape(-1)}"
          f" E={res.efficiency:.3f} ({t*1e3:.1f} ms)")
    return res


if __name__ == "__main__":
    print("[MoE expert balance]")
    moe_expert_balance()
    print("[Loader balance]")
    loader_balance()
    print("[Scheduling scalability]")
    scheduling_scalability()
