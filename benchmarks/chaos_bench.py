"""Fault-tolerance benchmark: snapshot overhead, resume latency, elastic
remesh quality, and chaos-injection accounting.

Four sections, one JSON report (gated by ``benchmarks/regress.py``):

* **snapshot_overhead** — the streaming engine run at several snapshot
  cadences vs uncheckpointed: per-snapshot p50 wall time, its share of
  the mean cycle time (the machine-normalized ratio the gate watches),
  and the end-to-end wall-time overhead;
* **resume** — restore latency from a mid-stream checkpoint and a
  bitwise check that the resumed journal equals the uninterrupted run
  (the determinism contract, measured end-to-end);
* **remesh_quality** — scale-down p=8 -> p=4 for the shelf and k-d tree
  domains on the slowly-drifting ``coastal_band`` network: the first
  resumed cycle's load imbalance under the elastically re-derived tiling
  vs a cold default tiling at the new p (both deterministic given the
  stream seed — the elastic path's whole reason to exist is that ratio
  staying below 1; a fast-moving network like ``rotating_swarm`` would
  make any load history stale by construction);
* **fault_injection** — a chaos run (scheduled transient pack/solve
  faults, retried with backoff) vs a clean run: retry counts and a
  bitwise journal comparison (retries must not perturb numerics).

``--kill-resume`` switches to the CI smoke orchestration: spawn a child
process (``--child-run``) that SIGKILLs itself mid-stream via a chaos
kill point, resume from the surviving checkpoint in the parent, and
exit non-zero unless the concatenated journal is bitwise identical to
an uninterrupted run.

  PYTHONPATH=src python benchmarks/chaos_bench.py --out chaos.json
  PYTHONPATH=src python benchmarks/chaos_bench.py --kill-resume
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.assim import AssimilationEngine, EngineConfig, streams  # noqa: E402
from repro.assim.metrics import imbalance_ratio  # noqa: E402
from repro.checkpoint import manager as ckpt  # noqa: E402
from repro.core import domain as domain_mod  # noqa: E402
from repro.core import kdtree as kdtree_mod  # noqa: E402
from repro.obs import meters as obs_meters  # noqa: E402
from repro.runtime import elastic  # noqa: E402
from repro.runtime.chaos import ChaosConfig, ChaosInjector  # noqa: E402

# The kill-and-resume smoke's shared shape: the child and the parent's
# uninterrupted reference must build the exact same run.
KILL_CFG = dict(n=48, p=3, iters=10)
KILL_STREAM = dict(name="drifting_swarm", m=80, cycles=10, seed=2)
KILL_AT_CYCLE = 5
KILL_SNAPSHOT_EVERY = 2


def _stream(args, seed_off: int = 0):
    return streams.ResumableStream("drifting_swarm", args.m, args.cycles,
                                   seed=args.seed + seed_off)


def _engine(args, **kw):
    return AssimilationEngine(
        EngineConfig(n=args.n, p=args.p, iters=args.iters), **kw)


def _timed_run(args, **run_kw):
    """(journal, wall, meters snapshot) of one engine run on fresh
    meters."""
    prev = obs_meters.set_meters(obs_meters.Meters())
    try:
        eng = _engine(args)
        t0 = time.perf_counter()
        j = eng.run(_stream(args), **run_kw)
        wall = time.perf_counter() - t0
        snap = obs_meters.get_meters().snapshot()
    finally:
        obs_meters.set_meters(prev)
    return j, wall, snap


def bench_snapshot_overhead(args, workdir: str) -> tuple:
    """Per-cadence snapshot cost; returns (rows, the cadence runs'
    checkpoint dirs) so the resume section can reuse a saved state."""
    # Warm compile, then the measured uncheckpointed reference.
    _timed_run(args)
    base_j, base_wall, _ = _timed_run(args)
    rows = {"baseline": {"wall_time": base_wall,
                         "cycle_time_mean": float(np.mean(
                             [r.cycle_time for r in base_j.records]))}}
    dirs = {}
    for cadence in args.cadences:
        ck = os.path.join(workdir, f"cadence_{cadence}")
        j, wall, snap = _timed_run(args, checkpoint_dir=ck,
                                   snapshot_every=cadence)
        times = snap["series"].get("engine.snapshot_time", [])
        cyc_mean = float(np.mean([r.cycle_time for r in j.records]))
        p50 = float(np.percentile(times, 50)) if times else 0.0
        rows[f"cadence_{cadence}"] = {
            "snapshots": len(times),
            "snapshot_p50_ms": p50 * 1e3,
            "snapshot_over_cycle_ratio": (p50 / cyc_mean if cyc_mean
                                          else 0.0),
            "wall_time": wall,
            "wall_overhead_ratio": (wall / base_wall - 1.0 if base_wall
                                    else 0.0),
            "cycle_time_mean": cyc_mean,
        }
        dirs[cadence] = ck
        print(f"cadence={cadence:3d}  {len(times):3d} snapshots  "
              f"p50 {p50*1e3:7.2f} ms  "
              f"({rows[f'cadence_{cadence}']['snapshot_over_cycle_ratio']:.3f} "
              f"of a cycle)  wall overhead "
              f"{rows[f'cadence_{cadence}']['wall_overhead_ratio']:+.1%}")
    return rows, (base_j, dirs)


def bench_resume(args, base_j, dirs) -> dict:
    """Restore latency from a mid-stream checkpoint + bitwise check."""
    cadence = args.cadences[0]
    ck = dirs[cadence]
    # A mid-stream step (not the final one): half the cycles, rounded to
    # the cadence grid.
    mid = max(cadence, (args.cycles // 2) // cadence * cadence)
    path = os.path.join(ck, f"step_{mid:08d}")
    t0 = time.perf_counter()
    eng, stream = elastic.resume_assim_engine(path)
    restore_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    j = eng.run(stream)
    replay_s = time.perf_counter() - t0
    bitwise = j.deterministic_json() == base_j.deterministic_json()
    row = {
        "resumed_from_cycle": mid,
        "restore_latency_s": restore_s,
        "remaining_cycles": args.cycles - mid,
        "resumed_run_s": replay_s,
        "restore_bitwise": float(bitwise),
    }
    print(f"resume from cycle {mid}: restore {restore_s*1e3:.1f} ms, "
          f"{args.cycles - mid} cycles in {replay_s:.2f} s, "
          f"bitwise={bitwise}")
    return row


def bench_remesh_quality(args, workdir: str) -> dict:
    """p=8 -> p=4 scale-down: first-cycle imbalance of the elastically
    re-derived tiling vs a cold default tiling, shelf and kdtree."""
    out = {}
    specs = {
        "shelf": (EngineConfig(n=64, ndim=2, nx=8, ny=8, pr=4, pc=2,
                               iters=args.iters),
                  lambda: domain_mod.ShelfTiling2D(nx=8, ny=8, pr=2,
                                                   pc=2)),
        "kdtree": (EngineConfig(n=64, domain_kind="kdtree", p=8, nx=8,
                                ny=8, iters=args.iters),
                   lambda: kdtree_mod.KDTreeDomain(nx=8, ny=8, p=4)),
    }
    for kind, (cfg, cold_domain) in specs.items():
        ck = os.path.join(workdir, f"remesh_{kind}")
        eng = AssimilationEngine(cfg)
        eng.run(streams.ResumableStream("coastal_band", args.m, 6,
                                        seed=args.seed),
                checkpoint_dir=ck, snapshot_every=3)
        eng2, stream2 = elastic.resume_assim_engine(
            os.path.join(ck, "step_00000003"), p=4)
        # The first resumed cycle's observations, against the elastic vs
        # the cold tiling — before any rebalance can repair either.
        obs = next(iter(streams.ResumableStream.from_cursor(
            stream2.cursor)))
        imb_elastic = imbalance_ratio(eng2.domain.counts(obs))
        imb_cold = imbalance_ratio(cold_domain().counts(obs))
        out[kind] = {
            "p_from": 8, "p_to": 4,
            "first_cycle_imbalance_elastic": float(imb_elastic),
            "first_cycle_imbalance_cold": float(imb_cold),
            "elastic_over_cold": (float(imb_elastic / imb_cold)
                                  if imb_cold else 0.0),
        }
        print(f"remesh {kind:7s} p8->p4: imbalance elastic "
              f"{imb_elastic:.3f} vs cold {imb_cold:.3f} "
              f"(ratio {out[kind]['elastic_over_cold']:.3f})")
    return out


def bench_fault_injection(args) -> dict:
    """Chaos run vs clean run: retries journalled, numerics untouched."""
    clean = _engine(args).run(_stream(args, seed_off=1))
    inj = ChaosInjector(ChaosConfig(
        pack_fault_cycles=(1, 3), solve_fault_cycles=(2,)))
    prev = obs_meters.set_meters(obs_meters.Meters())
    try:
        chaotic = _engine(args, chaos=inj).run(_stream(args, seed_off=1))
        snap = obs_meters.get_meters().snapshot()
    finally:
        obs_meters.set_meters(prev)
    bitwise = chaotic.deterministic_json() == clean.deterministic_json()
    row = {
        "injected_pack": snap["counters"].get("chaos.injected.pack", 0.0),
        "injected_solve": snap["counters"].get("chaos.injected.solve",
                                               0.0),
        "retries": snap["counters"].get("chaos.retries", 0.0),
        "journal_bitwise": float(bitwise),
        "schedule": inj.schedule(),
    }
    print(f"fault injection: {row['injected_pack']:.0f} pack + "
          f"{row['injected_solve']:.0f} solve faults, "
          f"{row['retries']:.0f} retries, bitwise={bitwise}")
    return row


# ---------------------------------------------------------------------------
# Kill-and-resume smoke (CI): child SIGKILLs itself, parent resumes.
# ---------------------------------------------------------------------------

def child_run(checkpoint_dir: str) -> None:
    inj = ChaosInjector(ChaosConfig(kill_cycles=(KILL_AT_CYCLE,)))
    eng = AssimilationEngine(EngineConfig(**KILL_CFG), chaos=inj)
    eng.run(streams.ResumableStream(**KILL_STREAM),
            checkpoint_dir=checkpoint_dir,
            snapshot_every=KILL_SNAPSHOT_EVERY)
    print("UNREACHABLE: kill point did not fire", file=sys.stderr)
    sys.exit(3)


def kill_resume_smoke() -> None:
    with tempfile.TemporaryDirectory() as workdir:
        ck = os.path.join(workdir, "ck")
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--child-run",
             "--checkpoint-dir", ck],
            env=dict(os.environ), capture_output=True, text=True,
            timeout=600)
        if proc.returncode != -signal.SIGKILL:
            print(f"[chaos] child exited {proc.returncode}, expected "
                  f"SIGKILL ({-signal.SIGKILL})\n{proc.stderr[-2000:]}",
                  file=sys.stderr)
            sys.exit(1)
        latest = ckpt.latest_checkpoint(ck)
        if latest is None:
            print("[chaos] no surviving checkpoint after kill",
                  file=sys.stderr)
            sys.exit(1)
        print(f"[chaos] child SIGKILLed after cycle {KILL_AT_CYCLE}; "
              f"resuming from {os.path.basename(latest)}")
        base = AssimilationEngine(EngineConfig(**KILL_CFG)).run(
            streams.ResumableStream(**KILL_STREAM))
        eng, stream = elastic.resume_assim_engine(ck)
        j = eng.run(stream)
        if j.deterministic_json() != base.deterministic_json():
            print("[chaos] resumed journal is NOT bitwise identical to "
                  "the uninterrupted run", file=sys.stderr)
            sys.exit(1)
        print(f"[chaos] kill-and-resume OK: {len(j.records)} cycles, "
              f"journal bitwise identical across the kill")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cycles", type=int, default=12)
    ap.add_argument("--m", type=int, default=120)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cadences", type=int, nargs="+", default=[2, 6],
                    help="snapshot_every values to sweep")
    ap.add_argument("--kill-resume", action="store_true",
                    help="run the kill-and-resume CI smoke instead of "
                         "the benchmark")
    ap.add_argument("--child-run", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--checkpoint-dir", default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.child_run:
        child_run(args.checkpoint_dir)
        return
    if args.kill_resume:
        kill_resume_smoke()
        return

    report = {
        "bench_config": {k: v for k, v in vars(args).items()
                         if k not in ("out", "child_run",
                                      "checkpoint_dir", "kill_resume")},
        "devices": len(jax.devices()),
        "chaos": {},
    }
    with tempfile.TemporaryDirectory() as workdir:
        rows, (base_j, dirs) = bench_snapshot_overhead(args, workdir)
        report["chaos"]["snapshot_overhead"] = rows
        report["chaos"]["resume"] = bench_resume(args, base_j, dirs)
        report["chaos"]["remesh_quality"] = \
            bench_remesh_quality(args, workdir)
    report["chaos"]["fault_injection"] = bench_fault_injection(args)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
