"""Shared benchmark harness for the paper-table reproductions.

Timing note (stated in every table): this container exposes ONE CPU
device, so ``T^p_DD-DA`` cannot be *measured* on p parallel processors.
We therefore report:
  * T1_kf     — measured wall time of the sequential KF-on-CLS solve
                (the paper's T^1 definition),
  * T1        — measured wall time of the SAME DD algorithm at p=1
                (the apples-to-apples parallelization baseline),
  * T_work    — measured wall time of all p subdomain solves executed
                serially (vmapped),
  * Tp_model  — T_work / p + T_comm  (the idealized p-processor time; the
                communication term is measured from the actual per-
                iteration all-reduce payload at ICI bandwidth),
  * S^p, E^p  — derived from Tp_model against T1.
Everything else in each table (l_in, l_r, l_fin, E, error_DD-DA, DyDD
timings) is measured directly and reproduces the paper's quantities.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cls, dd, ddkf, dydd, kalman
from repro.data import observations

jax.config.update("jax_enable_x64", True)

ICI_BW = 50e9   # bytes/s, matches the roofline constant


def timed(fn, *args, repeats: int = 1, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / repeats


@dataclasses.dataclass
class ScenarioResult:
    name: str
    p: int
    m: int
    dydd: "dydd.DyDDResult"
    t_dydd: float
    t_repartition: float
    t1_kf: float
    t1: float
    t_work: float
    tp_model: float
    err: float

    @property
    def overhead(self) -> float:
        return (self.t_repartition / self.t_dydd if self.t_dydd else 0.0)

    @property
    def speedup(self) -> float:
        """Conservative: vs the same DD algorithm at p=1 (direct solve)."""
        return self.t1 / self.tp_model if self.tp_model else 0.0

    @property
    def efficiency(self) -> float:
        return self.speedup / self.p

    @property
    def speedup_kf(self) -> float:
        """The paper's S^p definition: vs the sequential KF solve (their
        T^1), Table 9/12."""
        return self.t1_kf / self.tp_model if self.tp_model else 0.0

    @property
    def efficiency_kf(self) -> float:
        return self.speedup_kf / self.p


def run_scenario(name: str, n: int, m: int, p: int, graph: str = "chain",
                 empty_subdomains=(), seed: int = 0, kf_block: int = 50,
                 dd_iters: int = 80) -> ScenarioResult:
    obs = observations.make_observations(
        m, kind="uniform" if empty_subdomains else "beta", seed=seed,
        empty_subdomains=empty_subdomains, p=p)
    prob = cls.local_problem(jax.random.PRNGKey(seed), n, obs)

    # --- DyDD (timed; the repartition step timed separately) -------------
    t0 = time.perf_counter()
    b1 = dydd.repartition_empty_1d(obs, np.linspace(0, 1, p + 1))
    t_rep = time.perf_counter() - t0 if empty_subdomains else 0.0

    t0 = time.perf_counter()
    res = dydd.dydd_1d(obs, p)
    t_dydd = time.perf_counter() - t0

    # --- sequential reference: KF on CLS (paper's T^1 definition) --------
    mblk = kf_block
    while m % mblk:
        mblk -= 1
    _, t1_kf = timed(lambda: kalman.solve_cls_sequential(prob, block=mblk))
    x_kf = cls.solve(prob)

    # --- the same DD algorithm at p=1: parallelization baseline ----------
    dec1 = dd.decompose_1d(n, np.array([0.0, 1.0]))
    packed1 = ddkf.pack(prob, dec1)
    _, t1 = timed(lambda: ddkf.solve_vmapped(packed1, iters=1))

    # --- DD-KF after DyDD -------------------------------------------------
    dec = dd.decompose_1d(n, res.boundaries)
    packed = ddkf.pack(prob, dec)
    x_dd, t_work = timed(lambda: ddkf.solve_vmapped(packed,
                                                    iters=dd_iters))
    err = float(jnp.linalg.norm(x_dd - x_kf))

    # comm model: per iteration one (m,) psum + one (n,) psum, ring term
    bytes_per_iter = 8 * (packed.b.shape[0] + n) * 2.0
    t_comm = dd_iters * bytes_per_iter / ICI_BW
    tp_model = t_work / p + t_comm

    return ScenarioResult(name=name, p=p, m=m, dydd=res, t_dydd=t_dydd,
                          t_repartition=t_rep, t1_kf=t1_kf, t1=t1,
                          t_work=t_work, tp_model=tp_model, err=err)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
