"""Kernel microbenchmarks: us_per_call of the jnp references (CPU wall
time) + interpret-mode correctness deltas vs the oracles.  On TPU the same
harness times the Pallas kernels natively (mode='kernel')."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, repeats=5):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def bench_all(mode_fast: str = "ref"):
    rows = []
    # flash attention
    for (bh, s, d) in [(8, 512, 64), (4, 1024, 128)]:
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (jax.random.normal(kk, (bh, s, d), jnp.float32)
                   for kk in keys)
        us = _time(lambda: ops.flash_attention(q, k, v, causal=True,
                                               mode=mode_fast))
        out_i = ops.flash_attention(q[:1, :256], k[:1, :256], v[:1, :256],
                                    mode="interpret", block_q=64,
                                    block_k=64)
        out_r = ref.attention_ref(q[:1, :256], k[:1, :256], v[:1, :256])
        err = float(jnp.max(jnp.abs(out_i - out_r)))
        rows.append((f"flash_attention_{bh}x{s}x{d}", us,
                     f"interp_err={err:.1e}"))
    # rglru
    a = jax.random.uniform(jax.random.PRNGKey(1), (8, 1024, 256),
                           jnp.float32, 0.8, 0.999)
    b = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (8, 1024, 256),
                                jnp.float32)
    us = _time(lambda: ops.rglru_scan(a, b, mode=mode_fast))
    out_i = ops.rglru_scan(a[:1, :128, :64], b[:1, :128, :64],
                           mode="interpret", block_s=64, block_w=32)
    err = float(jnp.max(jnp.abs(out_i - ref.rglru_scan_ref(
        a[:1, :128, :64], b[:1, :128, :64]))))
    rows.append((f"rglru_scan_8x1024x256", us, f"interp_err={err:.1e}"))
    # ssd
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    bh, s, p, n = 16, 512, 64, 64
    x = jax.random.normal(keys[0], (bh, s, p), jnp.float32)
    dt = jax.random.uniform(keys[1], (bh, s), jnp.float32, 0.001, 0.1)
    A = -jax.random.uniform(keys[2], (bh,), jnp.float32, 0.5, 2.0)
    B = jax.random.normal(keys[3], (bh, s, n), jnp.float32)
    C = jax.random.normal(keys[4], (bh, s, n), jnp.float32)
    # time the chunked ssd (kernel-shaped math) via the pallas interpret on
    # a small slice + jnp chunked path for wall time
    from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel
    us = _time(lambda: ref.ssd_heads_ref(x[:2], dt[:2], A[:2], B[:2],
                                         C[:2], 128))
    out_i = _ssd_kernel(x[:2, :128], dt[:2, :128], A[:2], B[:2, :128],
                        C[:2, :128], chunk=64, interpret=True)
    err = float(jnp.max(jnp.abs(out_i - ref.ssd_heads_ref(
        x[:2, :128], dt[:2, :128], A[:2], B[:2, :128], C[:2, :128], 64))))
    rows.append((f"ssd_scan_{bh}x{s}x{p}x{n}", us, f"interp_err={err:.1e}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in bench_all():
        print(f"{name},{us:.1f},{derived}")
