"""Kernel microbenchmarks: us_per_call of the jnp references (CPU wall
time) + interpret-mode correctness deltas vs the oracles.  On TPU the same
harness times the Pallas kernels natively (mode='kernel')."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, repeats=5):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / repeats * 1e6  # us


def bench_all(mode_fast: str = "ref"):
    rows = []
    # flash attention
    for (bh, s, d) in [(8, 512, 64), (4, 1024, 128)]:
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (jax.random.normal(kk, (bh, s, d), jnp.float32)
                   for kk in keys)
        us = _time(lambda: ops.flash_attention(q, k, v, causal=True,
                                               mode=mode_fast))
        out_i = ops.flash_attention(q[:1, :256], k[:1, :256], v[:1, :256],
                                    mode="interpret", block_q=64,
                                    block_k=64)
        out_r = ref.attention_ref(q[:1, :256], k[:1, :256], v[:1, :256])
        err = float(jnp.max(jnp.abs(out_i - out_r)))
        rows.append((f"flash_attention_{bh}x{s}x{d}", us,
                     f"interp_err={err:.1e}"))
    # rglru
    a = jax.random.uniform(jax.random.PRNGKey(1), (8, 1024, 256),
                           jnp.float32, 0.8, 0.999)
    b = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (8, 1024, 256),
                                jnp.float32)
    us = _time(lambda: ops.rglru_scan(a, b, mode=mode_fast))
    out_i = ops.rglru_scan(a[:1, :128, :64], b[:1, :128, :64],
                           mode="interpret", block_s=64, block_w=32)
    err = float(jnp.max(jnp.abs(out_i - ref.rglru_scan_ref(
        a[:1, :128, :64], b[:1, :128, :64]))))
    rows.append((f"rglru_scan_8x1024x256", us, f"interp_err={err:.1e}"))
    # ssd
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    bh, s, p, n = 16, 512, 64, 64
    x = jax.random.normal(keys[0], (bh, s, p), jnp.float32)
    dt = jax.random.uniform(keys[1], (bh, s), jnp.float32, 0.001, 0.1)
    A = -jax.random.uniform(keys[2], (bh,), jnp.float32, 0.5, 2.0)
    B = jax.random.normal(keys[3], (bh, s, n), jnp.float32)
    C = jax.random.normal(keys[4], (bh, s, n), jnp.float32)
    # time the chunked ssd (kernel-shaped math) via the pallas interpret on
    # a small slice + jnp chunked path for wall time
    from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel
    us = _time(lambda: ref.ssd_heads_ref(x[:2], dt[:2], A[:2], B[:2],
                                         C[:2], 128))
    out_i = _ssd_kernel(x[:2, :128], dt[:2, :128], A[:2], B[:2, :128],
                        C[:2, :128], chunk=64, interpret=True)
    err = float(jnp.max(jnp.abs(out_i - ref.ssd_heads_ref(
        x[:2, :128], dt[:2, :128], A[:2], B[:2, :128], C[:2, :128], 64))))
    rows.append((f"ssd_scan_{bh}x{s}x{p}x{n}", us, f"interp_err={err:.1e}"))
    # gram — the batched pack-phase reduction N_i = A_i^T diag(r) A_i
    # over all p subdomain blocks at once (the DD-KF pack's device side)
    keys = jax.random.split(jax.random.PRNGKey(4), 2)
    pg, mg, wg = 8, 768, 96
    Ag = jax.random.normal(keys[0], (pg, mg, wg), jnp.float32)
    rg = jax.random.uniform(keys[1], (pg, mg), jnp.float32, 0.5, 2.0)
    us = _time(lambda: ops.gram(Ag, rg, mode=mode_fast))
    out_i = ops.gram(Ag[:2, :256], rg[:2, :256], mode="interpret",
                     block_m=128)
    err = float(jnp.max(jnp.abs(out_i - ref.gram_ref(Ag[:2, :256],
                                                     rg[:2, :256]))))
    rows.append((f"gram_pack_{pg}x{mg}x{wg}", us, f"interp_err={err:.1e}"))
    # fused Schwarz step — fwd (stacked y/u matmat) and bwd (residual
    # formed in VMEM + transpose product), the solve phase's inner loop
    keys = jax.random.split(jax.random.PRNGKey(5), 6)
    xg = jax.random.normal(keys[0], (pg, wg), jnp.float32)
    wdiv = jax.random.uniform(keys[1], (pg, wg), jnp.float32, 0.5, 1.0)
    rv = jax.random.uniform(keys[2], (mg,), jnp.float32, 0.5, 2.0)
    bv = jax.random.normal(keys[3], (mg,), jnp.float32)
    muov = jax.random.uniform(keys[4], (pg, wg), jnp.float32, 0.0, 1.0)
    mask = jnp.ones((pg, wg), jnp.float32)
    us = _time(lambda: ops.schwarz_fwd(Ag, xg, wdiv, mode=mode_fast))
    yi, ui = ops.schwarz_fwd(Ag[:2, :256], xg[:2], wdiv[:2],
                             mode="interpret", block_m=128)
    yr, ur = ref.schwarz_fwd_ref(Ag[:2, :256], xg[:2], wdiv[:2])
    err = float(max(jnp.max(jnp.abs(yi - yr)), jnp.max(jnp.abs(ui - ur))))
    rows.append((f"schwarz_fwd_{pg}x{mg}x{wg}", us, f"interp_err={err:.1e}"))
    y, u = ref.schwarz_fwd_ref(Ag, xg, wdiv)
    Ax = jnp.sum(y, axis=0)
    us = _time(lambda: ops.schwarz_bwd(Ag, rv, bv, Ax, u, xg, muov, mask,
                                       mode=mode_fast))
    out_i = ops.schwarz_bwd(Ag[:2, :256], rv[:256], bv[:256], Ax[:256],
                            u[:2, :256], xg[:2], muov[:2], mask[:2],
                            mode="interpret", block_m=128)
    out_r = ref.schwarz_bwd_ref(Ag[:2, :256], rv[:256], bv[:256],
                                Ax[:256], u[:2, :256], xg[:2], muov[:2],
                                mask[:2])
    err = float(jnp.max(jnp.abs(out_i - out_r)))
    rows.append((f"schwarz_bwd_{pg}x{mg}x{wg}", us, f"interp_err={err:.1e}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in bench_all():
        print(f"{name},{us:.1f},{derived}")
