"""Paper-table reproductions (one function per table/figure).

Example 1 (Tables 1-3):  p=2,  m=1500, balanced/empty cases.
Example 2 (Tables 4-8):  p=4,  m=1500, 0..3 empty subdomains.
Example 3 (Table 10):    star graph, m=1032, p=2..32.
Example 4 (Table 12):    chain graph, m=2000, p=2..32 + speedup/efficiency.
Table 11 / Figure 5:     error_DD-DA vs p.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import dydd
from repro.data import observations


N_MESH = 2048   # paper's mesh size


def example1(n=N_MESH, quick=False):
    """Tables 1-3: two subdomains; Case 1 unbalanced, Case 2 one empty."""
    rows = []
    for case, empty in ((1, ()), (2, (1,))):
        r = common.run_scenario(f"ex1_case{case}", n, 1500, 2,
                                empty_subdomains=empty, seed=case)
        rows.append(r)
        d = r.dydd
        print(f"[Table {case}] ex1 case{case}: l_in={d.loads_initial} "
              f"l_r={d.loads_repartitioned} l_fin={d.loads_final} "
              f"E={d.efficiency:.3f}")
    print("[Table 3] timings:")
    for r in rows:
        print(f"  {r.name}: T_DyDD={r.t_dydd:.4f}s T_r={r.t_repartition:.6f}s"
              f" Oh={r.overhead:.2e} E={r.dydd.efficiency:.3f}")
    return rows


def example2(n=N_MESH, quick=False):
    """Tables 4-8: four subdomains; 0..3 empty."""
    rows = []
    for case in range(1, 5):
        empty = tuple(range(case - 1))
        r = common.run_scenario(f"ex2_case{case}", n, 1500, 4,
                                empty_subdomains=empty, seed=10 + case)
        rows.append(r)
        d = r.dydd
        print(f"[Table {3+case}] ex2 case{case}: l_in={d.loads_initial} "
              f"l_r={d.loads_repartitioned} l_fin={d.loads_final} "
              f"E={d.efficiency:.3f}")
    print("[Table 8] timings:")
    for r in rows:
        print(f"  {r.name}: T_DyDD={r.t_dydd:.4f}s T_r={r.t_repartition:.6f}s"
              f" Oh={r.overhead:.2e} E={r.dydd.efficiency:.3f}")
    print("[Table 9] DD-KF performance (derived Tp — see common.py note):")
    for r in rows[:1]:
        print(f"  p=4 n_loc={n//4} T1_kf={r.t1_kf:.3f}s T1={r.t1:.3f}s "
              f"Tp={r.tp_model:.3f}s S_kf={r.speedup_kf:.2f} "
              f"E_kf={r.efficiency_kf:.3f} (S_dd={r.speedup:.2f})")
    return rows


def example3(n=N_MESH, quick=False):
    """Table 10: star-graph scheduling, m=1032, p=2..32.

    The star topology (deg(0)=p-1) is scheduled directly on the graph —
    the paper's configuration where E degrades as deg grows."""
    m = 1032
    ps = (2, 4, 8) if quick else (2, 4, 8, 16, 32)
    print("[Table 10] star graph:")
    out = []
    for p in ps:
        rng = np.random.default_rng(p)
        loads = rng.multinomial(m, rng.dirichlet(np.ones(p) * 0.5))
        import time
        t0 = time.perf_counter()
        final, scheds = dydd.balance(loads, dydd.star_edges(p))
        t = time.perf_counter() - t0
        E = dydd.balance_ratio(final)
        print(f"  p={p:3d} n_ad={p-1:3d} T_DyDD={t:.4f}s "
              f"l_max={final.max()} l_min={final.min()} E={E:.3f}")
        out.append((p, t, E, final))
    return out


def example4(n=N_MESH, quick=False):
    """Table 12: chain graph, m=2000, p=2..32, DyDD + DD-KF speedup."""
    ps = (2, 4, 8) if quick else (2, 4, 8, 16, 32)
    print("[Table 12] chain graph + DD-KF:")
    rows = []
    for p in ps:
        r = common.run_scenario(f"ex4_p{p}", n, 2000, p, seed=40 + p)
        rows.append(r)
        print(f"  p={p:3d} n_loc={n//p:5d} T_DyDD={r.t_dydd:.4f}s "
              f"T1_kf={r.t1_kf:.3f}s Tp={r.tp_model:.3f}s "
              f"S_kf={r.speedup_kf:.2f} E_kf={r.efficiency_kf:.3f} "
              f"(S_dd={r.speedup:.2f}) balE={r.dydd.efficiency:.3f}")
    return rows


def table11_accuracy(n=N_MESH, quick=False):
    """Table 11 / Figure 5: error_DD-DA vs p (paper: ~1e-11)."""
    ps = (2, 4) if quick else (2, 4, 8, 16, 32)
    print("[Table 11 / Fig 5] error_DD-DA:")
    out = []
    for p in ps:
        r = common.run_scenario(f"err_p{p}", n, 1500, p, seed=90 + p,
                                dd_iters=120)
        print(f"  p={p:3d} error_DD-DA={r.err:.2e}")
        out.append((p, r.err))
    return out
