"""Perf-regression gate: diff a streaming_bench JSON against a baseline.

The baseline is a checked-in JSON of named metrics extracted from a
reference bench run (``benchmarks/baselines/``), each with a tolerance
and a direction:

  * ``"both"`` — |relative change| beyond tolerance fails (deterministic
    quantities: modelled comm bytes, imbalance, migration volume — these
    depend only on the stream/seed/decomposition, not the machine);
  * ``"max"`` — only an *increase* beyond tolerance fails (timing-based
    ratios: more comm or a fatter phase is a regression, faster is not);
  * ``"min"`` — only a *decrease* beyond tolerance fails (quantities
    that must stay high, e.g. the allreduce/neighbour modelled-bytes
    ratio).

Timing metrics are gated as *ratios of the cycle time* (phase p50 over
mean cycle latency), not absolute seconds, so a uniformly faster or
slower runner cancels out; only a shift in where the cycle's time goes
trips the gate.

Usage:

  # gate (exit 1 on any failure):
  PYTHONPATH=src python benchmarks/regress.py \
      --bench streaming-shardmap.json \
      --baseline benchmarks/baselines/streaming_shardmap_8dev.json

  # refresh the baseline after an intentional perf change (run the exact
  # bench command recorded in the baseline's "command" field first):
  PYTHONPATH=src python benchmarks/regress.py \
      --bench streaming-shardmap.json \
      --baseline benchmarks/baselines/streaming_shardmap_8dev.json \
      --write-baseline
"""
from __future__ import annotations

import argparse
import json
import sys

# Default relative tolerance (the ISSUE's ">25% regression fails").
DEFAULT_TOLERANCE = 0.25
# Host-side pack work competes with device work on a CPU runner, so its
# share of the cycle is the noisiest gated ratio — give it headroom.
PACK_RATIO_TOLERANCE = 0.75
# Phases gated as cycle-time ratios; the sub-millisecond host phases
# (count/halo/data) are pure noise at bench scale and are not gated.
GATED_PHASES = ("solve", "pack")
# Fleet-vs-sequential throughput ratio (serving_bench): dominated by
# thread/core scheduling on shared CI runners, so the widest tolerance
# of any gated metric.
SERVING_RATIO_TOLERANCE = 0.5


def get_path(obj, path: str):
    """Fetch a dotted path ("scenarios.x.dydd.summary.y") from nested
    dicts; raises KeyError with the full path on a miss."""
    cur = obj
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(path)
        cur = cur[part]
    return cur


def phase_ratio(arm_summary: dict, phase: str) -> float | None:
    """p50 of one phase over the mean cycle time — the machine-speed-
    normalized share of the cycle that phase takes."""
    phases = arm_summary.get("phases", {})
    cyc = arm_summary.get("cycle_time_mean", 0.0)
    if phase not in phases or cyc <= 0:
        return None
    return float(phases[phase]["p50"]) / float(cyc)


def extract_metrics(bench: dict) -> dict:
    """The gated metric set from a bench report: deterministic comm /
    imbalance / migration figures (strictly tolerated, two-sided) plus
    one-sided phase-time ratios.  This is the single source of truth for
    what the gate covers — --write-baseline records exactly these."""
    metrics: dict = {}

    def add(path: str, value, tolerance=DEFAULT_TOLERANCE,
            direction="both"):
        metrics[path] = {"value": float(value),
                         "tolerance": float(tolerance),
                         "direction": direction}

    for name, sc in bench.get("scenarios", {}).items():
        for arm in ("static", "dydd"):
            if arm not in sc:
                continue
            s = sc[arm]["summary"]
            pre = f"scenarios.{name}.{arm}.summary."
            # Deterministic given (stream, seed, config): more modelled
            # comm or worse balance than baseline is a real regression,
            # machine speed cannot cause it.
            add(pre + "comm_bytes_per_cycle_mean",
                s["comm_bytes_per_cycle_mean"], direction="max")
            add(pre + "imbalance_max", s["imbalance_max"],
                direction="max")
            add(pre + "halo_fraction_mean", s["halo_fraction_mean"],
                direction="max")
            add(pre + "migrated_total", s["migrated_total"])
            # Timing, normalized to the cycle: one-sided.
            for ph in GATED_PHASES:
                r = phase_ratio(s, ph)
                if r is not None:
                    tol = (PACK_RATIO_TOLERANCE if ph == "pack"
                           else DEFAULT_TOLERANCE)
                    metrics[f"phase_ratio.{name}.{arm}.{ph}"] = {
                        "value": float(r), "tolerance": tol,
                        "direction": "max"}
        if "comm_compare" in sc:
            # The neighbour path's whole reason to exist: its modelled
            # bytes must stay well below allreduce's.
            add(f"scenarios.{name}.comm_compare.modelled_bytes_ratio",
                sc["comm_compare"]["modelled_bytes_ratio"],
                direction="min")
        if "kernel_compare" in sc:
            # The fused kernel's whole reason to exist: its solve time
            # must stay at or below the jnp path's (one-sided — a faster
            # fused solve is never a regression).
            add(f"scenarios.{name}.kernel_compare"
                f".fused_over_jnp_solve_ratio",
                sc["kernel_compare"]["fused_over_jnp_solve_ratio"],
                direction="max")
    ch = bench.get("chaos", {})
    for kind, row in ch.get("remesh_quality", {}).items():
        # Deterministic given (stream, seed): the elastically re-derived
        # tiling's first-cycle balance, and its ratio over a cold default
        # tiling (the remesh path's whole reason to exist — one-sided:
        # a better-balanced remesh is never a regression).
        add(f"chaos.remesh_quality.{kind}.first_cycle_imbalance_elastic",
            row["first_cycle_imbalance_elastic"])
        add(f"chaos.remesh_quality.{kind}.elastic_over_cold",
            row["elastic_over_cold"], direction="max")
    if "fault_injection" in ch:
        fi = ch["fault_injection"]
        # Bitwise flags are 1.0-or-broken: zero tolerance, one-sided.
        add("chaos.fault_injection.journal_bitwise",
            fi["journal_bitwise"], tolerance=0.0, direction="min")
        add("chaos.fault_injection.retries", fi["retries"])
    if "resume" in ch:
        add("chaos.resume.restore_bitwise",
            ch["resume"]["restore_bitwise"], tolerance=0.0,
            direction="min")
    for cad, row in ch.get("snapshot_overhead", {}).items():
        if cad == "baseline":
            continue
        # Snapshot cost as a share of the cycle (machine-normalized,
        # like the phase ratios); host filesystem jitter makes this the
        # noisiest chaos metric, hence the widest tolerance.
        add(f"chaos.snapshot_overhead.{cad}.snapshot_over_cycle_ratio",
            row["snapshot_over_cycle_ratio"], tolerance=1.0,
            direction="max")
    pint = bench.get("pint")
    if pint:
        # Parallel-in-time arm (streaming_bench --time-windows): the
        # window engine's whole reason to exist is wall-clock over the
        # sequential cycle loop — gated as a ratio (machine speed
        # cancels) with the serving-grade tolerance, since both arms'
        # thread/device scheduling is runner-noisy.
        add("pint.pint_over_sequential_cycles_per_sec",
            pint["pint_over_sequential_cycles_per_sec"],
            tolerance=SERVING_RATIO_TOLERANCE, direction="min")
        # Deterministic given (stream, seed, config): the Parareal
        # iteration count must not creep up (more fine sweeps = the
        # speedup quietly eroding), and convergence is 1.0-or-broken.
        add("pint.pint_iters", pint["pint_iters"], tolerance=0.5,
            direction="max")
        add("pint.converged", 1.0 if pint["converged"] else 0.0,
            tolerance=0.0, direction="min")
    for count, row in bench.get("fleet_counts", {}).items():
        # serving_bench reports: the fleet's whole reason to exist is
        # throughput over the sequential per-engine loop.  Gated as a
        # ratio so machine speed cancels; one-sided with generous
        # tolerance (thread scheduling is the noisiest thing we gate).
        add(f"fleet_counts.{count}.fleet_over_sequential_throughput",
            row["fleet_over_sequential_throughput"],
            tolerance=SERVING_RATIO_TOLERANCE, direction="min")
    return metrics


def resolve(bench: dict, path: str) -> float:
    """Current value of a gated metric path in a bench report (the
    ``phase_ratio.`` pseudo-paths are computed, the rest looked up)."""
    if path.startswith("phase_ratio."):
        _, name, arm, ph = path.split(".")
        r = phase_ratio(bench["scenarios"][name][arm]["summary"], ph)
        if r is None:
            raise KeyError(path)
        return r
    return float(get_path(bench, path))


def run_gate(bench: dict, baseline: dict) -> list:
    """Returns the list of failure rows; prints a full comparison table."""
    failures = []
    rows = []
    for path, spec in sorted(baseline["metrics"].items()):
        base_v = float(spec["value"])
        tol = float(spec.get("tolerance", DEFAULT_TOLERANCE))
        direction = spec.get("direction", "both")
        try:
            cur = resolve(bench, path)
        except KeyError:
            failures.append((path, base_v, None, "missing"))
            rows.append((path, base_v, None, tol, direction, "MISSING"))
            continue
        # Relative change; absolute when the baseline is zero (a zero
        # baseline with any nonzero current value is an infinite
        # relative change — treat the raw delta against the tolerance).
        rel = ((cur - base_v) / abs(base_v)) if base_v != 0 \
            else (cur - base_v)
        if direction == "max":
            bad = rel > tol
        elif direction == "min":
            bad = -rel > tol
        else:
            bad = abs(rel) > tol
        status = "FAIL" if bad else "ok"
        if bad:
            failures.append((path, base_v, cur, f"{rel:+.1%}"))
        rows.append((path, base_v, cur, tol, direction, status))

    w = max(len(r[0]) for r in rows) if rows else 10
    print(f"{'metric':<{w}}  {'baseline':>12}  {'current':>12}  "
          f"{'tol':>5}  {'dir':>4}  status")
    for path, base_v, cur, tol, direction, status in rows:
        cur_s = f"{cur:12.6g}" if cur is not None else f"{'—':>12}"
        print(f"{path:<{w}}  {base_v:12.6g}  {cur_s}  {tol:5.0%}  "
              f"{direction:>4}  {status}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", required=True,
                    help="bench JSON report to gate (streaming_bench or "
                         "serving_bench)")
    ap.add_argument("--baseline", required=True,
                    help="checked-in baseline JSON")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline's metrics from --bench "
                    "instead of gating (intentional perf changes)")
    ap.add_argument("--command", default=None,
                    help="with --write-baseline: record the bench "
                    "command that produced --bench, for refreshes")
    args = ap.parse_args()

    with open(args.bench) as f:
        bench = json.load(f)

    if args.write_baseline:
        prev = {}
        try:
            with open(args.baseline) as f:
                prev = json.load(f)
        except FileNotFoundError:
            pass
        baseline = {
            "description": prev.get(
                "description",
                "bench perf baseline (see regress.py)"),
            "command": args.command or prev.get("command", ""),
            "bench_config": bench.get("config",
                                      bench.get("bench_config", {})),
            "metrics": extract_metrics(bench),
        }
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"[regress] wrote {args.baseline} "
              f"({len(baseline['metrics'])} metrics)")
        return

    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = run_gate(bench, baseline)
    if failures:
        print(f"\n[regress] {len(failures)} metric(s) regressed beyond "
              f"tolerance:", file=sys.stderr)
        for path, base_v, cur, note in failures:
            print(f"  {path}: baseline {base_v:.6g} -> "
                  f"{cur if cur is not None else 'missing'} ({note})",
                  file=sys.stderr)
        print("[regress] if the change is intentional, refresh with "
              "--write-baseline (see the module docstring)",
              file=sys.stderr)
        raise SystemExit(1)
    print("\n[regress] all metrics within tolerance")


if __name__ == "__main__":
    main()
