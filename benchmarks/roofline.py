"""Roofline table: reads the dry-run artifacts (results/dryrun_*.json) and
prints the per-(arch x shape) three-term analysis — deliverable (g)."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(multi_pod=False):
    name = "dryrun_multipod.json" if multi_pod else "dryrun_singlepod.json"
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def print_table(rows=None, multi_pod=False):
    data = rows or load(multi_pod)
    if not data:
        print("(no dry-run results yet — run repro.launch.dryrun)")
        return []
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_ms':>9s} {'mem_ms':>9s} "
           f"{'coll_ms':>9s} {'bound':>10s} {'useful':>7s} {'roofl':>6s} "
           f"{'peakGiB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    out = []
    for key in sorted(data):
        r = data[key]
        if r.get("status") == "skipped":
            arch, shape = key.split("|")
            print(f"{arch:22s} {shape:12s} {'—':>9s} {'—':>9s} {'—':>9s} "
                  f"{'skipped':>10s}")
            continue
        if r.get("status") != "ok":
            continue
        peak = r.get("memory", {}).get("peak_per_device", 0) / 2**30
        print(f"{r['arch']:22s} {r['shape']:12s} "
              f"{r['compute_s']*1e3:9.2f} {r['memory_s']*1e3:9.2f} "
              f"{r['collective_s']*1e3:9.2f} {r['dominant']:>10s} "
              f"{r['useful_flops_frac']:7.3f} {r['roofline_frac']:6.3f} "
              f"{peak:8.2f}")
        out.append(r)
    return out


LEVERS = {
    # dominant term -> the established lever family (EXPERIMENTS.md §Perf)
    "compute": "already compute-bound: raise MXU utilization via larger "
               "per-device batch or fewer remat recomputes",
    "memory": "attention-score traffic / remat reads: Pallas flash kernel "
              "on TPU, larger fusion scope, bf16 intermediates",
    "collective": "sharding-level: EP for MoE grads (PERF-A2/C1), dp "
                  "profile for small-d archs (PERF-B0), replicated embed "
                  "(PERF-B3), microbatching",
}


def what_would_move(row) -> str:
    d = row["dominant"]
    base = LEVERS.get(d, "")
    if row["shape"].startswith(("decode", "long")):
        return ("serving regime: batch more requests per step; " + base)
    return base


def summarize():
    data = load()
    if not data:
        return
    ok = [r for r in data.values() if r.get("status") == "ok"]
    from collections import Counter
    doms = Counter(r["dominant"] for r in ok)
    worst = sorted(ok, key=lambda r: r["roofline_frac"])[:3]
    coll = sorted(ok, key=lambda r: -r["collective_s"])[:3]
    print(f"\n{len(ok)} cells analyzed; bottleneck mix: {dict(doms)}")
    print("worst roofline fraction:",
          [(r["arch"], r["shape"], round(r["roofline_frac"], 3))
           for r in worst])
    print("most collective-bound:",
          [(r["arch"], r["shape"], round(r["collective_s"] * 1e3, 1))
           for r in coll])
    print("\nlever per dominant term (details: EXPERIMENTS.md §Perf):")
    for d in doms:
        print(f"  {d}: {LEVERS[d]}")


if __name__ == "__main__":
    print_table()
    summarize()
