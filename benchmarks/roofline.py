"""Roofline table: reads the dry-run artifacts (results/dryrun_*.json) and
prints the per-(arch x shape) three-term analysis — deliverable (g).

``--solve BENCH.json`` switches to the DD-KF solve roofline: from a
streaming_bench report it rebuilds each arm's decomposition shapes,
prices one Schwarz iteration per device as three terms — compute
(~6mw + 2w^2 flops), memory (two HBM passes over the (m, w) operator
block on the fused kernel, three on the jnp path) and collective (the
m-vector all-reduce bytes from ``ddkf.comm_model`` under torus-aware
mesh pricing) — and prints the modelled bound next to the measured
solve-phase p50 from the report's journalled phase spans.

  PYTHONPATH=src python benchmarks/roofline.py            # dry-run table
  PYTHONPATH=src python benchmarks/roofline.py \
      --solve streaming-shardmap.json                     # solve roofline
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(multi_pod=False):
    name = "dryrun_multipod.json" if multi_pod else "dryrun_singlepod.json"
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def print_table(rows=None, multi_pod=False):
    data = rows or load(multi_pod)
    if not data:
        print("(no dry-run results yet — run repro.launch.dryrun)")
        return []
    hdr = (f"{'arch':22s} {'shape':12s} {'comp_ms':>9s} {'mem_ms':>9s} "
           f"{'coll_ms':>9s} {'bound':>10s} {'useful':>7s} {'roofl':>6s} "
           f"{'peakGiB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    out = []
    for key in sorted(data):
        r = data[key]
        if r.get("status") == "skipped":
            arch, shape = key.split("|")
            print(f"{arch:22s} {shape:12s} {'—':>9s} {'—':>9s} {'—':>9s} "
                  f"{'skipped':>10s}")
            continue
        if r.get("status") != "ok":
            continue
        peak = r.get("memory", {}).get("peak_per_device", 0) / 2**30
        print(f"{r['arch']:22s} {r['shape']:12s} "
              f"{r['compute_s']*1e3:9.2f} {r['memory_s']*1e3:9.2f} "
              f"{r['collective_s']*1e3:9.2f} {r['dominant']:>10s} "
              f"{r['useful_flops_frac']:7.3f} {r['roofline_frac']:6.3f} "
              f"{peak:8.2f}")
        out.append(r)
    return out


LEVERS = {
    # dominant term -> the established lever family (EXPERIMENTS.md §Perf)
    "compute": "already compute-bound: raise MXU utilization via larger "
               "per-device batch or fewer remat recomputes",
    "memory": "attention-score traffic / remat reads: Pallas flash kernel "
              "on TPU, larger fusion scope, bf16 intermediates",
    "collective": "sharding-level: EP for MoE grads (PERF-A2/C1), dp "
                  "profile for small-d archs (PERF-B0), replicated embed "
                  "(PERF-B3), microbatching",
}


def what_would_move(row) -> str:
    d = row["dominant"]
    base = LEVERS.get(d, "")
    if row["shape"].startswith(("decode", "long")):
        return ("serving regime: batch more requests per step; " + base)
    return base


def summarize():
    data = load()
    if not data:
        return
    ok = [r for r in data.values() if r.get("status") == "ok"]
    from collections import Counter
    doms = Counter(r["dominant"] for r in ok)
    worst = sorted(ok, key=lambda r: r["roofline_frac"])[:3]
    coll = sorted(ok, key=lambda r: -r["collective_s"])[:3]
    print(f"\n{len(ok)} cells analyzed; bottleneck mix: {dict(doms)}")
    print("worst roofline fraction:",
          [(r["arch"], r["shape"], round(r["roofline_frac"], 3))
           for r in worst])
    print("most collective-bound:",
          [(r["arch"], r["shape"], round(r["collective_s"] * 1e3, 1))
           for r in coll])
    print("\nlever per dominant term (details: EXPERIMENTS.md §Perf):")
    for d in doms:
        print(f"  {d}: {LEVERS[d]}")


# -- DD-KF solve roofline (--solve) ---------------------------------------

# Conservative single-device peaks; override per machine.  The defaults
# describe a TPU-v4-class chip (f32 MXU, HBM2e, one ICI link) — on a CPU
# runner the measured column will sit far above the bound, which is the
# point: the table shows how far the *observed* solve phase is from the
# shapes' hardware-limit story, whichever term dominates.
PEAK_FLOPS = 9.2e13       # flop/s
PEAK_MEMBW = 1.2e12       # HBM bytes/s
PEAK_COLLBW = 9.0e10      # collective bytes/s per device


def _rebuild_domain(meta: dict):
    """Domain object back from a journal's ``Domain.describe()`` dict."""
    from repro.core import domain as domain_mod
    from repro.core import kdtree as kdtree_mod
    kind = meta.get("kind", "interval1d")
    if kind == "interval1d":
        return domain_mod.Interval1D(n=meta["n"], p=meta["p"])
    if kind == "shelf2d":
        return domain_mod.ShelfTiling2D(nx=meta["nx"], ny=meta["ny"],
                                        pr=meta["pr"], pc=meta["pc"])
    if kind == "kdtree":
        return kdtree_mod.KDTreeDomain(nx=meta["nx"], ny=meta["ny"],
                                       p=meta["p"])
    raise ValueError(f"unknown domain kind {kind!r}")


def solve_bound(meta: dict, config: dict, kernel: str,
                peak_flops=PEAK_FLOPS, peak_membw=PEAK_MEMBW,
                peak_collbw=PEAK_COLLBW) -> dict:
    """Three-term per-solve bound (seconds) for one arm's shapes.

    Rebuilds the arm's *initial* decomposition (DyDD may move boundaries
    later; w only shrinks under balancing, so this is the conservative
    shape).  Per device and iteration: ~6mw + 2w^2 flops (two stacked
    matmats + the transpose product + the triangular solves), operator
    bytes = passes * m * w * itemsize with passes = 2 fused / 3 jnp, and
    the collective term is comm_model's per-device pricing of the
    configured exchange under the domain's torus mesh shape.
    """
    from repro.core import ddkf
    dom = _rebuild_domain(meta)
    overlap = int(config.get("overlap", 0))
    iters = int(config.get("iters", 100))
    m_obs = int(config.get("m", 0))
    itemsize = 8  # streaming_bench runs under jax_enable_x64
    dec = dom.decomposition(overlap=overlap)
    w = dec.pad_width
    m = dom.n + m_obs          # stacked rows: state block + observations
    comm = config.get("comm", "allreduce")
    halo = dec.halo_exchange if comm == "neighbour" else None
    stats = ddkf.comm_model(dom.n, m, dom.p, itemsize, halo=halo,
                            comm=comm, mesh_shape=dom.mesh_axes()[1])
    passes = 2 if kernel.startswith("fused") else 3
    flops = 6.0 * m * w + 2.0 * w * w
    mem_bytes = passes * m * w * itemsize
    coll_bytes = stats["bytes_per_iter_total"] / dom.p \
        + stats["mvec_bytes_per_device"]
    terms = {
        "compute_s": iters * flops / peak_flops,
        "memory_s": iters * mem_bytes / peak_membw,
        "collective_s": iters * coll_bytes / peak_collbw,
    }
    dominant = max(terms, key=terms.get)
    return {
        "p": dom.p, "m": m, "w": w, "iters": iters, "kernel": kernel,
        **terms,
        "bound_s": terms[dominant],
        "dominant": dominant.removesuffix("_s"),
    }


def print_solve_table(report: dict, peak_flops=PEAK_FLOPS,
                      peak_membw=PEAK_MEMBW, peak_collbw=PEAK_COLLBW):
    config = report.get("config", {})
    hdr = (f"{'scenario/arm':32s} {'p':>3s} {'m':>6s} {'w':>5s} "
           f"{'kern':>6s} {'comp_ms':>8s} {'mem_ms':>8s} {'coll_ms':>8s} "
           f"{'bound_ms':>9s} {'meas_ms':>9s} {'x_bound':>8s} "
           f"{'dominant':>10s}")
    print(hdr)
    print("-" * len(hdr))
    rows = []
    for name, sc in sorted(report.get("scenarios", {}).items()):
        for arm in ("static", "dydd"):
            if arm not in sc:
                continue
            rec = sc[arm]
            kernel = rec.get("solver_kernel",
                             config.get("solver_kernel", "jnp"))
            b = solve_bound(rec.get("domain", {}), config, kernel,
                            peak_flops, peak_membw, peak_collbw)
            # Measured solve phase p50 from the journalled phase spans.
            meas = rec.get("summary", {}).get("phases", {}) \
                      .get("solve", {}).get("p50")
            ratio = (meas / b["bound_s"]) if meas and b["bound_s"] > 0 \
                else None
            print(f"{name + '/' + arm:32s} {b['p']:3d} {b['m']:6d} "
                  f"{b['w']:5d} {kernel[:6]:>6s} "
                  f"{b['compute_s']*1e3:8.3f} {b['memory_s']*1e3:8.3f} "
                  f"{b['collective_s']*1e3:8.3f} {b['bound_s']*1e3:9.3f} "
                  f"{(meas or 0)*1e3:9.2f} "
                  f"{ratio if ratio is not None else float('nan'):8.1f} "
                  f"{b['dominant']:>10s}")
            rows.append({"scenario": name, "arm": arm, "measured_s": meas,
                         **b})
    fused = [r for r in rows if r["kernel"].startswith("fused")]
    if fused:
        print(f"\nfused kernel: modelled operator traffic 2/3 of the jnp "
              f"path's (two HBM passes over A per iteration, not three)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--solve", default=None, metavar="BENCH.json",
                    help="streaming_bench report: print the DD-KF solve "
                    "roofline instead of the dry-run table")
    ap.add_argument("--peak-flops", type=float, default=PEAK_FLOPS)
    ap.add_argument("--peak-membw", type=float, default=PEAK_MEMBW)
    ap.add_argument("--peak-collbw", type=float, default=PEAK_COLLBW)
    ap.add_argument("--multi-pod", action="store_true",
                    help="dry-run table: read dryrun_multipod.json")
    cli = ap.parse_args()
    if cli.solve:
        with open(cli.solve) as f:
            print_solve_table(json.load(f), cli.peak_flops,
                              cli.peak_membw, cli.peak_collbw)
    else:
        print_table(multi_pod=cli.multi_pod)
        summarize()
