"""Benchmark entrypoint: one function per paper table + beyond-paper
benches + the roofline table.  Prints ``name,us_per_call,derived`` CSV at
the end.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller p-sweeps (CI mode)")
    ap.add_argument("--n", type=int, default=2048, help="mesh size")
    args, _ = ap.parse_known_args()

    from benchmarks import beyond_paper, kernels_bench, paper_tables, \
        roofline
    from benchmarks.common import csv_row

    csv = []
    t_all = time.time()

    print("=" * 72)
    print("Example 1 (paper Tables 1-3)")
    rows = paper_tables.example1(n=args.n, quick=args.quick)
    for r in rows:
        csv.append(csv_row(f"dydd_{r.name}", r.t_dydd * 1e6,
                           f"E={r.dydd.efficiency:.3f};err={r.err:.1e}"))

    print("=" * 72)
    print("Example 2 (paper Tables 4-8, Table 9)")
    rows = paper_tables.example2(n=args.n, quick=args.quick)
    for r in rows:
        csv.append(csv_row(f"dydd_{r.name}", r.t_dydd * 1e6,
                           f"E={r.dydd.efficiency:.3f};err={r.err:.1e}"))

    print("=" * 72)
    print("Example 3 (paper Table 10)")
    for p, t, E, _ in paper_tables.example3(n=args.n, quick=args.quick):
        csv.append(csv_row(f"dydd_star_p{p}", t * 1e6, f"E={E:.3f}"))

    print("=" * 72)
    print("Example 4 (paper Table 12)")
    rows = paper_tables.example4(n=args.n, quick=args.quick)
    for r in rows:
        csv.append(csv_row(
            f"ddkf_chain_p{r.p}", r.tp_model * 1e6,
            f"S_kf={r.speedup_kf:.2f};E_kf={r.efficiency_kf:.3f};"
            f"S_dd={r.speedup:.2f}"))

    print("=" * 72)
    print("Table 11 / Figure 5 (error_DD-DA)")
    for p, err in paper_tables.table11_accuracy(n=args.n,
                                                quick=args.quick):
        csv.append(csv_row(f"err_dd_da_p{p}", 0.0, f"err={err:.2e}"))

    print("=" * 72)
    print("Beyond paper: DyDD in the LM framework")
    print("[MoE expert balance]")
    for bal, er, et, mass in beyond_paper.moe_expert_balance():
        csv.append(csv_row(f"moe_balance_{bal}", 0.0,
                           f"E_router={er:.3f};E_sched={et:.3f}"))
    print("[DP loader balance]")
    for bal, emean, emin in beyond_paper.loader_balance(
            windows=5 if args.quick else 20):
        csv.append(csv_row(f"loader_balance_{bal}", 0.0,
                           f"Emean={emean:.3f};Emin={emin:.3f}"))
    print("[Scheduling scalability]")
    for p, t, E in beyond_paper.scheduling_scalability():
        csv.append(csv_row(f"dydd_sched_p{p}", t * 1e6, f"E={E:.3f}"))
    print("[2D DyDD (paper Figures 1-4 setting)]")
    r2 = beyond_paper.dydd_2d_figures()
    csv.append(csv_row("dydd_2d_2x4", 0.0, f"E={r2.efficiency:.3f}"))

    print("=" * 72)
    print("Kernel microbenchmarks")
    for name, us, derived in kernels_bench.bench_all():
        csv.append(csv_row(name, us, derived))

    print("=" * 72)
    print("Roofline (from dry-run artifacts)")
    roofline.print_table()
    roofline.summarize()

    print("=" * 72)
    print(f"total bench time {time.time() - t_all:.0f}s")
    print("\nname,us_per_call,derived")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
