"""Multi-tenant serving benchmark: fleet-batched vs sequential streams.

For each fleet size S (default 16 and 64; the full curve in the paper
runs 16/64/256/1024) build S independent assimilation streams from a
mixed scenario pool (1D interval, 2D shelf, 2D adaptive k-d tree —
different shapes land in different compiled cohorts, exercising the
shape bucketing) and run them twice:

* **sequential** — one ``AssimilationEngine.run`` per stream, back to
  back: the per-engine loop a tenant would run alone;
* **fleet** — all S streams through one :class:`FleetServer`
  (continuous batching on the shared slot scheduler, cohort-stacked
  ``lax.map`` solves, host packing on a thread pool).

Reported per fleet size: sustained cycles/sec, per-cycle latency
p50/p99 (from the journals' measured ``cycle_time``), the
``fleet_over_sequential_throughput`` ratio the CI smoke gate asserts
``> 1``, and a ``bitwise_identical`` flag comparing every stream's
final analysis across the two arms (the determinism contract,
end-to-end).  The fleet arm's telemetry (queue-depth gauge,
admission/retirement events, per-cohort dispatch counters) is snapshot
from :mod:`repro.obs.meters` into the report.

  PYTHONPATH=src python benchmarks/serving_bench.py --out serving.json
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python benchmarks/serving_bench.py --streams 64 --cycles 3 \
      --out serving.json                              # CI smoke shape
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.assim import (  # noqa: E402
    AssimilationEngine, EngineConfig, FleetServer, streams)
from repro.core import _compat  # noqa: E402
from repro.obs import meters as obs_meters  # noqa: E402


def scenario_pool(args):
    """(name, config, scenario) templates cycled over the fleet: three
    domain kinds so a mixed fleet always spans several shape cohorts."""
    return [
        ("drifting_swarm",
         EngineConfig(n=args.n, p=args.p, iters=args.iters)),
        ("bursty_clusters",
         EngineConfig(n=args.n, p=args.p, iters=args.iters)),
        ("rotating_swarm",
         EngineConfig(ndim=2, nx=args.nx, ny=args.ny, pr=args.pr,
                      pc=args.pc, iters=args.iters)),
        ("satellite_track",
         EngineConfig(ndim=2, domain_kind="kdtree", nx=args.nx2,
                      ny=args.ny2, p=args.p, iters=args.iters)),
    ]


def build_specs(count: int, args):
    pool = scenario_pool(args)
    return [(f"s{i}",) + pool[i % len(pool)] + (i,)
            for i in range(count)]


def latency_stats(journals) -> dict:
    lat = np.array([rec.cycle_time for j in journals.values()
                    for rec in j.records])
    return {
        "cycles": int(lat.size),
        "latency_p50": float(np.percentile(lat, 50)) if lat.size else 0.0,
        "latency_p99": float(np.percentile(lat, 99)) if lat.size else 0.0,
    }


def run_sequential(specs, args) -> tuple:
    journals, finals = {}, {}
    t0 = time.perf_counter()
    for sid, name, cfg, seed in specs:
        eng = AssimilationEngine(cfg)
        journals[sid] = eng.run(
            streams.make_stream(name, args.m, args.cycles, seed=seed))
        finals[sid] = np.asarray(eng.analysis)
    wall = time.perf_counter() - t0
    row = {"wall_time": wall, **latency_stats(journals)}
    row["cycles_per_sec"] = row["cycles"] / wall if wall else 0.0
    return row, finals


def run_fleet(specs, args, mesh, solver=None) -> tuple:
    prev = obs_meters.set_meters(obs_meters.Meters())
    try:
        server = FleetServer(mesh=mesh, max_active=args.max_active,
                             pack_workers=args.pack_workers,
                             solver=solver)
        for sid, name, cfg, seed in specs:
            server.add_stream(sid, cfg, streams.make_stream(
                name, args.m, args.cycles, seed=seed))
        journals = server.serve()
        finals = {sid: np.asarray(eng.analysis)
                  for sid, eng in server.engines.items()}
        snap = obs_meters.get_meters().snapshot()
    finally:
        obs_meters.set_meters(prev)
    names = [e["name"] for e in snap["events"]]
    row = {"wall_time": server.stats["wall_time"],
           "rounds": server.stats["rounds"],
           **latency_stats(journals)}
    row["cycles_per_sec"] = (row["cycles"] / row["wall_time"]
                             if row["wall_time"] else 0.0)
    row["telemetry"] = {
        "cohort_dispatches": snap["counters"].get(
            "fleet.cohort.dispatches", 0.0),
        "cohort_members": snap["counters"].get("fleet.cohort.members",
                                               0.0),
        "padded_slots": snap["counters"].get("fleet.cohort.padded_slots",
                                             0.0),
        "admit_events": names.count("fleet.admit"),
        "retire_events": names.count("fleet.retire"),
        "dydd_repacks": names.count("fleet.dydd.repack"),
        "queue_depth_final": snap["gauges"].get("fleet.queue_depth"),
    }
    return row, finals


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--streams", type=int, nargs="+", default=[16, 64],
                    help="fleet sizes to sweep (paper curve: 16 64 256 "
                         "1024)")
    ap.add_argument("--cycles", type=int, default=3)
    ap.add_argument("--m", type=int, default=120)
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--nx", type=int, default=12)
    ap.add_argument("--ny", type=int, default=8)
    ap.add_argument("--pr", type=int, default=2)
    ap.add_argument("--pc", type=int, default=2)
    ap.add_argument("--nx2", type=int, default=16,
                    help="kdtree raster width")
    ap.add_argument("--ny2", type=int, default=12,
                    help="kdtree raster height")
    ap.add_argument("--max-active", type=int, default=64,
                    help="fleet slot-table capacity (streams beyond it "
                         "queue FIFO)")
    ap.add_argument("--pack-workers", type=int, default=4)
    ap.add_argument("--no-mesh", action="store_true",
                    help="keep the fleet on one device even when more "
                         "are visible")
    ap.add_argument("--warmup", type=int, default=1,
                    help="unmeasured full passes per arm before the "
                         "measured one, so cycles/sec is *sustained* "
                         "throughput (compiled programs warm; the same "
                         "streams re-run hit the same shape cohorts). "
                         "0 = include compile time")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = None
    if not args.no_mesh and n_dev > 1:
        mesh = _compat.make_device_mesh((n_dev,), ("fleet",))

    report = {
        "bench_config": {k: v for k, v in vars(args).items()
                         if k != "out"},
        "devices": n_dev,
        "fleet_mesh": None if mesh is None else n_dev,
        "fleet_counts": {},
    }
    for count in args.streams:
        specs = build_specs(count, args)
        # One CohortSolver across warmup + measured passes: its pinned
        # cohort capacities (and the jitted programs keyed off them)
        # are what the warmup exists to stabilize.
        from repro.assim import fleet as fleet_lib
        solver = fleet_lib.CohortSolver(mesh=mesh)
        for _ in range(args.warmup):
            run_sequential(specs, args)
            run_fleet(specs, args, mesh, solver=solver)
        seq_row, seq_finals = run_sequential(specs, args)
        fleet_row, fleet_finals = run_fleet(specs, args, mesh,
                                            solver=solver)
        bitwise = all(np.array_equal(seq_finals[sid], fleet_finals[sid])
                      for sid, *_ in specs)
        ratio = (fleet_row["cycles_per_sec"] / seq_row["cycles_per_sec"]
                 if seq_row["cycles_per_sec"] else 0.0)
        report["fleet_counts"][str(count)] = {
            "sequential": seq_row,
            "fleet": fleet_row,
            "fleet_over_sequential_throughput": ratio,
            "bitwise_identical": bool(bitwise),
        }
        print(f"S={count:5d}  seq {seq_row['cycles_per_sec']:8.2f} cyc/s "
              f"(p50 {seq_row['latency_p50']*1e3:7.1f} ms, "
              f"p99 {seq_row['latency_p99']*1e3:7.1f} ms)  "
              f"fleet {fleet_row['cycles_per_sec']:8.2f} cyc/s "
              f"(p50 {fleet_row['latency_p50']*1e3:7.1f} ms, "
              f"p99 {fleet_row['latency_p99']*1e3:7.1f} ms)  "
              f"ratio {ratio:5.2f}x  bitwise={bitwise}")
        sys.stdout.flush()

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
