"""Streaming assimilation benchmark: static DD vs online DyDD.

For every registered observation-stream scenario, run the multi-cycle
engine twice — ``rebalance=False`` (the paper's static decomposition,
left to degrade as the network moves) and ``rebalance=True`` (online
DyDD with the default threshold/hysteresis policy) — and emit a JSON
comparison of per-cycle latency and the imbalance trajectory.

  PYTHONPATH=src python benchmarks/streaming_bench.py --out streaming.json
  PYTHONPATH=src python benchmarks/streaming_bench.py \
      --n 96 --m 200 --cycles 4 --scenarios drifting_swarm    # smoke
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.assim import AssimilationEngine, EngineConfig, streams  # noqa: E402


def run_arm(name: str, rebalance: bool, args) -> dict:
    cfg = EngineConfig(n=args.n, p=args.p, iters=args.iters,
                       rebalance=rebalance,
                       imbalance_threshold=args.threshold,
                       track_reference=args.track_reference)
    eng = AssimilationEngine(cfg)
    journal = eng.run_scenario(name, m=args.m, cycles=args.cycles,
                               seed=args.seed)
    cycle_times = journal.cycle_times
    return {
        "rebalance": rebalance,
        "imbalance_trajectory": journal.imbalance_trajectory,
        "efficiency_trajectory": [r.efficiency for r in journal.records],
        "cycle_latency_s": cycle_times,
        "cycle_latency_mean_s": float(np.mean(cycle_times)),
        # Steady-state latency: drop the first cycles, which pay the jit
        # specialization for each new padded block width.
        "cycle_latency_steady_s": float(np.mean(
            cycle_times[len(cycle_times) // 2:])),
        "solve_time_mean_s": float(np.mean(
            [r.solve_time for r in journal.records])),
        "pack_time_mean_s": float(np.mean(
            [r.pack_time for r in journal.records])),
        "repartitions": journal.repartition_count,
        "migrated_total": journal.migrated_total,
        "summary": journal.summary(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--m", type=int, default=600)
    ap.add_argument("--p", type=int, default=8)
    ap.add_argument("--cycles", type=int, default=8)
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--threshold", type=float, default=1.5)
    ap.add_argument("--track-reference", action="store_true",
                    help="also journal per-cycle error vs one-shot solve")
    ap.add_argument("--scenarios", nargs="*", default=None,
                    choices=streams.available(),
                    help="subset of the registered scenarios (default: all)")
    ap.add_argument("--out", default=None, help="write JSON here "
                    "(default: stdout)")
    args = ap.parse_args()

    names = args.scenarios or streams.available()
    report = {
        "config": {"n": args.n, "m": args.m, "p": args.p,
                   "cycles": args.cycles, "iters": args.iters,
                   "seed": args.seed, "threshold": args.threshold},
        "scenarios": {},
    }
    for name in names:
        print(f"[streaming_bench] {name} ...", file=sys.stderr)
        static = run_arm(name, rebalance=False, args=args)
        dydd = run_arm(name, rebalance=True, args=args)
        report["scenarios"][name] = {
            "static": static,
            "dydd": dydd,
            "imbalance_reduction": float(
                np.mean(static["imbalance_trajectory"])
                / max(np.mean(dydd["imbalance_trajectory"]), 1e-12)),
        }

    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"[streaming_bench] wrote {args.out}", file=sys.stderr)
    else:
        print(text)


if __name__ == "__main__":
    main()
