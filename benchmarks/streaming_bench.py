"""Streaming assimilation benchmark: static DD vs online DyDD, 1D and 2D.

For every registered observation-stream scenario — 1D interval domains and
2D shelf tilings alike — run the multi-cycle engine twice:
``rebalance=False`` (the paper's static decomposition, left to degrade as
the network moves) and ``rebalance=True`` (online DyDD with the default
threshold/hysteresis policy) — and emit a JSON comparison of per-cycle
latency (split into host+device *pack* vs device *solve*, so the batched
``kernels.ops.gram`` packing win is visible) and the imbalance trajectory.

  PYTHONPATH=src python benchmarks/streaming_bench.py --out streaming.json
  PYTHONPATH=src python benchmarks/streaming_bench.py \
      --n 96 --m 200 --cycles 4 --scenarios drifting_swarm    # smoke
  PYTHONPATH=src python benchmarks/streaming_bench.py \
      --nx 12 --ny 8 --pr 2 --pc 2 --scenarios rotating_swarm # 2D smoke
"""
from __future__ import annotations

import argparse
import json
import sys

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.assim import AssimilationEngine, EngineConfig, streams  # noqa: E402
from repro.kernels import ops  # noqa: E402


def make_config(ndim: int, rebalance: bool, args) -> EngineConfig:
    common = dict(iters=args.iters, rebalance=rebalance,
                  imbalance_threshold=args.threshold,
                  track_reference=args.track_reference,
                  solver=args.solver, overlap=args.overlap)
    if ndim == 1:
        return EngineConfig(n=args.n, p=args.p, **common)
    return EngineConfig(ndim=2, nx=args.nx, ny=args.ny,
                        pr=args.pr, pc=args.pc, damping=args.damping_2d,
                        **common)


def run_arm(name: str, rebalance: bool, args) -> dict:
    ndim = streams.get(name).ndim
    eng = AssimilationEngine(make_config(ndim, rebalance, args))
    journal = eng.run_scenario(name, m=args.m, cycles=args.cycles,
                               seed=args.seed)
    cycle_times = journal.cycle_times
    pack_times = [r.pack_time for r in journal.records]
    solve_times = [r.solve_time for r in journal.records]
    imb = journal.imbalance_trajectory
    return {
        "rebalance": rebalance,
        "solver": args.solver,
        "overlap": args.overlap,
        "domain": journal.meta,
        "imbalance_trajectory": imb,
        "imbalance_final": imb[-1],
        "efficiency_trajectory": [r.efficiency for r in journal.records],
        "cycle_latency_s": cycle_times,
        "cycle_latency_mean_s": float(np.mean(cycle_times)),
        # Steady-state latency: drop the first cycles, which pay the jit
        # specialization for each new padded block width.
        "cycle_latency_steady_s": float(np.mean(
            cycle_times[len(cycle_times) // 2:])),
        # Pack (host slicing + batched device gram/cholesky) vs solve
        # (device DD-KF iteration) — the per-cycle split.
        "pack_time_s": pack_times,
        "solve_time_s": solve_times,
        "pack_time_mean_s": float(np.mean(pack_times)),
        "solve_time_mean_s": float(np.mean(solve_times)),
        "repartitions": journal.repartition_count,
        "migrated_total": journal.migrated_total,
        "summary": journal.summary(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=256, help="1D state dimension")
    ap.add_argument("--p", type=int, default=8, help="1D subdomains")
    ap.add_argument("--nx", type=int, default=24, help="2D mesh width")
    ap.add_argument("--ny", type=int, default=12, help="2D mesh height")
    ap.add_argument("--pr", type=int, default=2, help="2D strip count")
    ap.add_argument("--pc", type=int, default=4, help="2D cells per strip")
    ap.add_argument("--damping-2d", type=float, default=0.7,
                    help="additive-Schwarz damping for the 2D tiling")
    ap.add_argument("--m", type=int, default=600)
    ap.add_argument("--cycles", type=int, default=8)
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--threshold", type=float, default=1.5)
    ap.add_argument("--track-reference", action="store_true",
                    help="also journal per-cycle error vs one-shot solve")
    ap.add_argument("--solver", default="vmapped",
                    choices=("vmapped", "shardmap"),
                    help="shardmap needs one device per subdomain")
    ap.add_argument("--overlap", type=int, default=0,
                    help="Schwarz halo width")
    ap.add_argument("--scenarios", nargs="*", default=None,
                    choices=streams.available(),
                    help="subset of the registered scenarios "
                    "(default: all, 1D and 2D)")
    ap.add_argument("--out", default=None, help="write JSON here "
                    "(default: stdout)")
    args = ap.parse_args()

    names = args.scenarios or streams.available()
    report = {
        "config": {"n": args.n, "p": args.p, "nx": args.nx, "ny": args.ny,
                   "pr": args.pr, "pc": args.pc, "m": args.m,
                   "cycles": args.cycles, "iters": args.iters,
                   "seed": args.seed, "threshold": args.threshold,
                   "solver": args.solver, "overlap": args.overlap},
        "scenarios": {},
    }
    for name in names:
        ndim = streams.get(name).ndim
        print(f"[streaming_bench] {name} ({ndim}D) ...", file=sys.stderr)
        static = run_arm(name, rebalance=False, args=args)
        dydd = run_arm(name, rebalance=True, args=args)
        report["scenarios"][name] = {
            "ndim": ndim,
            "static": static,
            "dydd": dydd,
            "imbalance_reduction": float(
                np.mean(static["imbalance_trajectory"])
                / max(np.mean(dydd["imbalance_trajectory"]), 1e-12)),
            "final_imbalance_reduction": float(
                static["imbalance_final"]
                / max(dydd["imbalance_final"], 1e-12)),
        }

    # Autotuned gram reduction tiles (chosen block_m + timed sweep per
    # packed shape; empty when every pack took the jnp reference path).
    report["gram_autotune"] = ops.gram_tuning_report()

    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"[streaming_bench] wrote {args.out}", file=sys.stderr)
    else:
        print(text)


if __name__ == "__main__":
    main()
