"""Streaming assimilation benchmark: static DD vs online DyDD, 1D and 2D.

For every registered observation-stream scenario — 1D interval domains and
2D shelf tilings alike — run the multi-cycle engine twice:
``rebalance=False`` (the paper's static decomposition, left to degrade as
the network moves) and ``rebalance=True`` (online DyDD with the default
threshold/hysteresis policy) — and emit a JSON comparison of per-cycle
latency (split into host+device *pack* vs device *solve*, so the batched
``kernels.ops.gram`` packing win is visible) and the imbalance trajectory.

The report also carries the communication accounting: per-arm modelled
``comm_bytes_per_cycle`` + ``halo_fraction``, a ``comm_sweep`` section
pricing the allreduce vs neighbour (halo-only ppermute) state exchange
across overlap widths s = 0..3, and — with ``--compare-comm`` on a
sharded run — measured wall-clock for both paths side by side plus the
max-abs difference of their final analyses (the ULP-parity evidence).
``--compare-kernels`` does the same for the local Schwarz step: the
historic jnp path vs the fused kernel (``solver_kernel=``), recording
solve-phase wall-clock shares and the final-analysis parity.

``--compare-domains`` additionally runs every 2D scenario's DyDD arm on
both the shelf tiling and the adaptive k-d tree domain at equal p
(pr*pc cells vs pr*pc leaves) and records final imbalance, migration
volume and comm bytes side by side — on the anisotropic station-network
scenarios (``satellite_track``, ``river_gauges``) the kdtree's final
imbalance sits strictly below the shelf's.

  PYTHONPATH=src python benchmarks/streaming_bench.py --out streaming.json
  PYTHONPATH=src python benchmarks/streaming_bench.py \
      --n 96 --m 200 --cycles 4 --scenarios drifting_swarm    # smoke
  PYTHONPATH=src python benchmarks/streaming_bench.py \
      --nx 12 --ny 8 --pr 2 --pc 2 --scenarios rotating_swarm # 2D smoke
"""
from __future__ import annotations

import argparse
import contextlib
import json
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.assim import AssimilationEngine, EngineConfig, streams  # noqa: E402
from repro.core import ddkf, domain, kdtree  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.obs import meters as obs_meters  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402


def make_config(ndim: int, rebalance: bool, args,
                comm: str | None = None,
                domain_kind: str | None = None,
                solver_kernel: str | None = None) -> EngineConfig:
    common = dict(iters=args.iters, rebalance=rebalance,
                  imbalance_threshold=args.threshold,
                  track_reference=args.track_reference,
                  solver=args.solver, overlap=args.overlap,
                  comm=comm or args.comm, halo_weight=args.halo_weight,
                  record_residuals=not args.no_residuals,
                  solver_kernel=solver_kernel or args.solver_kernel)
    if ndim == 1:
        return EngineConfig(n=args.n, p=args.p, **common)
    kind = domain_kind or args.domain
    if kind == "kdtree":
        # Equal p: the k-d tree gets exactly as many leaves as the shelf
        # has cells, so the comparison is like for like.
        return EngineConfig(ndim=2, domain_kind="kdtree",
                            p=args.pr * args.pc, nx=args.nx, ny=args.ny,
                            damping=args.damping_2d, **common)
    return EngineConfig(ndim=2, nx=args.nx, ny=args.ny,
                        pr=args.pr, pc=args.pc, damping=args.damping_2d,
                        **common)


_WALL_CLOCK_S: list = []   # measured per-arm wall-clock, for the trace
                           # coverage figure (sum of journal cycle times)


def run_arm(name: str, rebalance: bool, args, comm: str | None = None,
            domain_kind: str | None = None,
            solver_kernel: str | None = None):
    """Run one engine arm; returns (record_dict, final_analysis)."""
    ndim = streams.get(name).ndim
    eng = AssimilationEngine(make_config(ndim, rebalance, args, comm=comm,
                                         domain_kind=domain_kind,
                                         solver_kernel=solver_kernel))
    journal = eng.run_scenario(name, m=args.m, cycles=args.cycles,
                               seed=args.seed)
    cycle_times = journal.cycle_times
    _WALL_CLOCK_S.append(float(np.sum(cycle_times)))
    pack_times = [r.pack_time for r in journal.records]
    solve_times = [r.solve_time for r in journal.records]
    imb = journal.imbalance_trajectory
    return {
        "rebalance": rebalance,
        "solver": args.solver,
        "solver_kernel": solver_kernel or args.solver_kernel,
        "overlap": args.overlap,
        "comm": comm or args.comm,
        "halo_weight": args.halo_weight,
        "domain": journal.meta,
        # Modelled per-cycle communication volume of the configured comm
        # path plus the decomposition's shared-slot fraction (both from
        # the journal; the model prices solve_shardmap traffic even when
        # the arm ran the vmapped solver).
        "comm_bytes_per_cycle": [r.comm_bytes_per_cycle
                                 for r in journal.records],
        "halo_fraction": [r.halo_fraction for r in journal.records],
        "loads_weighted_final": journal.records[-1].loads_weighted,
        "imbalance_trajectory": imb,
        "imbalance_final": imb[-1],
        "efficiency_trajectory": [r.efficiency for r in journal.records],
        "cycle_latency_s": cycle_times,
        "cycle_latency_mean_s": float(np.mean(cycle_times)),
        # Steady-state latency: drop the first cycles, which pay the jit
        # specialization for each new padded block width.
        "cycle_latency_steady_s": float(np.mean(
            cycle_times[len(cycle_times) // 2:])),
        # Pack (host slicing + batched device gram/cholesky) vs solve
        # (device DD-KF iteration) — the per-cycle split.
        "pack_time_s": pack_times,
        "solve_time_s": solve_times,
        "pack_time_mean_s": float(np.mean(pack_times)),
        "solve_time_mean_s": float(np.mean(solve_times)),
        "repartitions": journal.repartition_count,
        "migrated_total": journal.migrated_total,
        # Telemetry: per-cycle Schwarz residual histories (empty with
        # --no-residuals), per-phase p50/p99, per-edge comm bytes + the
        # assembled (p, p) matrix of the final cycle, per-device solve
        # times and any straggler flags.
        "residual_history": [r.residual_history for r in journal.records],
        "phases": journal.phase_stats(),
        "comm_edge_bytes_per_cycle": [r.comm_edge_bytes_per_cycle
                                      for r in journal.records],
        "comm_matrix_final": obs_meters.comm_matrix(
            eng.p,
            journal.records[-1].comm_edge_bytes_per_cycle).tolist(),
        "comm_mvec_bytes_per_cycle": [r.comm_mvec_bytes_per_cycle
                                      for r in journal.records],
        "device_solve_times": [r.device_solve_times
                               for r in journal.records],
        "straggler_flags": [r.straggler_flags for r in journal.records],
        "summary": journal.summary(),
    }, (None if eng.analysis is None else np.asarray(eng.analysis))


def comm_sweep(args) -> dict:
    """Modelled per-iteration state-exchange bytes vs overlap width s.

    For the benchmark's 1D and 2D domain shapes (uniform boundaries —
    the model depends only on the decomposition geometry), price both
    communication paths at s = 0..3: the allreduce path is flat in s
    (it always moves the full n-vector), the neighbour path grows
    linearly with s and never depends on n — the scaling regime the
    paper's T^p_oh overhead term assumes.
    """
    itemsize = 8  # the benchmark engines run under jax_enable_x64
    out = {}
    domains = {
        "1d": domain.Interval1D(n=args.n, p=args.p),
        "2d": domain.ShelfTiling2D(nx=args.nx, ny=args.ny,
                                   pr=args.pr, pc=args.pc),
        "kdtree": kdtree.KDTreeDomain(nx=args.nx, ny=args.ny,
                                      p=args.pr * args.pc),
    }
    for key, dom in domains.items():
        rows = {}
        # stacked rows: the background block (dom.n) + observations
        m = dom.n + args.m
        mesh_shape = dom.mesh_axes()[1]
        for s in range(4):
            dec = dom.decomposition(overlap=s)
            halo = dec.halo_exchange
            alla = ddkf.comm_model(dom.n, m, dom.p, itemsize,
                                   comm="allreduce",
                                   mesh_shape=mesh_shape)
            neigh = ddkf.comm_model(dom.n, m, dom.p, itemsize,
                                    halo=halo, comm="neighbour",
                                    mesh_shape=mesh_shape)
            rows[f"s{s}"] = {
                "halo_fraction": dec.halo_fraction,
                "allreduce_state_bytes_per_device":
                    alla["state_bytes_per_device_mean"],
                "neighbour_state_bytes_per_device":
                    neigh["state_bytes_per_device_mean"],
                "neighbour_per_edge_bytes": neigh["per_edge_bytes"],
                "permute_rounds": neigh["permute_rounds"],
            }
        out[key] = rows
    return out


def pint_section(args) -> dict:
    """Parallel-in-time arm: one long 1D stream run sequentially and
    under the Parareal window engine (``repro.assim.timepar``), with the
    wall-clock cycles/sec ratio, the Parareal iteration evidence and the
    analysis-chain parity recorded side by side.

    Both arms are warmed up on the *same full stream* first so jit
    compilation does not land in either measurement: the window-stacked
    program (and the padded solver programs) are specific to the
    stream-wide max block width, which a short prefix would not
    reproduce — DyDD drifts the widths over the stream.
    """
    from repro.assim.timepar import TimeParEngine

    name, cycles = "drifting_swarm", args.pint_cycles
    cfg_kw = dict(n=args.n, p=args.p, iters=args.iters,
                  record_residuals=False)
    pint_cfg = EngineConfig(time_windows=args.time_windows,
                            pint_tol=args.pint_tol,
                            pint_fine_iters=args.pint_fine_iters,
                            pint_coarse_iters=args.pint_coarse_iters,
                            **cfg_kw)

    print(f"[streaming_bench] pint warmup ({cycles} cycles, both arms)"
          f" ...", file=sys.stderr)
    AssimilationEngine(EngineConfig(**cfg_kw)).run(
        streams.make_stream(name, args.m, cycles, seed=args.seed))
    TimeParEngine(pint_cfg).run(
        streams.make_stream(name, args.m, cycles, seed=args.seed))

    print(f"[streaming_bench] pint sequential arm ({cycles} cycles) ...",
          file=sys.stderr)
    seq = AssimilationEngine(EngineConfig(**cfg_kw))
    chain: list = []
    seq.on_analysis = lambda c, x: chain.append(np.asarray(x))
    t0 = time.perf_counter()
    seq.run(streams.make_stream(name, args.m, cycles, seed=args.seed))
    seq_wall = time.perf_counter() - t0

    print(f"[streaming_bench] pint windowed arm (W={args.time_windows})"
          f" ...", file=sys.stderr)
    tp = TimeParEngine(pint_cfg)
    t0 = time.perf_counter()
    journal = tp.run(streams.make_stream(name, args.m, cycles,
                                         seed=args.seed))
    pint_wall = time.perf_counter() - t0

    meta = journal.meta["pint"]
    diff = max(float(np.max(np.abs(a - b)))
               for a, b in zip(tp.analyses, chain))
    return {
        "scenario": name,
        "cycles": cycles,
        "time_windows": meta["time_windows"],
        "window_sizes": meta["window_sizes"],
        "mesh": meta["mesh"],
        "coarse_iters": meta["coarse_iters"],
        "fine_iters": meta["fine_iters"],
        "warm_start": meta["warm_start"],
        "pint_iters": meta["iters"],
        "converged": bool(meta["converged"]),
        "correction_norms": meta["correction_norms"],
        "tol": meta["tol"],
        "sequential_wall_s": seq_wall,
        "pint_wall_s": pint_wall,
        "sequential_cycles_per_sec": cycles / max(seq_wall, 1e-12),
        "pint_cycles_per_sec": cycles / max(pint_wall, 1e-12),
        # The headline: windowed throughput over sequential on the same
        # stream (> 1 means the time axis bought real wall-clock).
        "pint_over_sequential_cycles_per_sec":
            seq_wall / max(pint_wall, 1e-12),
        "analysis_chain_max_abs_diff": diff,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=256, help="1D state dimension")
    ap.add_argument("--p", type=int, default=8, help="1D subdomains")
    ap.add_argument("--nx", type=int, default=24, help="2D mesh width")
    ap.add_argument("--ny", type=int, default=12, help="2D mesh height")
    ap.add_argument("--pr", type=int, default=2, help="2D strip count")
    ap.add_argument("--pc", type=int, default=4, help="2D cells per strip")
    ap.add_argument("--damping-2d", type=float, default=0.7,
                    help="additive-Schwarz damping for the 2D tiling")
    ap.add_argument("--m", type=int, default=600)
    ap.add_argument("--cycles", type=int, default=8)
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--threshold", type=float, default=1.5)
    ap.add_argument("--track-reference", action="store_true",
                    help="also journal per-cycle error vs one-shot solve")
    ap.add_argument("--solver", default="vmapped",
                    choices=("vmapped", "shardmap"),
                    help="shardmap needs one device per subdomain")
    ap.add_argument("--overlap", type=int, default=0,
                    help="Schwarz halo width")
    ap.add_argument("--comm", default="allreduce",
                    choices=("allreduce", "neighbour"),
                    help="sharded state-exchange path (neighbour = "
                    "halo-only ppermute rounds)")
    ap.add_argument("--halo-weight", type=float, default=0.0,
                    help="overlap-aware DyDD: work units per halo column "
                    "added to the scheduled loads")
    ap.add_argument("--solver-kernel", default="auto",
                    choices=ddkf.SOLVER_KERNELS,
                    help="local Schwarz step implementation (auto = "
                    "fused Pallas on TPU, jnp elsewhere)")
    ap.add_argument("--domain", default="shelf",
                    choices=("shelf", "kdtree"),
                    help="2D domain of the main arms: shelf tiling or "
                    "adaptive k-d tree (pr*pc leaves)")
    ap.add_argument("--compare-domains", action="store_true",
                    help="also run the DyDD arm of every 2D scenario "
                    "with both the shelf and the kdtree domain at equal "
                    "p and record final imbalance / migration volume / "
                    "comm bytes side by side")
    ap.add_argument("--compare-comm", action="store_true",
                    help="also run the DyDD arm with both comm paths and "
                    "record wall-clock + modelled bytes side by side "
                    "(meaningful with --solver shardmap)")
    ap.add_argument("--compare-kernels", action="store_true",
                    help="also run the DyDD arm with the jnp and the "
                    "fused Schwarz-step kernel and record wall-clock + "
                    "solve phase ratio side by side (the fused kernel "
                    "resolves to its interpret/reference path off-TPU)")
    ap.add_argument("--time-windows", type=int, default=0,
                    help="run the parallel-in-time section: a long "
                    "drifting_swarm stream sequentially and under the "
                    "Parareal window engine with this many windows "
                    "(sharded over a ('time','sub') mesh when the "
                    "device count factors); 0 = off")
    ap.add_argument("--pint-cycles", type=int, default=32,
                    help="stream length of the parallel-in-time section")
    ap.add_argument("--pint-tol", type=float, default=1e-8,
                    help="Parareal correction-norm stopping tolerance")
    ap.add_argument("--pint-coarse-iters", type=int, default=0,
                    help="Schwarz iterations of the coarse propagator "
                    "(0 = --iters // 10)")
    ap.add_argument("--pint-fine-iters", type=int, default=0,
                    help="Schwarz iterations of the warm-started fine "
                    "sweeps (0 = cold full --iters solves); the "
                    "work-optimal Parareal setting — coarse + fine "
                    "iterations together buy the accuracy, so the "
                    "windowed arm spends fewer total iterations per "
                    "cycle than the sequential arm at the same "
                    "analysis-chain tolerance")
    ap.add_argument("--scenarios", nargs="*", default=None,
                    choices=streams.available(),
                    help="subset of the registered scenarios "
                    "(default: all, 1D and 2D)")
    ap.add_argument("--out", default=None, help="write JSON here "
                    "(default: stdout)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome/Perfetto trace_events timeline "
                    "of every engine run here (open at ui.perfetto.dev)")
    ap.add_argument("--profile", default=None, metavar="LOGDIR",
                    help="wrap the runs in jax.profiler.trace into this "
                    "directory (TensorBoard XPlane; kernel-level)")
    ap.add_argument("--no-residuals", action="store_true",
                    help="skip the per-iteration Schwarz residual "
                    "histories (drops the lax.scan solve variant)")
    args = ap.parse_args()

    # Fresh telemetry sinks for this run: a meters registry (always — the
    # snapshot lands in the report) and a span tracer when --trace asks
    # for a timeline.  ExitStack keeps the scenario loop un-indented.
    obs_meters.set_meters(obs_meters.Meters())
    tracer = obs_trace.Tracer("streaming_bench") if args.trace else None
    ctx = contextlib.ExitStack()
    ctx.enter_context(obs_trace.tracing(tracer))
    ctx.enter_context(obs_trace.jax_profile(args.profile))

    names = args.scenarios or streams.available()
    report = {
        "config": {"n": args.n, "p": args.p, "nx": args.nx, "ny": args.ny,
                   "pr": args.pr, "pc": args.pc, "m": args.m,
                   "cycles": args.cycles, "iters": args.iters,
                   "seed": args.seed, "threshold": args.threshold,
                   "solver": args.solver, "overlap": args.overlap,
                   "comm": args.comm, "halo_weight": args.halo_weight,
                   "domain": args.domain,
                   "solver_kernel": args.solver_kernel,
                   "time_windows": args.time_windows,
                   "pint_cycles": args.pint_cycles,
                   "pint_fine_iters": args.pint_fine_iters},
        "scenarios": {},
        # Modelled bytes vs overlap width for both comm paths (no runs
        # needed — the model depends only on the decomposition).
        "comm_sweep": comm_sweep(args),
    }
    for name in names:
        ndim = streams.get(name).ndim
        print(f"[streaming_bench] {name} ({ndim}D) ...", file=sys.stderr)
        static, _ = run_arm(name, rebalance=False, args=args)
        dydd, x_dydd = run_arm(name, rebalance=True, args=args)
        report["scenarios"][name] = {
            "ndim": ndim,
            "static": static,
            "dydd": dydd,
            "imbalance_reduction": float(
                np.mean(static["imbalance_trajectory"])
                / max(np.mean(dydd["imbalance_trajectory"]), 1e-12)),
            "final_imbalance_reduction": float(
                static["imbalance_final"]
                / max(dydd["imbalance_final"], 1e-12)),
        }
        if args.compare_domains and ndim == 2:
            # Shelf-vs-kdtree at equal p (pr*pc cells vs pr*pc leaves):
            # final imbalance, migration volume, modelled comm bytes on
            # the anisotropic station networks.  Since tie-aware 2D
            # counting the shelf rank-splits tied coordinate groups and
            # lands on the m/p rounding floor even here; the kdtree's
            # geometric median cuts cannot, so the ratio below now
            # favours the shelf on count balance (the kdtree keeps
            # arbitrary-p support and strip-free geometry).
            compare_d = {}
            for kind in ("shelf", "kdtree"):
                if kind == args.domain:
                    arm = dydd
                else:
                    print(f"[streaming_bench]   domain={kind} ...",
                          file=sys.stderr)
                    arm, _ = run_arm(name, rebalance=True, args=args,
                                     domain_kind=kind)
                compare_d[kind] = {
                    "imbalance_final": arm["imbalance_final"],
                    "imbalance_mean": float(
                        np.mean(arm["imbalance_trajectory"])),
                    "migrated_total": arm["migrated_total"],
                    "repartitions": arm["repartitions"],
                    "comm_bytes_per_cycle_mean": float(
                        np.mean(arm["comm_bytes_per_cycle"])),
                    "p": arm["domain"]["p"],
                }
            assert compare_d["shelf"]["p"] == compare_d["kdtree"]["p"]
            compare_d["final_imbalance_ratio_shelf_over_kdtree"] = float(
                compare_d["shelf"]["imbalance_final"]
                / max(compare_d["kdtree"]["imbalance_final"], 1e-12))
            report["scenarios"][name]["domain_compare"] = compare_d
        if args.compare_comm:
            # Allreduce-vs-neighbour on the same scenario: measured
            # wall-clock next to modelled per-cycle bytes.  The dydd arm
            # above already ran with args.comm — only the other path
            # needs a fresh run.
            compare = {}
            analyses = {args.comm: x_dydd}
            for comm in ("allreduce", "neighbour"):
                if comm == args.comm:
                    arm = dydd
                else:
                    print(f"[streaming_bench]   comm={comm} ...",
                          file=sys.stderr)
                    arm, analyses[comm] = run_arm(name, rebalance=True,
                                                  args=args, comm=comm)
                compare[comm] = {
                    "solve_time_mean_s": arm["solve_time_mean_s"],
                    "cycle_latency_steady_s": arm["cycle_latency_steady_s"],
                    "comm_bytes_per_cycle_mean": float(
                        np.mean(arm["comm_bytes_per_cycle"])),
                }
            compare["modelled_bytes_ratio"] = float(
                compare["allreduce"]["comm_bytes_per_cycle_mean"]
                / max(compare["neighbour"]["comm_bytes_per_cycle_mean"],
                      1e-12))
            # The two comm paths iterate the identical update; their
            # final analyses may differ only by collective reduction
            # order (ULPs) — recorded so the CI artifact carries the
            # parity evidence.
            compare["analysis_max_abs_diff"] = float(np.max(np.abs(
                analyses["allreduce"] - analyses["neighbour"])))
            report["scenarios"][name]["comm_compare"] = compare
        if args.compare_kernels:
            # Jnp-vs-fused Schwarz step on the same scenario: measured
            # wall-clock and the solve phase's share of the cycle for
            # both local-step implementations.  Off-TPU "fused" resolves
            # to the single-pass stacked reference (same arithmetic
            # structure as the kernel), so the comparison stays honest
            # on a CPU CI host.
            kcompare = {}
            kanalyses = {}
            for kern in ("jnp", "fused"):
                if kern == args.solver_kernel:
                    arm, kanalyses[kern] = dydd, x_dydd
                else:
                    print(f"[streaming_bench]   kernel={kern} ...",
                          file=sys.stderr)
                    arm, kanalyses[kern] = run_arm(
                        name, rebalance=True, args=args,
                        solver_kernel=kern)
                summ = arm["summary"]
                solve_p50 = summ["phases"].get("solve", {}).get("p50", 0.0)
                kcompare[kern] = {
                    "solve_time_mean_s": arm["solve_time_mean_s"],
                    "cycle_latency_steady_s": arm["cycle_latency_steady_s"],
                    "solve_phase_ratio": float(
                        solve_p50 / max(summ["cycle_time_mean"], 1e-12)),
                }
            kcompare["fused_over_jnp_solve_ratio"] = float(
                kcompare["fused"]["solve_time_mean_s"]
                / max(kcompare["jnp"]["solve_time_mean_s"], 1e-12))
            # Both kernels iterate the identical update; the final
            # analyses may differ only by reduction order (ULPs) — the
            # CI artifact's parity evidence.
            kcompare["analysis_max_abs_diff"] = float(np.max(np.abs(
                kanalyses["jnp"] - kanalyses["fused"])))
            report["scenarios"][name]["kernel_compare"] = kcompare

    if args.time_windows > 0:
        report["pint"] = pint_section(args)

    # Autotuned gram reduction tiles (chosen block_m + timed sweep per
    # packed shape; empty when every pack took the jnp reference path).
    report["gram_autotune"] = ops.gram_tuning_report()
    # Same for the fused Schwarz-step kernel (empty when every solve ran
    # the jnp or reference path).
    report["schwarz_autotune"] = ops.schwarz_tuning_report()

    ctx.close()   # stop profiling, restore the previous tracer
    # Counter/gauge/series registry the engines and core layers reported
    # into (comm bytes, halo builds, CG residuals, straggler flags ...).
    report["meters"] = obs_meters.get_meters().snapshot()
    if tracer is not None:
        wall = float(np.sum(_WALL_CLOCK_S))
        report["trace"] = {
            "path": args.trace,
            "wall_clock_s": wall,
            # Fraction of the measured cycle wall-clock covered by the
            # engine's "cycle" spans — the acceptance metric (>= 0.95).
            "cycle_coverage": tracer.coverage("cycle", wall),
            "events": len(tracer.events),
        }
        tracer.save(args.trace)
        print(f"[streaming_bench] wrote trace {args.trace} "
              f"(coverage {report['trace']['cycle_coverage']:.3f})",
              file=sys.stderr)

    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"[streaming_bench] wrote {args.out}", file=sys.stderr)
    else:
        print(text)


if __name__ == "__main__":
    main()
