"""The paper's §6 scenarios, end to end, with a moving observation network.

Reproduces the structure of Examples 1-4 and then goes beyond the paper's
static snapshot: the observation distribution DRIFTS over assimilation
cycles (a moving sensor swarm) and DyDD re-balances each cycle — the
configuration the paper's conclusion names as future work ("each subdomain
to move independently with time").

  PYTHONPATH=src python examples/dydd_assimilation.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cls, dd, ddkf, dydd  # noqa: E402


def drifting_observations(m, cycle, n_cycles, seed=0):
    """A cluster of sensors drifting from x=0.2 to x=0.8 over cycles."""
    rng = np.random.default_rng(seed + cycle)
    center = 0.2 + 0.6 * cycle / max(n_cycles - 1, 1)
    obs = np.clip(center + 0.08 * rng.normal(size=m), 0, 0.999999)
    return np.sort(obs)


def main():
    n, m, p, cycles = 512, 800, 8, 6
    key = jax.random.PRNGKey(0)

    print(f"{cycles} assimilation cycles, {m} drifting observations, "
          f"p={p} subdomains\n")
    print(f"{'cycle':>5s} {'E static':>9s} {'E DyDD':>8s} {'rounds':>6s} "
          f"{'moved':>6s} {'error_DD-DA':>12s}")

    boundaries = np.linspace(0, 1, p + 1)
    for c in range(cycles):
        obs = drifting_observations(m, c, cycles)
        prob = cls.local_problem(key, n, obs)

        static_counts = np.histogram(obs, bins=p, range=(0, 1))[0]
        e_static = dydd.balance_ratio(static_counts)

        # Dynamic re-decomposition: start from LAST cycle's boundaries
        # (the paper's 'dynamic redefining of the DD').
        res = dydd.dydd_1d(obs, p, boundaries=boundaries.copy())
        boundaries = res.boundaries

        dec = dd.decompose_1d(n, res.boundaries)
        packed = ddkf.pack(prob, dec)
        x_dd = ddkf.solve_vmapped(packed, iters=120)
        err = float(jnp.linalg.norm(x_dd - cls.solve(prob)))

        print(f"{c:5d} {e_static:9.3f} {res.efficiency:8.3f} "
              f"{res.rounds:6d} {res.total_movement:6d} {err:12.2e}")
        assert res.efficiency > 0.8
        assert err < 1e-8

    print("\nDyDD keeps every cycle balanced while the static DD would "
          "have collapsed to E~0 (all sensors in one subdomain).")


if __name__ == "__main__":
    main()
