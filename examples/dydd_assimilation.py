"""Streaming DD-KF assimilation with online DyDD — thin engine driver.

Runs registered observation-stream scenarios through the
:class:`repro.assim.AssimilationEngine`: multi-cycle DD-KF with the
analysis carried forward as the next background and DyDD repartitioning
the subdomains whenever the moving observation network unbalances them —
the configuration the paper's conclusion names as future work ("each
subdomain to move independently with time").

  PYTHONPATH=src python examples/dydd_assimilation.py
  PYTHONPATH=src python examples/dydd_assimilation.py \
      --n 96 --m 200 --cycles 4 --scenarios drifting_swarm   # CI smoke
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

from repro.assim import AssimilationEngine, EngineConfig, streams  # noqa: E402


def run_scenario(name: str, args) -> None:
    cfg = EngineConfig(n=args.n, p=args.p, iters=args.iters,
                       rebalance=not args.static,
                       imbalance_threshold=args.threshold,
                       hysteresis=args.hysteresis,
                       track_reference=True)
    eng = AssimilationEngine(cfg)
    print(f"\n=== {name} ({'static DD' if args.static else 'DyDD'}, "
          f"p={cfg.p}, m={args.m}, {args.cycles} cycles) ===")
    print(f"{'cycle':>5s} {'imb_in':>7s} {'imb_out':>7s} {'E':>6s} "
          f"{'rep':>4s} {'moved':>6s} {'t_cycle':>8s} {'err_DD-DA':>10s}")
    journal = eng.run_scenario(name, m=args.m, cycles=args.cycles,
                               seed=args.seed)
    for r in journal.records:
        print(f"{r.cycle:5d} {r.imbalance_before:7.2f} {r.imbalance:7.2f} "
              f"{r.efficiency:6.3f} {'yes' if r.repartitioned else '-':>4s} "
              f"{r.migrated:6d} {r.cycle_time * 1e3:7.1f}ms "
              f"{r.error_vs_direct:10.2e}")
    s = journal.summary()
    print(f"summary: {s['repartitions']} repartitions, "
          f"{s['migrated_total']} observations migrated, "
          f"max imbalance {s['imbalance_max']:.3f}, "
          f"max error vs one-shot solve {s['error_max']:.2e}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=512, help="state dimension")
    ap.add_argument("--m", type=int, default=800, help="observations/cycle")
    ap.add_argument("--p", type=int, default=8, help="subdomains")
    ap.add_argument("--cycles", type=int, default=6)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max/mean imbalance ratio arming the rebalance")
    ap.add_argument("--hysteresis", type=int, default=1,
                    help="consecutive over-threshold cycles before firing")
    ap.add_argument("--static", action="store_true",
                    help="disable DyDD (static-DD baseline)")
    ap.add_argument("--scenarios", nargs="*", default=None,
                    choices=streams.available(),
                    help="subset of the registered scenarios (default: all)")
    args = ap.parse_args()

    for name in args.scenarios or streams.available():
        run_scenario(name, args)


if __name__ == "__main__":
    main()
