"""Streaming DD-KF assimilation with online DyDD — thin engine driver.

Runs registered observation-stream scenarios through the
:class:`repro.assim.AssimilationEngine`: multi-cycle DD-KF with the
analysis carried forward as the next background and DyDD repartitioning
the subdomains whenever the moving observation network unbalances them —
the configuration the paper's conclusion names as future work ("each
subdomain to move independently with time").

``--ndim 1`` (default) drives an Interval1D domain; ``--ndim 2`` drives a
ShelfTiling2D (the paper's Ω ⊂ R² setting) and prints the per-cell load
table before/after each rebalance.  ``--ndim 2 --domain kdtree`` swaps
the shelf for the adaptive k-d tree domain (pr*pc median-split leaves —
the right choice for strongly anisotropic networks such as
``satellite_track`` / ``river_gauges``).

  PYTHONPATH=src python examples/dydd_assimilation.py
  PYTHONPATH=src python examples/dydd_assimilation.py \
      --n 96 --m 200 --cycles 4 --scenarios drifting_swarm   # CI smoke
  PYTHONPATH=src python examples/dydd_assimilation.py \
      --ndim 2 --nx 12 --ny 8 --pr 2 --pc 2 --m 200 --cycles 2 \
      --scenarios rotating_swarm                             # 2D CI smoke
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/dydd_assimilation.py \
      --ndim 2 --pr 2 --pc 4 --overlap 1 --solver shardmap \
      --scenarios rotating_swarm    # sharded: one device per tiling cell
  PYTHONPATH=src python examples/dydd_assimilation.py \
      --ndim 2 --domain kdtree --pr 2 --pc 4 --m 300 --cycles 3 \
      --scenarios satellite_track river_gauges  # anisotropic k-d domain
"""
import argparse

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.assim import AssimilationEngine, EngineConfig, streams  # noqa: E402
from repro.core import ddkf  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402


def make_config(args) -> EngineConfig:
    common = dict(iters=args.iters, rebalance=not args.static,
                  imbalance_threshold=args.threshold,
                  hysteresis=args.hysteresis, track_reference=True,
                  solver=args.solver, overlap=args.overlap,
                  comm=args.comm, halo_weight=args.halo_weight,
                  record_residuals=args.residuals,
                  solver_kernel=args.solver_kernel)
    if args.ndim == 1:
        return EngineConfig(n=args.n, p=args.p, **common)
    if args.domain == "kdtree":
        # Equal p to the shelf at the same flags: pr*pc leaves.
        return EngineConfig(ndim=2, domain_kind="kdtree",
                            p=args.pr * args.pc, nx=args.nx, ny=args.ny,
                            damping=args.damping, **common)
    return EngineConfig(ndim=2, nx=args.nx, ny=args.ny, pr=args.pr,
                        pc=args.pc, damping=args.damping, **common)


def print_load_table(eng, rec) -> None:
    """Per-cell loads before/after the cycle's rebalance, as pr x pc grids."""
    before = eng.domain.load_table(rec.loads_before)
    after = eng.domain.load_table(rec.loads)
    rows = []
    for rb, ra in zip(np.atleast_2d(before), np.atleast_2d(after)):
        rows.append("  " + " ".join(f"{v:5d}" for v in rb)
                    + "   ->   " + " ".join(f"{v:5d}" for v in ra))
    print(f"  cycle {rec.cycle} cell loads (before -> after rebalance):")
    print("\n".join(rows))


def run_scenario(name: str, args) -> None:
    cfg = make_config(args)
    eng = AssimilationEngine(cfg)
    dom = eng.journal.meta
    if args.ndim == 1:
        shape = f"p={dom['p']}"
    elif dom["kind"] == "kdtree":
        shape = (f"{dom['p']}-leaf k-d tree on a "
                 f"{dom['nx']}x{dom['ny']} mesh")
    else:
        shape = (f"{dom['pr']}x{dom['pc']} cells on a "
                 f"{dom['nx']}x{dom['ny']} mesh")
    solver = cfg.solver + (f" on mesh {dict(eng.mesh.shape)}"
                           if eng.mesh is not None else "")
    if cfg.solver == "shardmap":
        solver += f", comm={cfg.comm}"
    print(f"\n=== {name} ({'static DD' if args.static else 'DyDD'}, "
          f"{shape}, overlap={cfg.overlap}, {solver}, m={args.m}, "
          f"{args.cycles} cycles) ===")
    print(f"{'cycle':>5s} {'imb_in':>7s} {'imb_out':>7s} {'E':>6s} "
          f"{'rep':>4s} {'moved':>6s} {'t_cycle':>8s} {'err_DD-DA':>10s}")
    journal = eng.run_scenario(name, m=args.m, cycles=args.cycles,
                               seed=args.seed)
    for r in journal.records:
        print(f"{r.cycle:5d} {r.imbalance_before:7.2f} {r.imbalance:7.2f} "
              f"{r.efficiency:6.3f} {'yes' if r.repartitioned else '-':>4s} "
              f"{r.migrated:6d} {r.cycle_time * 1e3:7.1f}ms "
              f"{r.error_vs_direct:10.2e}")
        if args.ndim == 2 and r.repartitioned:
            print_load_table(eng, r)
    s = journal.summary()
    print(f"summary: {s['repartitions']} repartitions, "
          f"{s['migrated_total']} observations migrated, "
          f"max imbalance {s['imbalance_max']:.3f}, "
          f"max error vs one-shot solve {s['error_max']:.2e}")
    if cfg.overlap > 0:
        print(f"comm ({cfg.comm}): "
              f"{s['comm_bytes_per_cycle_mean'] / 1e3:.1f} kB/cycle "
              f"modelled, halo fraction "
              f"{s['halo_fraction_mean']:.3f}")
    if s.get("phases"):
        split = ", ".join(f"{k} {v['p50'] * 1e3:.1f}ms"
                          for k, v in sorted(s["phases"].items()))
        print(f"phase p50: {split}")
    if cfg.record_residuals and s.get("residual_final_mean") is not None:
        print(f"Schwarz residual (final iter, mean over cycles): "
              f"{s['residual_final_mean']:.2e}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ndim", type=int, default=1, choices=(1, 2),
                    help="domain dimension: 1 = interval, 2 = shelf tiling "
                    "or k-d tree (see --domain)")
    ap.add_argument("--domain", default="shelf",
                    choices=("shelf", "kdtree"),
                    help="2D domain kind: shelf tiling (pr x pc cells) or "
                    "adaptive k-d tree (pr*pc median-split leaves — for "
                    "strongly anisotropic networks)")
    ap.add_argument("--n", type=int, default=512, help="1D state dimension")
    ap.add_argument("--p", type=int, default=8, help="1D subdomains")
    ap.add_argument("--nx", type=int, default=24, help="2D mesh width")
    ap.add_argument("--ny", type=int, default=12, help="2D mesh height")
    ap.add_argument("--pr", type=int, default=2, help="2D strip count")
    ap.add_argument("--pc", type=int, default=4, help="2D cells per strip")
    ap.add_argument("--damping", type=float, default=0.7,
                    help="additive-Schwarz damping (2D tilings converge "
                    "with under-relaxation)")
    ap.add_argument("--m", type=int, default=800, help="observations/cycle")
    ap.add_argument("--cycles", type=int, default=6)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max/mean imbalance ratio arming the rebalance")
    ap.add_argument("--hysteresis", type=int, default=1,
                    help="consecutive over-threshold cycles before firing")
    ap.add_argument("--static", action="store_true",
                    help="disable DyDD (static-DD baseline)")
    ap.add_argument("--solver", default="vmapped",
                    choices=("vmapped", "shardmap"),
                    help="shardmap needs one device per subdomain "
                    "(set XLA_FLAGS=--xla_force_host_platform_device_"
                    "count=<p> on CPU)")
    ap.add_argument("--overlap", type=int, default=0,
                    help="Schwarz halo width (mesh columns/rows absorbed "
                    "from each grid-graph neighbour)")
    ap.add_argument("--comm", default="allreduce",
                    choices=("allreduce", "neighbour"),
                    help="sharded state exchange: full n-vector allreduce "
                    "or halo-only neighbour ppermute rounds")
    ap.add_argument("--halo-weight", type=float, default=0.0,
                    help="overlap-aware DyDD: work units per halo column "
                    "added to the loads the schedule balances")
    ap.add_argument("--solver-kernel", default="auto",
                    choices=ddkf.SOLVER_KERNELS,
                    help="local Schwarz step: auto (fused Pallas on TPU, "
                    "jnp elsewhere), jnp, fused, fused_interpret, "
                    "fused_ref")
    ap.add_argument("--scenarios", nargs="*", default=None,
                    choices=streams.available(),
                    help="subset of the registered scenarios "
                    "(default: all of this --ndim)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome/Perfetto trace_events timeline "
                    "of the runs here (open at ui.perfetto.dev)")
    ap.add_argument("--profile", default=None, metavar="LOGDIR",
                    help="wrap the runs in jax.profiler.trace into this "
                    "directory (TensorBoard XPlane; kernel-level)")
    ap.add_argument("--residuals", action="store_true",
                    help="journal per-iteration Schwarz residual "
                    "histories (lax.scan solve variant)")
    args = ap.parse_args()

    names = args.scenarios or streams.available(ndim=args.ndim)
    tracer = obs_trace.Tracer("dydd_assimilation") if args.trace else None
    with obs_trace.tracing(tracer), obs_trace.jax_profile(args.profile):
        for name in names:
            if streams.get(name).ndim != args.ndim:
                raise SystemExit(
                    f"scenario {name!r} is {streams.get(name).ndim}D; "
                    f"pass --ndim {streams.get(name).ndim}")
            run_scenario(name, args)
    if tracer is not None:
        tracer.save(args.trace)
        print(f"\nwrote trace {args.trace} "
              f"({len(tracer.events)} events)")


if __name__ == "__main__":
    main()
