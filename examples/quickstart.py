"""Quickstart: the paper's pipeline in ~40 lines.

Non-uniform observations -> DyDD load balancing -> DD-KF distributed solve,
validated against the sequential KF estimate (error_DD-DA ~ 1e-14).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cls, dd, ddkf, dydd, kalman  # noqa: E402
from repro.data import observations  # noqa: E402


def main():
    n, m, p = 512, 1200, 8

    # 1. A CLS state-estimation problem with spatially clustered (sparse,
    #    non-uniform) observations — the setting DyDD exists for.
    obs = observations.make_observations(m, kind="clustered", seed=42)
    prob = cls.local_problem(jax.random.PRNGKey(0), n, obs)

    # 2. Static uniform DD would be badly unbalanced:
    static_counts = np.histogram(obs, bins=p, range=(0, 1))[0]
    print(f"static DD loads:   {static_counts}  "
          f"(E = {dydd.balance_ratio(static_counts):.3f})")

    # 3. DyDD: DD step + diffusion scheduling + boundary migration.
    res = dydd.dydd_1d(obs, p)
    print(f"after DyDD:        {res.loads_final}  "
          f"(E = {res.efficiency:.3f}, {res.rounds} scheduling rounds, "
          f"{res.total_movement} obs moved)")

    # 4. DD-KF: the distributed Kalman/CLS solve on the balanced DD.
    dec = dd.decompose_1d(n, res.boundaries)
    packed = ddkf.pack(prob, dec)
    x_ddkf = ddkf.solve_vmapped(packed, iters=120)

    # 5. Validate against the sequential KF (the paper's reference).
    x_kf = kalman.solve_cls_sequential(prob, block=50)
    err = float(jnp.linalg.norm(x_ddkf - x_kf))
    print(f"error_DD-DA = ||x_KF - x_DD-KF|| = {err:.2e}   "
          f"(paper reports ~1e-11 at n=2048)")
    assert err < 1e-8


if __name__ == "__main__":
    main()
