"""Serve a small model with batched requests: prefill + lock-step decode
with per-request lengths, greedy and sampled decoding.

  PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-1b]
"""
import argparse

import numpy as np
import jax

from repro import configs
from repro.launch.serve import Request, serve_batch
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        1, cfg.vocab_size,
                        int(rng.integers(4, 32))).astype(np.int32),
                    max_new=int(rng.integers(8, args.max_new + 1)))
            for i in range(args.batch)]
    print(f"{len(reqs)} requests, prompt lens "
          f"{[len(r.prompt) for r in reqs]}, max_new "
          f"{[r.max_new for r in reqs]}")

    reqs, stats = serve_batch(cfg, params, reqs, max_seq=64, greedy=True)
    for r in reqs:
        print(f"  req {r.rid}: generated {len(r.out)} tokens "
              f"{r.out[:10]}{'...' if len(r.out) > 10 else ''}")
    print(f"prefill {stats['prefill_s']*1e3:.0f} ms, "
          f"decode {stats['decode_s']*1e3:.0f} ms "
          f"({stats['tokens_per_s']:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
