"""End-to-end driver: train a ~100M-param gemma3-family model for a few
hundred steps with the full production stack — DyDD-balanced data loading,
AdamW + cosine schedule, straggler monitoring, async fault-tolerant
checkpoints with auto-resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

--tiny (CI mode) shrinks the model so the example completes in ~a minute.
"""
import argparse
import os
import tempfile

from repro import configs
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.tiny:
        cfg = configs.get_smoke_config("gemma3-1b")
        seq, batch, dp = 64, 8, 4
    else:
        # ~100M params: gemma3-1b family at reduced width/depth
        cfg = configs.get_config("gemma3-1b").scaled(
            num_layers=12, d_model=512, num_heads=4, num_kv_heads=1,
            head_dim=128, d_ff=2048, vocab_size=32768, window=256,
            dtype="float32", fsdp=False, remat="none", loss_chunk=0,
            attn_q_chunk=0, scan_layers=True)
        seq, batch, dp = 256, 8, 4
        n = cfg.param_count()
        print(f"model: {cfg.name}-family, {n/1e6:.1f}M params")

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_train_lm")
    _, _, losses = train(cfg, steps=args.steps, seq=seq,
                         global_batch=batch, dp=dp, ckpt_dir=ckpt_dir,
                         ckpt_every=100, lr=3e-4, log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({len(losses)} steps); checkpoints in {ckpt_dir}")
    assert losses[-1] < losses[0], "training should reduce the loss"


if __name__ == "__main__":
    main()
