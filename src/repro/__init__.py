"""repro: DyDD dynamic domain decomposition framework in JAX."""
__version__ = "1.0.0"
