"""Streaming multi-cycle DD-KF assimilation with online DyDD rebalancing.

See README.md in this directory for the engine loop, the rebalance
trigger policy, and how to add a stream scenario.
"""
from repro.assim.engine import (  # noqa: F401
    AssimilationEngine, CycleStep, EngineConfig)
from repro.assim.metrics import (  # noqa: F401
    CycleMetrics, Journal, imbalance_ratio)
from repro.assim import streams  # noqa: F401
from repro.assim.serving import FleetServer  # noqa: F401
from repro.assim.timepar import TimeParEngine  # noqa: F401
