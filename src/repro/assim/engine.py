"""Streaming multi-cycle DD-KF assimilation engine with online DyDD.

The engine consumes an observation stream cycle by cycle and, per cycle:

  1. counts the incoming observations against the *current* subdomain
     boundaries of its :class:`~repro.core.domain.Domain` and decides —
     threshold + hysteresis, see :class:`EngineConfig` — whether to fire a
     DyDD repartition (DD-step for empty subdomains, Hu–Blake–Emerson
     diffusion scheduling on the domain's processor graph, geometric
     boundary migration — ``dydd_1d`` on an :class:`Interval1D`,
     ``dydd_2d``'s per-axis passes on a :class:`ShelfTiling2D`);
  2. decomposes the state index set on the (possibly moved) boundaries and
     packs the local operator blocks — host-side slicing plus the batched
     device-side normal-matrix/Cholesky build (``ddkf.pack_operator``,
     ``kernels.ops.gram``);
  3. injects the cycle's right-hand side (background carried forward from
     the previous analysis + fresh observation data) and runs the sharded
     DD-KF solve (``ddkf.solve_vmapped`` / ``solve_shardmap``);
  4. journals loads, imbalance, migration volume and timings
     (:mod:`repro.assim.metrics`).

Pipelining: with ``double_buffer=True`` step 1+2 for cycle t+1 run on a
host worker thread while the device solves cycle t.  This is sound
because the rebalance decision and the operator packing depend only on
the observation stream and the boundary state — never on a solve result;
only the rhs (step 3) consumes the carried analysis, and it is injected
on the main thread via a cheap ``dataclasses.replace``.
"""
from __future__ import annotations

import contextlib
import copy
import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cls as cls_mod
from repro.core import dd as dd_mod
from repro.core import ddkf as ddkf_mod
from repro.core import domain as domain_mod
from repro.core import dydd as dydd_mod
from repro.core import kdtree as kdtree_mod
from repro.core import _compat as compat_mod
from repro.checkpoint import manager as ckpt_mod
from repro.kernels import ops as ops_mod
from repro.obs import meters as meters_mod
from repro.obs import trace as trace_mod
from repro.runtime import chaos as chaos_mod
from repro.runtime.straggler import StragglerConfig, StragglerMonitor
from repro.assim import streams as streams_mod
from repro.assim.metrics import CycleMetrics, Journal, imbalance_ratio


@contextlib.contextmanager
def _phase(phases: dict, name: str, **args):
    """Time one engine phase into both telemetry sinks: the journal's
    per-cycle ``phases`` dict (always, via perf_counter) and the active
    tracer's span timeline (a shared no-op when tracing is off)."""
    t0 = time.perf_counter()
    with trace_mod.span(name, **args):
        yield
    phases[name] = phases.get(name, 0.0) + (time.perf_counter() - t0)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Streaming DD-KF engine configuration.

    Domain selection: ``ndim=1`` (default) runs on an
    :class:`~repro.core.domain.Interval1D` with ``p`` subdomains over an
    ``n``-point mesh; ``ndim=2`` runs on a
    :class:`~repro.core.domain.ShelfTiling2D` of ``pr x pc`` cells over an
    ``nx x ny`` raster mesh (``nx``/``ny`` default to the most-square
    factoring of ``n``).  An explicit ``domain=`` handed to the engine
    overrides all of these.

    Solver selection: ``solver="vmapped"`` (default) batches subdomains on
    a leading axis of one device; ``solver="shardmap"`` runs one device
    per subdomain on a mesh shaped like the domain's processor graph —
    a (p,) chain in 1D, a (pr, pc) grid in 2D.  The engine builds the
    mesh itself when the visible device count equals p (e.g. under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), or accepts
    an explicit ``mesh=``; a device-count mismatch is rejected up front.
    ``overlap`` (>= 0, validated here for every domain) is the Schwarz
    halo width in mesh columns/rows absorbed from each grid-graph
    neighbour, with ``mu`` the overlap regularization of eq. 25-26.

    Rebalance trigger policy: a repartition fires at the start of a cycle
    when EITHER (a) some subdomain would receive zero observations (the
    DD-step must split a neighbour — never deferred), or (b) the max/mean
    load ratio against the incoming boundaries has exceeded
    ``imbalance_threshold`` for ``hysteresis`` consecutive cycles.  The
    hysteresis keeps a near-balanced network from thrashing boundaries
    (and recompiling nothing, but re-factoring p local Cholesky blocks)
    every cycle on noise.
    """

    n: int = 256                      # state dimension
    p: int = 4                        # subdomains (= processors), 1D and
                                      # kdtree (leaf count)
    ndim: int = 1                     # 1 = Interval1D, 2 = ShelfTiling2D
    domain_kind: Optional[str] = None  # "interval" | "shelf" | "kdtree";
                                      # None derives from ndim (1 ->
                                      # interval, 2 -> shelf).  "kdtree"
                                      # is a 2D adaptive k-d tree of p
                                      # leaves over the nx x ny mesh
                                      # (anisotropic networks)
    pr: int = 2                       # 2D: strip count
    pc: int = 2                       # 2D: cells per strip
    nx: Optional[int] = None          # 2D: mesh width (default: factor n)
    ny: Optional[int] = None          # 2D: mesh height
    overlap: int = 0                  # shared columns between neighbours
    mu: float = 1.0                   # overlap regularization
    iters: int = 120                  # DD-KF Schwarz iterations per cycle
    damping: float = 1.0              # additive-Schwarz under-relaxation
    rebalance: bool = True            # online DyDD on/off (off = static DD)
    imbalance_threshold: float = 1.5  # max/mean ratio that arms the trigger
    hysteresis: int = 1               # consecutive over-threshold cycles
    double_buffer: bool = True        # overlap t+1 packing with t's solve
    track_reference: bool = False     # per-cycle ||x - one_shot|| (O(n^3))
    seed: int = 0                     # truth trajectory + data noise
    smooth: float = 0.25              # H0 second-difference weight
    obs_noise: float = 1e-3           # observation data noise
    truth_drift: float = 0.05         # per-cycle truth random-walk scale
    solver: str = "vmapped"           # "vmapped" | "shardmap"
    comm: str = "allreduce"           # sharded overlap exchange:
                                      # "allreduce" (full n-vector) |
                                      # "neighbour" (halo-only ppermute)
    halo_weight: float = 0.0          # overlap-aware DyDD: work units per
                                      # halo column added to the loads the
                                      # diffusion schedule balances (0 =
                                      # unweighted, the historic policy)
    record_residuals: bool = False    # journal the per-iteration Schwarz
                                      # update-norm history (switches the
                                      # inner loop to lax.scan; identical
                                      # numerics, one extra (iters,)
                                      # output per solve)
    solver_kernel: str = "auto"       # local Schwarz step implementation:
                                      # "auto" (fused Pallas on TPU, jnp
                                      # elsewhere) | "jnp" | "fused" |
                                      # "fused_interpret" | "fused_ref"
    solve_retries: int = 2            # bounded retry on a TransientFault
                                      # from prepare/solve (exponential
                                      # backoff); exceeding it is fatal.
                                      # Retries are bitwise-safe: faults
                                      # fire before any state mutation
    time_windows: int = 1             # parallel-in-time (Parareal) window
                                      # count for repro.assim.timepar;
                                      # 1 = the sequential cycle loop
                                      # (bitwise-identical degeneration)
    pint_tol: float = 1e-8            # Parareal convergence tolerance on
                                      # the max window-boundary
                                      # correction (max-abs norm)
    pint_max_iters: int = 8           # Parareal iteration cap; 0 forces
                                      # the sequential engine (bitwise
                                      # degeneration, like time_windows=1)
    pint_coarse_iters: int = 0        # Schwarz iterations of the coarse
                                      # propagator; 0 = max(1, iters//10)
    pint_fine_iters: int = 0          # Schwarz iterations of the fine
                                      # sweeps; 0 = iters (cold-start
                                      # equivalent).  When set, fine
                                      # solves warm-start from the coarse
                                      # trajectory, so the combined
                                      # coarse+fine iteration count is
                                      # what buys the accuracy — the
                                      # work-optimal Parareal variant


def _resolve_mesh_shape(cfg: EngineConfig) -> tuple:
    """(nx, ny) of the 2D raster mesh from the config (factor n if only
    one or neither axis is given)."""
    nx, ny = cfg.nx, cfg.ny
    if nx is None and ny is None:
        return domain_mod.factor_mesh(cfg.n)
    if nx is None or ny is None:
        # One axis given: the other must complete cfg.n exactly.
        given = nx if nx is not None else ny
        if given < 1 or cfg.n % given:
            raise ValueError(
                f"mesh axis {given} does not divide n={cfg.n}; give "
                f"both nx and ny or a divisor of n")
        return (given, cfg.n // given) if nx is not None \
            else (cfg.n // given, given)
    return nx, ny


def _domain_from_config(cfg: EngineConfig) -> domain_mod.Domain:
    if cfg.ndim not in (1, 2):
        raise ValueError(f"ndim must be 1 or 2 (got {cfg.ndim})")
    kind = cfg.domain_kind
    if kind is None:
        kind = "interval" if cfg.ndim == 1 else "shelf"
    if kind == "interval":
        return domain_mod.Interval1D(n=cfg.n, p=cfg.p)
    if kind == "shelf":
        nx, ny = _resolve_mesh_shape(cfg)
        return domain_mod.ShelfTiling2D(nx=nx, ny=ny, pr=cfg.pr, pc=cfg.pc)
    if kind == "kdtree":
        nx, ny = _resolve_mesh_shape(cfg)
        return kdtree_mod.KDTreeDomain(nx=nx, ny=ny, p=cfg.p)
    raise ValueError(f"domain_kind must be 'interval', 'shelf' or "
                     f"'kdtree' (got {cfg.domain_kind!r})")


# Checkpoint-tree key prefix for the domain's boundary-state arrays.
_DOMAIN_PREFIX = "domain/"


@dataclasses.dataclass
class _Prepared:
    """Host-side work for one cycle, computable before cycle t-1 finishes."""

    cycle: int
    obs: np.ndarray
    packed_op: "ddkf_mod.PackedDD"
    H0: np.ndarray
    H1: np.ndarray
    y1: np.ndarray                # observation data (truth-driven)
    loads: np.ndarray             # post-repartition per-subdomain counts
    loads_before: np.ndarray      # counts against the incoming boundaries
    loads_weighted: np.ndarray    # loads + halo-cost offsets (the
                                  # overlap-aware schedule's view)
    imbalance_before: float
    repartitioned: bool
    migrated: int
    rounds: int
    pack_time: float
    halo: "dd_mod.HaloExchange | None"  # neighbour-exchange schedule of
                                        # the cycle's decomposition
    comm_bytes_per_cycle: float
    halo_fraction: float
    rebalance_suppressed: bool = False  # trigger armed but suppressed
                                        # (previous rebalance already
                                        # left these exact loads)
    phases: dict = dataclasses.field(default_factory=dict)
                                        # host-phase durations (count/
                                        # dydd/halo/pack/data); _run_cycle
                                        # adds solve before journalling
    comm_edge_bytes_per_cycle: dict = dataclasses.field(
        default_factory=dict)           # "i-j" -> per-cycle endpoint
                                        # bytes (neighbour-path pricing
                                        # of the halo geometry)
    comm_mvec_bytes_per_cycle: float = 0.0
    comm_mvec_axis_bytes_per_cycle: dict = dataclasses.field(
        default_factory=dict)           # mesh-axis name -> per-cycle
                                        # m-vector all-reduce bytes (torus
                                        # pricing: outer axes full-vector)
    window: int = -1                    # time-window id (parallel-in-time
                                        # runs); -1 on sequential cycles


@dataclasses.dataclass
class CycleStep:
    """One cycle of the engine's per-cycle state machine.

    ``run`` (and external drivers: the fleet runner, the Parareal
    window engine) advance a step through the three stages —
    :meth:`AssimilationEngine.prepare` fills ``prep``,
    :meth:`AssimilationEngine.solve_step` fills the solve outputs,
    :meth:`AssimilationEngine.finish_step` journals it — making the
    cycle lifecycle a first-class record instead of loop-local state.
    ``window`` tags which time window the cycle belongs to (-1 =
    sequential run) and rides through to the journal.
    """

    cycle: int
    obs: np.ndarray
    window: int = -1
    prep: Optional[_Prepared] = None
    analysis: Optional[jax.Array] = None
    background: Optional[np.ndarray] = None
    solve_time: float = 0.0
    hist: object = None
    device_times: list = dataclasses.field(default_factory=list)


class AssimilationEngine:
    """Multi-cycle DD-KF with online DyDD rebalancing on a Domain.

    Usage::

        cfg = EngineConfig(n=128, p=4, rebalance=True)
        eng = AssimilationEngine(cfg)
        journal = eng.run(streams.make_stream("drifting_swarm", 400, 6))

        cfg2d = EngineConfig(ndim=2, nx=16, ny=8, pr=2, pc=2)
        journal = AssimilationEngine(cfg2d).run_scenario(
            "rotating_swarm", m=400, cycles=6)

    The analysis of cycle t is carried as the background of cycle t+1
    (persistence forecast by default; pass ``forecast`` to override).
    ``eng.analysis`` holds the latest analysis state.
    """

    def __init__(self, config: EngineConfig,
                 forecast: Optional[Callable] = None,
                 mesh=None, mesh_axis=None,
                 domain: Optional[domain_mod.Domain] = None,
                 straggler_config: Optional[StragglerConfig] = None,
                 chaos: "chaos_mod.ChaosInjector | None" = None):
        self.cfg = config
        self.forecast = forecast or (lambda x: x)
        if config.solver not in ("vmapped", "shardmap"):
            raise ValueError(f"unknown solver {config.solver!r}")
        if config.comm not in ("allreduce", "neighbour"):
            raise ValueError(f"comm must be 'allreduce' or 'neighbour' "
                             f"(got {config.comm!r})")
        if config.solver_kernel not in ddkf_mod.SOLVER_KERNELS:
            raise ValueError(
                f"solver_kernel must be one of {ddkf_mod.SOLVER_KERNELS} "
                f"(got {config.solver_kernel!r})")
        if config.halo_weight < 0:
            raise ValueError(f"halo_weight is a per-halo-column work cost "
                             f"and must be >= 0 (got {config.halo_weight})")
        if config.overlap < 0:
            raise ValueError(
                f"overlap is a halo width and must be >= 0 "
                f"(got {config.overlap})")
        if config.hysteresis < 1:
            raise ValueError(
                f"hysteresis must be >= 1 (got {config.hysteresis}); "
                f"1 means fire as soon as the threshold is crossed")
        if config.imbalance_threshold < 1.0:
            raise ValueError(
                f"imbalance_threshold is a max/mean ratio and must be "
                f">= 1.0 (got {config.imbalance_threshold})")
        if config.time_windows < 1:
            raise ValueError(
                f"time_windows must be >= 1 (got {config.time_windows})")
        if (config.pint_max_iters < 0 or config.pint_coarse_iters < 0
                or config.pint_fine_iters < 0):
            raise ValueError(
                f"pint_max_iters/pint_coarse_iters/pint_fine_iters must "
                f"be >= 0 (got {config.pint_max_iters}/"
                f"{config.pint_coarse_iters}/{config.pint_fine_iters})")
        if config.pint_tol <= 0:
            raise ValueError(
                f"pint_tol must be > 0 (got {config.pint_tol})")

        self.domain = domain if domain is not None \
            else _domain_from_config(config)
        self.n = self.domain.n
        self.p = self.domain.p
        self.mesh, self.mesh_axis = self._resolve_mesh(mesh, mesh_axis)
        self.journal = Journal(meta=self.domain.describe())
        self.analysis: Optional[jax.Array] = None
        self._H0 = cls_mod.state_operator(self.n, smooth=config.smooth)
        self._rng = np.random.default_rng(config.seed)
        self._truth = self._rng.normal(size=self.n)
        self._streak = 0  # consecutive over-threshold cycles
        self._last_rebalance_loads: Optional[np.ndarray] = None
        self._suppressed = False  # this cycle's trigger was suppressed
        self._dec_cache: Optional[dd_mod.Decomposition] = None
        self._t_last = time.perf_counter()
        # One EWMA-deadline straggler monitor per subdomain device; the
        # shardmap path feeds each its shard-ready time, the vmapped path
        # feeds monitor 0 the whole-solve time (one logical device).
        self._stragglers = [StragglerMonitor(straggler_config)
                            for _ in range(self.p)]
        self._straggler_config = straggler_config
        self._chaos = chaos
        # The stream being consumed, when it exposes a serializable
        # cursor (streams.ResumableStream) — what snapshot() records so
        # resume can fast-forward the seeded generator.
        self._stream = None
        self._restored_cursor: Optional[dict] = None
        # Optional per-cycle analysis hook: called as
        # ``on_analysis(cycle, x)`` from complete_cycle right after the
        # analysis is published — how parity tests and the Parareal
        # gate capture the sequential analysis chain without journalling
        # (n,) vectors.
        self.on_analysis: Optional[Callable] = None

    # -- mesh resolution for the sharded solver ----------------------------

    def _resolve_mesh(self, mesh, mesh_axis):
        """Validate or build the device mesh for ``solver='shardmap'``.

        The solver needs one device per subdomain, laid out as the
        domain's processor graph (``domain.mesh_axes()``: a (p,) chain in
        1D, a (pr, pc) grid in 2D).  A mismatched device count is
        rejected here, up front, with the fix spelled out — downstream it
        would only surface as an opaque shard_map shape error.
        """
        if self.cfg.solver != "shardmap":
            return mesh, mesh_axis
        names, shape = self.domain.mesh_axes()
        if mesh is None:
            n_dev = len(jax.devices())
            if n_dev != self.p:
                raise ValueError(
                    f"solver='shardmap' requires a mesh with one device "
                    f"per subdomain: p={self.p} but {n_dev} JAX device(s) "
                    f"are visible.  Pass mesh= explicitly, or set "
                    f"XLA_FLAGS=--xla_force_host_platform_device_count="
                    f"{self.p} to fan a host platform out, or match the "
                    f"config's p/pr*pc to the hardware")
            mesh = compat_mod.make_device_mesh(shape, names)
            return mesh, (names if len(names) > 1 else names[0])
        n_mesh = int(np.prod(list(mesh.shape.values())))
        if n_mesh != self.p:
            raise ValueError(
                f"solver='shardmap' requires a mesh with one device per "
                f"subdomain: p={self.p} but the given mesh has {n_mesh} "
                f"device(s) (shape {dict(mesh.shape)}).  Rebuild the mesh "
                f"to match, or change p/pr/pc")
        if mesh_axis is None:
            axes = tuple(mesh.shape.keys())
            mesh_axis = axes if len(axes) > 1 else axes[0]
        return mesh, mesh_axis

    @property
    def boundaries(self):
        """1D compatibility view of the domain's interval edges."""
        return getattr(self.domain, "boundaries", None)

    # -- rebalance trigger policy ------------------------------------------

    def _should_rebalance(self, loads: np.ndarray) -> bool:
        self._suppressed = False
        if not self.cfg.rebalance:
            self._streak = 0
            return False
        fire = False
        if (loads == 0).any():
            # Empty subdomain: the DD step cannot wait out the hysteresis.
            self._streak = 0
            fire = True
        else:
            if imbalance_ratio(loads) > self.cfg.imbalance_threshold:
                self._streak += 1
            else:
                self._streak = 0
            if self._streak >= self.cfg.hysteresis:
                self._streak = 0
                fire = True
        if fire and self._last_rebalance_loads is not None \
                and np.array_equal(loads, self._last_rebalance_loads):
            # The last rebalance already left exactly these loads:
            # re-firing would schedule the same targets again, so a
            # genuinely unpopulatable subdomain (e.g. fewer observations
            # than subdomains) would otherwise re-trigger the empty-DD
            # step every cycle — suppress, and journal the suppression.
            # On Interval1D this is exact (migration realizes targets
            # from loads alone); on position-dependent domains (kdtree
            # median cuts) a stream whose positions moved while the
            # count vector stayed identical keeps the previous cuts one
            # extra cycle — the deliberate trade against trigger thrash
            # (any count change lifts the suppression).
            self._suppressed = True
            return False
        return fire

    # -- host-side cycle preparation (runs on the worker thread) -----------

    def _current_dec(self) -> dd_mod.Decomposition:
        """The decomposition of the *current* boundaries, cached across
        cycles and invalidated only by a rebalance (the engine is the
        sole mutator of its domain's boundary state).  Reusing one
        Decomposition object is what lets its ``cached_property`` halo
        schedule actually hit — the O(n·mult²) edge discovery and the
        colouring/slot-map build would otherwise re-run every cycle and
        be charged to ``pack_time``."""
        if self._dec_cache is None:
            self._dec_cache = self.domain.decomposition(
                overlap=self.cfg.overlap)
        return self._dec_cache

    def _halo_offsets(self) -> np.ndarray | None:
        """Per-subdomain halo-cost offsets for the overlap-aware DyDD
        weighting, from the *current* boundaries (the decomposition the
        rebalance decision is looking at) — None when the weighting is
        off or there is no overlap to weigh."""
        if self.cfg.halo_weight <= 0 or self.cfg.overlap <= 0:
            return None
        return self.cfg.halo_weight * self._current_dec().halo_sizes

    def prepare(self, cycle: int, obs: np.ndarray,
                window: int = -1) -> _Prepared:
        """Host-side work for one cycle: DyDD decision, repartition,
        operator packing, observation data.  Depends only on the stream
        and boundary state — never on a solve result — so it may run on
        a worker thread while the device solves an earlier cycle (or,
        for the parallel-in-time engine, for *every* cycle of the stream
        up front: the mutation chain is identical to the sequential
        sweep's, whatever backgrounds later flow into the solves).  The
        engine mutates its domain/truth/rng state here, so at most one
        ``prepare`` per engine may be in flight at a time (the serving
        layer's packing pool enforces this per stream).  ``window`` tags
        the resulting cycle record with a time-window id."""
        # Fault injection sits BEFORE any state mutation: a retried
        # prepare after a TransientFault starts from identical rng/
        # domain/truth state, so the retry is bitwise-equivalent to an
        # uninjected run.
        if self._chaos is not None:
            self._chaos.check("pack", cycle)
        t0 = time.perf_counter()
        cfg = self.cfg
        obs = np.asarray(obs, dtype=np.float64)
        phases: dict = {}

        with _phase(phases, "count", cycle=cycle):
            loads_in = self.domain.counts(obs)
            imb_before = imbalance_ratio(loads_in)
            fire = self._should_rebalance(loads_in)
        repartitioned, migrated, rounds = False, 0, 0
        if fire:
            with _phase(phases, "dydd", cycle=cycle):
                info = self.domain.rebalance(
                    obs, cost_offsets=self._halo_offsets())
            repartitioned = True
            migrated = info.migrated
            rounds = info.rounds
            self._dec_cache = None   # boundaries moved
        suppressed = self._suppressed
        loads = self.domain.counts(obs)
        if repartitioned:
            self._last_rebalance_loads = np.asarray(loads).copy()

        with _phase(phases, "halo", cycle=cycle):
            dec = self._current_dec()
            # Weighted loads: what the overlap-aware schedule balances
            # (the plain counts when halo_weight is 0).
            loads_weighted = loads + np.rint(
                cfg.halo_weight * dec.halo_sizes).astype(np.int64)
            # Neighbour-exchange schedule (cached on the Decomposition;
            # empty edge set when there is no overlap) — the comm model
            # prices the neighbour path even when the solve runs
            # allreduce/vmapped.
            halo = dec.halo_exchange
        with _phase(phases, "pack", cycle=cycle, p=self.p):
            H1 = cls_mod.observation_operator(
                self.n, self.domain.obs_positions(obs),
                block=self.domain.row_size)
            A = np.concatenate([self._H0, H1], axis=0)
            r = np.ones((A.shape[0],))
            packed_op = ddkf_mod.pack_operator(
                jnp.asarray(A), jnp.asarray(r), dec, mu=cfg.mu,
                solver_kernel=cfg.solver_kernel)
            # The batched factor build runs on device; block here (still
            # on the worker thread under double buffering) so pack_time
            # is honest.
            jax.block_until_ready(packed_op.L_loc)

        with _phase(phases, "data", cycle=cycle):
            # Truth-driven observation data: the truth random-walks each
            # cycle (deterministic under cfg.seed, independent of any
            # solve result — which is what makes this whole method
            # pipelineable).
            self._truth = ((1.0 - cfg.truth_drift) * self._truth
                           + cfg.truth_drift * self._rng.normal(
                               size=self.n))
            y1 = H1 @ self._truth + cfg.obs_noise * self._rng.normal(
                size=H1.shape[0])

        # Modelled per-cycle communication volume for the configured
        # state-exchange path (with no overlap the neighbour path moves
        # no state bytes at all — only the m-vector all-reduce remains).
        axis_names, axis_shape = self.domain.mesh_axes()
        stats = packed_op.comm_stats(halo=halo, comm=cfg.comm,
                                     mesh_shape=axis_shape)
        comm_bytes = stats["bytes_per_iter_total"] * cfg.iters
        # Per-edge bytes are always the neighbour-path pricing (the
        # allreduce path has no per-edge structure to report) — like
        # comm_bytes on a vmapped run, a model of what the halo geometry
        # would move, journalled for every comm config.
        edge_bytes = {k: float(v) * cfg.iters
                      for k, v in packed_op.edge_send_bytes(halo).items()}
        mvec_bytes = (stats["mvec_bytes_per_device"] * self.p * cfg.iters)
        # Per-torus-axis m-vector all-reduce split (outer axes pay plain
        # full-vector psum hops; only the innermost rides the
        # reduce-scatter pricing) — journalled so roofline --solve can
        # attribute the collective term by mesh axis.
        mvec_axis_bytes = {
            name: float(v) * self.p * cfg.iters
            for name, v in zip(axis_names,
                               stats["mvec_bytes_per_device_per_axis"])}

        return _Prepared(cycle=cycle, obs=obs, packed_op=packed_op,
                         H0=self._H0, H1=H1, y1=y1, loads=loads,
                         loads_before=loads_in,
                         loads_weighted=loads_weighted,
                         imbalance_before=imb_before,
                         repartitioned=repartitioned, migrated=migrated,
                         rounds=rounds,
                         pack_time=time.perf_counter() - t0,
                         halo=halo,
                         comm_bytes_per_cycle=float(comm_bytes),
                         halo_fraction=dec.halo_fraction,
                         rebalance_suppressed=suppressed,
                         phases=phases,
                         comm_edge_bytes_per_cycle=edge_bytes,
                         comm_mvec_bytes_per_cycle=float(mvec_bytes),
                         comm_mvec_axis_bytes_per_cycle=mvec_axis_bytes,
                         window=window)

    # -- device-side solve (main thread) -----------------------------------

    def solve_input(self, prep: _Prepared):
        """(rhs-injected packing, background) for a prepared cycle.

        This is the only step that consumes the carried analysis, so it
        must run *after* the previous cycle's :meth:`complete_cycle` (the
        fleet runner calls it on the main thread just before batching the
        cohort; ``run`` reaches it through :meth:`_solve`)."""
        background = (np.zeros(self.n) if self.analysis is None
                      else np.asarray(self.forecast(self.analysis)))
        y0 = prep.H0 @ background
        packed = ddkf_mod.with_rhs(prep.packed_op,
                                   np.concatenate([y0, prep.y1]))
        return packed, background

    def _solve(self, prep: _Prepared):
        """Returns (analysis, background, residual_hist, device_times).

        ``residual_hist`` is the per-iteration Schwarz update-norm array
        (None unless ``record_residuals``); ``device_times`` is the
        per-device time-to-shard-ready since dispatch on the shardmap
        path (empty for vmapped — the caller substitutes the whole-solve
        time for the single logical device).  Shard-ready times are
        observed by blocking the addressable shards in subdomain order,
        so device i's figure is an upper bound that includes any wait on
        devices 0..i-1 the host blocked on first — ordering-biased, but
        a genuine per-device completion signal on a forced-multi-device
        host platform, and exactly what the straggler monitor needs
        (a straggler's shard-ready time is late under any ordering).
        """
        cfg = self.cfg
        # The solve mutates no engine state until complete_cycle, so a
        # fault raised here leaves the cycle cleanly retryable.
        if self._chaos is not None:
            self._chaos.check("solve", prep.cycle)
        packed, background = self.solve_input(prep)
        hist = None
        device_times: list = []
        with trace_mod.span("solve", cycle=prep.cycle,
                            solver=cfg.solver) as sp:
            t0 = time.perf_counter()
            if cfg.solver == "shardmap":
                out = ddkf_mod.solve_shardmap(
                    packed, self.mesh, axis=self.mesh_axis,
                    iters=cfg.iters, damping=cfg.damping,
                    comm=cfg.comm, halo=prep.halo,
                    residual_history=cfg.record_residuals,
                    return_per_device=True)
                x_pd = out[0] if cfg.record_residuals else out
                if cfg.record_residuals:
                    hist = out[1]
                shards = sorted(x_pd.addressable_shards,
                                key=lambda s: s.index[0].start or 0)
                for sh in shards:
                    sh.data.block_until_ready()
                    dt = time.perf_counter() - t0
                    device_times.append(dt)
                    trace_mod.emit(
                        "solve", t0, dt,
                        track=f"device {sh.index[0].start or 0}",
                        cycle=prep.cycle)
                x = x_pd[0]
            else:
                out = ddkf_mod.solve_vmapped(
                    packed, iters=cfg.iters, damping=cfg.damping,
                    residual_history=cfg.record_residuals)
                x = out[0] if cfg.record_residuals else out
                if cfg.record_residuals:
                    hist = out[1]
            sp.fence(x)
        return x, background, hist, device_times

    def _reference_error(self, prep: _Prepared, background: np.ndarray,
                         x: jax.Array) -> float:
        """||x_engine - x_one_shot|| for the cycle's CLS problem."""
        dtype = prep.packed_op.A_loc.dtype
        prob = cls_mod.CLSProblem(
            H0=jnp.asarray(prep.H0, dtype),
            y0=jnp.asarray(prep.H0 @ background, dtype),
            H1=jnp.asarray(prep.H1, dtype),
            y1=jnp.asarray(prep.y1, dtype),
            R0=jnp.ones((prep.H0.shape[0],), dtype),
            R1=jnp.ones((prep.H1.shape[0],), dtype))
        return float(jnp.linalg.norm(x - cls_mod.solve(prob)))

    # -- driver -------------------------------------------------------------

    def run(self, stream: Iterable[np.ndarray], *,
            checkpoint_dir: str | None = None,
            snapshot_every: int = 0) -> Journal:
        """Consume the stream to exhaustion; returns the journal.

        Resume-aware: cycle numbering continues from the journal (a
        restored engine picks up at ``len(journal)``), and when the
        stream exposes a ``cursor`` (:class:`streams.ResumableStream`)
        it is recorded for :meth:`snapshot`.  With ``checkpoint_dir``
        and ``snapshot_every=k``, an atomic engine checkpoint is saved
        every k completed cycles — on those cycles the next cycle's
        prepare (which mutates rng/domain/truth state) is *deferred*
        until the snapshot is taken, so the saved state is exactly the
        cycle boundary and resume is bitwise journal-continuing.
        """
        cfg = self.cfg
        self._stream = stream if hasattr(stream, "cursor") else None
        it = iter(stream)
        base = len(self.journal.records)
        self._t_last = time.perf_counter()

        def snap_due(cycle: int) -> bool:
            return (checkpoint_dir is not None and snapshot_every > 0
                    and (cycle + 1) % snapshot_every == 0)

        def finish(step: "CycleStep") -> None:
            self.finish_step(self.solve_step(step))
            if snap_due(step.cycle):
                self.save_checkpoint(checkpoint_dir, step=step.cycle + 1)
            if self._chaos is not None:
                # After the snapshot: a kill at cycle c resumes from a
                # checkpoint no newer than c+1, never a torn mid-cycle.
                self._chaos.maybe_kill("cycle_end", step.cycle)

        if not cfg.double_buffer:
            for i, obs in enumerate(it):
                step = CycleStep(cycle=base + i, obs=obs)
                step.prep = chaos_mod.retry_transient(
                    lambda: self.prepare(step.cycle, step.obs),
                    retries=max(cfg.solve_retries, 0),
                    site="pack", cycle=step.cycle)
                finish(step)
            return self.journal

        # Double-buffered: prepare cycle t+1 on the worker while the main
        # thread solves cycle t.  _prepare mutates boundary/truth state, so
        # exactly one prepare is in flight at a time (single worker, next
        # submit only after the previous result is claimed).
        # thread_name_prefix names the worker's trace track: packing
        # spans land on a "pack_0" row next to the main solve thread.
        with ThreadPoolExecutor(max_workers=1,
                                thread_name_prefix="pack") as pool:
            try:
                first = next(it)
            except StopIteration:
                return self.journal
            step = CycleStep(cycle=base, obs=first)
            fut = pool.submit(self.prepare, step.cycle, step.obs)
            cycle = base
            while fut is not None:
                step.prep = self._claim_prepare(fut, pool, step.cycle,
                                                step.obs)
                cur = step
                cycle += 1
                fut = None

                def submit_next():
                    nonlocal fut, step
                    nxt = next(it, None)
                    if nxt is not None:
                        step = CycleStep(cycle=cycle, obs=nxt)
                        fut = pool.submit(self.prepare, step.cycle,
                                          step.obs)

                if snap_due(cur.cycle):
                    # Snapshot cycle: do NOT pipeline — the next prepare
                    # would mutate rng/domain/truth before the save, and
                    # the checkpoint would no longer be a cycle boundary.
                    finish(cur)
                    submit_next()
                else:
                    submit_next()
                    finish(cur)
        return self.journal

    def _claim_prepare(self, fut, pool, cycle: int, obs):
        """Claim an in-flight prepare, retrying TransientFaults with
        exponential backoff by resubmitting the same (cycle, obs) — safe
        because injected pack faults fire before any state mutation."""
        retries = max(self.cfg.solve_retries, 0)
        for attempt in range(retries + 1):
            try:
                return fut.result()
            except chaos_mod.TransientFault:
                if attempt >= retries:
                    raise
                m = meters_mod.get_meters()
                m.event("chaos.retry", site="pack", cycle=int(cycle),
                        attempt=attempt + 1)
                m.inc("chaos.retries")
                time.sleep(0.05 * (2.0 ** attempt))
                fut = pool.submit(self.prepare, cycle, obs)

    def run_scenario(self, name: str, m: int, cycles: int,
                     seed: int = 0, **kw) -> Journal:
        """Convenience: run a registered stream scenario end to end."""
        spec = streams_mod.get(name)
        if spec.ndim != self.domain.ndim:
            raise ValueError(
                f"scenario {name!r} is {spec.ndim}D but the engine domain "
                f"is {self.domain.ndim}D")
        return self.run(streams_mod.make_stream(name, m, cycles,
                                                seed=seed, **kw))

    def solve_step(self, step: CycleStep) -> CycleStep:
        """Stage 2 of the cycle state machine: drive a prepared step
        through the device solve (bounded TransientFault retries; wall
        time measured to analysis-ready)."""
        t0 = time.perf_counter()
        x, background, hist, device_times = chaos_mod.retry_transient(
            lambda: self._solve(step.prep),
            retries=max(self.cfg.solve_retries, 0),
            site="solve", cycle=step.prep.cycle)
        step.analysis = jax.block_until_ready(x)
        step.background = background
        step.hist = hist
        step.device_times = device_times
        step.solve_time = time.perf_counter() - t0
        return step

    def finish_step(self, step: CycleStep) -> CycleStep:
        """Stage 3: journal the solved step and publish its analysis."""
        self.complete_cycle(step.prep, step.analysis, step.background,
                            solve_time=step.solve_time, hist=step.hist,
                            device_times=step.device_times)
        return step

    def _run_cycle(self, prep: _Prepared) -> None:
        step = CycleStep(cycle=prep.cycle, obs=prep.obs,
                         window=prep.window, prep=prep)
        self.finish_step(self.solve_step(step))

    def reset_clock(self) -> None:
        """Restart the per-cycle wall-clock reference (``cycle_time`` of
        the next completed cycle is measured from now) — what ``run``
        does at stream start, exposed for external drivers admitting an
        engine mid-flight."""
        self._t_last = time.perf_counter()

    def complete_cycle(self, prep: _Prepared, x, background,
                       solve_time: float, hist=None,
                       device_times=None) -> None:
        """Journal a solved cycle and carry its analysis forward.

        The reentrant tail of the cycle: callers that dispatch the solve
        themselves (the fleet runner batches many engines' cycles into
        one device program) hand the analysis back here with the solve
        wall time they measured; ``run`` reaches it through
        :meth:`_run_cycle`.  Must be called in cycle order per engine —
        it consumes ``prep`` and publishes ``self.analysis`` for the next
        cycle's :meth:`solve_input`."""
        device_times = list(device_times) if device_times else []
        x = jax.block_until_ready(x)
        now = time.perf_counter()
        # Measured wall time since the previous cycle completed — with
        # double buffering this is what the pipelining actually buys
        # (~max(pack, solve), not their sum).
        cycle_time = now - self._t_last
        t_cycle0 = self._t_last
        self._t_last = now
        self.analysis = x
        if self.on_analysis is not None:
            self.on_analysis(prep.cycle, x)

        # The cycle span covers the measured wall-clock by construction
        # (emitted after the fact from the same timestamps cycle_time is
        # computed from) — the acceptance coverage metric reads these.
        trace_mod.emit("cycle", t_cycle0, cycle_time, cycle=prep.cycle)

        # Straggler detection: per-device shard-ready times on the
        # shardmap path; the vmapped solve is one logical device.
        if not device_times:
            device_times = [solve_time]
        if self._chaos is not None:
            # Forced straggler: inflate the scheduled device's *reported*
            # time — the solve already happened, analyses stay bitwise.
            device_times = self._chaos.straggle(prep.cycle, device_times)
        flags = [i for i, dt in enumerate(device_times)
                 if self._stragglers[i].record(dt)]

        residual_history = ([] if hist is None
                            else [float(v) for v in np.asarray(hist)])
        phases = dict(prep.phases)
        phases["solve"] = solve_time

        m = meters_mod.get_meters()
        m.inc("engine.cycles")
        if prep.repartitioned:
            m.inc("engine.rebalance.fired")
        if prep.rebalance_suppressed:
            m.inc("engine.rebalance.suppressed")
        if prep.migrated:
            m.inc("engine.migrated", prep.migrated)
        m.observe("engine.imbalance", imbalance_ratio(prep.loads))
        m.observe("engine.halo_fraction", prep.halo_fraction)
        m.inc("solve.comm_bytes_per_cycle", prep.comm_bytes_per_cycle)
        if residual_history:
            m.observe("engine.residual_final", residual_history[-1])
        if flags:
            m.inc("engine.straggler.flags", len(flags))
            m.event("engine.straggler", cycle=prep.cycle, devices=flags,
                    device_times=[float(t) for t in device_times])

        err = (self._reference_error(prep, background, x)
               if self.cfg.track_reference else float("nan"))
        self.journal.append(CycleMetrics(
            cycle=prep.cycle,
            loads=[int(v) for v in prep.loads],
            loads_before=[int(v) for v in prep.loads_before],
            imbalance=imbalance_ratio(prep.loads),
            imbalance_before=prep.imbalance_before,
            efficiency=dydd_mod.balance_ratio(prep.loads),
            repartitioned=prep.repartitioned,
            migrated=prep.migrated,
            rounds=prep.rounds,
            pack_time=prep.pack_time,
            solve_time=solve_time,
            cycle_time=cycle_time,
            error_vs_direct=err,
            comm_bytes_per_cycle=prep.comm_bytes_per_cycle,
            halo_fraction=prep.halo_fraction,
            loads_weighted=[int(v) for v in prep.loads_weighted],
            rebalance_suppressed=prep.rebalance_suppressed,
            phases=phases,
            residual_history=residual_history,
            comm_edge_bytes_per_cycle=prep.comm_edge_bytes_per_cycle,
            comm_mvec_bytes_per_cycle=prep.comm_mvec_bytes_per_cycle,
            comm_mvec_axis_bytes_per_cycle=(
                prep.comm_mvec_axis_bytes_per_cycle),
            device_solve_times=[float(t) for t in device_times],
            straggler_flags=flags,
            window=prep.window))

    # -- checkpoint / resume ------------------------------------------------

    # v2 adds nothing mandatory over v1 — it marks snapshots that may
    # carry the optional "pint" metadata entry (window id + window count
    # of a parallel-in-time window-boundary save) and may be assembled
    # from a stashed host_state().  restore() accepts both versions.
    SNAPSHOT_VERSION = 2
    _SNAPSHOT_VERSIONS = (1, 2)

    def host_state(self) -> dict:
        """Deep copy of the host-side mutable state ``prepare`` advances
        (truth, rng, domain boundary state, trigger state, stream
        cursor) at the current point of the prepare sweep.

        The parallel-in-time engine prepares *every* cycle up front, so
        a window boundary's host state is long gone by the time the
        window's analyses exist — it stashes this at each boundary
        during the sweep and hands it back to :meth:`snapshot` when the
        completion phase reaches the boundary."""
        cursor = self._stream.cursor if self._stream is not None else None
        return {
            "truth": np.asarray(self._truth, np.float64).copy(),
            "rng_state": copy.deepcopy(self._rng.bit_generator.state),
            "domain": {k: np.asarray(v).copy()
                       for k, v in self.domain.state_dict().items()},
            "streak": int(self._streak),
            "last_rebalance_loads": (
                None if self._last_rebalance_loads is None
                else np.asarray(self._last_rebalance_loads).copy()),
            "cursor": copy.deepcopy(cursor),
        }

    def snapshot(self, host_state: dict | None = None,
                 extra_meta: dict | None = None) -> tuple:
        """(tree, metadata) capturing everything resume needs.

        Must be taken at a cycle boundary with no prepare in flight
        (``run`` defers the pipelined next-prepare around snapshot
        cycles).  The tree holds the array state (truth, carried
        analysis, domain boundary state); the metadata holds the
        JSON-side state: config, rng bit-generator state (exact — resume
        re-draws the same truth walk and data noise), journal, stream
        cursor, straggler EWMAs and the gram/schwarz autotune caches.

        ``host_state`` substitutes a stashed :meth:`host_state` capture
        for the live truth/rng/domain/trigger/cursor state — the
        parallel-in-time engine's window-boundary snapshots, where the
        prepare sweep has already advanced past the boundary while the
        analysis/journal side (taken live) is exactly at it.
        ``extra_meta`` merges extra JSON entries into the metadata
        (e.g. the ``"pint"`` window descriptor).
        """
        hs = host_state
        truth = (self._truth if hs is None else hs["truth"])
        domain_sd = (self.domain.state_dict() if hs is None
                     else hs["domain"])
        last_loads = (self._last_rebalance_loads if hs is None
                      else hs["last_rebalance_loads"])
        tree: dict = {"truth": np.asarray(truth, np.float64)}
        if self.analysis is not None:
            tree["analysis"] = np.asarray(jax.device_get(self.analysis))
        if last_loads is not None:
            tree["last_rebalance_loads"] = np.asarray(last_loads)
        for k, v in domain_sd.items():
            tree[_DOMAIN_PREFIX + k] = np.asarray(v)
        cursor = (self._stream.cursor
                  if self._stream is not None else None) \
            if hs is None else hs["cursor"]
        metadata = {
            "snapshot_version": self.SNAPSHOT_VERSION,
            "config": dataclasses.asdict(self.cfg),
            "domain": self.domain.describe(),
            "rng_state": (self._rng.bit_generator.state if hs is None
                          else hs["rng_state"]),
            "streak": int(self._streak if hs is None else hs["streak"]),
            "journal": self.journal.to_dict(),
            "cursor": cursor,
            "stragglers": [s.state_dict() for s in self._stragglers],
            "autotune": ops_mod.export_tune_caches(),
        }
        if extra_meta:
            metadata.update(extra_meta)
        return tree, metadata

    def save_checkpoint(self, directory: str, step: int,
                        host_state: dict | None = None,
                        extra_meta: dict | None = None) -> str:
        """Atomic engine checkpoint via the hash-verified manager
        primitives; ``step`` is the completed-cycle count.  Returns the
        final checkpoint path."""
        tree, metadata = self.snapshot(host_state=host_state,
                                       extra_meta=extra_meta)
        t0 = time.perf_counter()
        path = ckpt_mod.save_pytree(tree, directory, step, metadata)
        m = meters_mod.get_meters()
        m.inc("engine.snapshots")
        m.observe("engine.snapshot_time", time.perf_counter() - t0)
        return path

    @classmethod
    def restore(cls, checkpoint: str, *,
                config: "EngineConfig | None" = None,
                domain: Optional[domain_mod.Domain] = None,
                mesh=None, mesh_axis=None,
                forecast: Optional[Callable] = None,
                straggler_config: Optional[StragglerConfig] = None,
                chaos: "chaos_mod.ChaosInjector | None" = None
                ) -> "AssimilationEngine":
        """Rebuild an engine from a checkpoint directory (latest verified
        step) or a specific ``step_XXXX`` path.

        Same-shape resume (``config``/``domain`` omitted) restores the
        exact saved state and is bitwise journal-continuing.  Passing a
        ``config`` and ``domain`` overrides them for an *elastic* resume
        under a different p — the saved domain state is then not loaded
        (the caller, :func:`repro.runtime.elastic.remesh_assim_domain`,
        derives the new tiling) while truth/rng/analysis/journal carry
        over, so the stream still continues without replaying cycles.
        """
        flat, manifest = ckpt_mod.restore_pytree(checkpoint)
        meta = manifest["metadata"]
        ver = meta.get("snapshot_version")
        if ver not in cls._SNAPSHOT_VERSIONS:
            raise ValueError(f"unsupported engine snapshot version {ver}")
        cfg = config if config is not None \
            else EngineConfig(**meta["config"])
        eng = cls(cfg, forecast=forecast, mesh=mesh, mesh_axis=mesh_axis,
                  domain=domain, straggler_config=straggler_config,
                  chaos=chaos)
        eng._load_snapshot(flat, meta, remeshed=domain is not None)
        return eng

    def _load_snapshot(self, flat: dict, meta: dict,
                       remeshed: bool = False) -> None:
        self._truth = np.asarray(flat["truth"], np.float64)
        if "analysis" in flat:
            self.analysis = jnp.asarray(flat["analysis"])
        # Exact generator state, not a reseed: the resumed run draws the
        # same truth steps and data noise the uninterrupted run would.
        self._rng.bit_generator.state = meta["rng_state"]
        journal = Journal.from_dict(meta["journal"])
        resume_log = list(journal.meta.get("resume", []))
        resume_log.append({"at_cycle": len(journal.records),
                           "p": int(self.p), "remeshed": bool(remeshed)})
        if remeshed:
            # New tiling: domain state stays as the caller derived it,
            # trigger/straggler state is stale for the new p — start
            # those fresh.  The journal meta switches to the new
            # descriptor so downstream load_table reshapes correctly.
            journal.meta = self.domain.describe()
        else:
            self.domain.load_state(
                {k.split(_DOMAIN_PREFIX, 1)[1]: v
                 for k, v in flat.items()
                 if k.startswith(_DOMAIN_PREFIX)})
            self._streak = int(meta.get("streak", 0))
            if "last_rebalance_loads" in flat:
                self._last_rebalance_loads = np.asarray(
                    flat["last_rebalance_loads"])
            for mon, st in zip(self._stragglers,
                               meta.get("stragglers", [])):
                mon.load_state(st)
        journal.meta["resume"] = resume_log
        self.journal = journal
        self._dec_cache = None
        self._restored_cursor = meta.get("cursor")
        ops_mod.import_tune_caches(meta.get("autotune"))

    def resume_stream(self) -> "streams_mod.ResumableStream | None":
        """The stream continuation from the restored cursor (None when
        the snapshot was taken without a cursor-bearing stream)."""
        cursor = self._restored_cursor
        if cursor is None:
            return None
        return streams_mod.ResumableStream.from_cursor(cursor)
