"""Streaming multi-cycle DD-KF assimilation engine with online DyDD.

The engine consumes an observation stream cycle by cycle and, per cycle:

  1. counts the incoming observations against the *current* subdomain
     boundaries and decides — threshold + hysteresis, see
     :class:`EngineConfig` — whether to fire a DyDD repartition
     (``dydd_1d``: DD-step for empty subdomains, Hu–Blake–Emerson
     diffusion scheduling, geometric boundary migration);
  2. decomposes the state index set on the (possibly moved) boundaries and
     packs the local operator blocks + Cholesky factors
     (``ddkf.pack_operator`` — the expensive host-side work);
  3. injects the cycle's right-hand side (background carried forward from
     the previous analysis + fresh observation data) and runs the sharded
     DD-KF solve (``ddkf.solve_vmapped`` / ``solve_shardmap``);
  4. journals loads, imbalance, migration volume and timings
     (:mod:`repro.assim.metrics`).

Pipelining: with ``double_buffer=True`` step 1+2 for cycle t+1 run on a
host worker thread while the device solves cycle t.  This is sound
because the rebalance decision and the operator packing depend only on
the observation stream and the boundary state — never on a solve result;
only the rhs (step 3) consumes the carried analysis, and it is injected
on the main thread via a cheap ``dataclasses.replace``.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Iterable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cls as cls_mod
from repro.core import dd as dd_mod
from repro.core import ddkf as ddkf_mod
from repro.core import dydd as dydd_mod
from repro.assim import streams as streams_mod
from repro.assim.metrics import CycleMetrics, Journal, imbalance_ratio


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Streaming DD-KF engine configuration.

    Rebalance trigger policy: a repartition fires at the start of a cycle
    when EITHER (a) some subdomain would receive zero observations (the
    DD-step must split a neighbour — never deferred), or (b) the max/mean
    load ratio against the incoming boundaries has exceeded
    ``imbalance_threshold`` for ``hysteresis`` consecutive cycles.  The
    hysteresis keeps a near-balanced network from thrashing boundaries
    (and recompiling nothing, but re-factoring p local Cholesky blocks)
    every cycle on noise.
    """

    n: int = 256                      # state dimension
    p: int = 4                        # subdomains (= processors)
    overlap: int = 0                  # shared columns between neighbours
    mu: float = 1.0                   # overlap regularization
    iters: int = 120                  # DD-KF Schwarz iterations per cycle
    damping: float = 1.0              # additive-Schwarz under-relaxation
    rebalance: bool = True            # online DyDD on/off (off = static DD)
    imbalance_threshold: float = 1.5  # max/mean ratio that arms the trigger
    hysteresis: int = 1               # consecutive over-threshold cycles
    double_buffer: bool = True        # overlap t+1 packing with t's solve
    track_reference: bool = False     # per-cycle ||x - one_shot|| (O(n^3))
    seed: int = 0                     # truth trajectory + data noise
    smooth: float = 0.25              # H0 second-difference weight
    obs_noise: float = 1e-3           # observation data noise
    truth_drift: float = 0.05         # per-cycle truth random-walk scale
    solver: str = "vmapped"           # "vmapped" | "shardmap"


@dataclasses.dataclass
class _Prepared:
    """Host-side work for one cycle, computable before cycle t-1 finishes."""

    cycle: int
    obs: np.ndarray
    packed_op: "ddkf_mod.PackedDD"
    H0: np.ndarray
    H1: np.ndarray
    y1: np.ndarray                # observation data (truth-driven)
    loads: np.ndarray             # post-repartition per-subdomain counts
    imbalance_before: float
    repartitioned: bool
    migrated: int
    rounds: int
    pack_time: float


class AssimilationEngine:
    """Multi-cycle DD-KF with online DyDD rebalancing.

    Usage::

        cfg = EngineConfig(n=128, p=4, rebalance=True)
        eng = AssimilationEngine(cfg)
        journal = eng.run(streams.make_stream("drifting_swarm", 400, 6))

    The analysis of cycle t is carried as the background of cycle t+1
    (persistence forecast by default; pass ``forecast`` to override).
    ``eng.analysis`` holds the latest analysis state.
    """

    def __init__(self, config: EngineConfig,
                 forecast: Optional[Callable] = None,
                 mesh=None, mesh_axis: str = "sub"):
        self.cfg = config
        self.forecast = forecast or (lambda x: x)
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        if config.solver == "shardmap" and mesh is None:
            raise ValueError("solver='shardmap' requires a mesh")
        if config.solver not in ("vmapped", "shardmap"):
            raise ValueError(f"unknown solver {config.solver!r}")
        if config.hysteresis < 1:
            raise ValueError(
                f"hysteresis must be >= 1 (got {config.hysteresis}); "
                f"1 means fire as soon as the threshold is crossed")
        if config.imbalance_threshold < 1.0:
            raise ValueError(
                f"imbalance_threshold is a max/mean ratio and must be "
                f">= 1.0 (got {config.imbalance_threshold})")

        self.boundaries = np.linspace(0.0, 1.0, config.p + 1)
        self.journal = Journal()
        self.analysis: Optional[jax.Array] = None
        self._H0 = cls_mod.state_operator(config.n, smooth=config.smooth)
        self._rng = np.random.default_rng(config.seed)
        self._truth = self._rng.normal(size=config.n)
        self._streak = 0  # consecutive over-threshold cycles
        self._t_last = time.perf_counter()

    # -- rebalance trigger policy ------------------------------------------

    def _should_rebalance(self, loads: np.ndarray) -> bool:
        if not self.cfg.rebalance:
            self._streak = 0
            return False
        if (loads == 0).any():
            # Empty subdomain: the DD step cannot wait out the hysteresis.
            self._streak = 0
            return True
        if imbalance_ratio(loads) > self.cfg.imbalance_threshold:
            self._streak += 1
        else:
            self._streak = 0
        if self._streak >= self.cfg.hysteresis:
            self._streak = 0
            return True
        return False

    # -- host-side cycle preparation (runs on the worker thread) -----------

    def _prepare(self, cycle: int, obs: np.ndarray) -> _Prepared:
        t0 = time.perf_counter()
        cfg = self.cfg
        obs = np.asarray(obs, dtype=np.float64)

        loads_in = dydd_mod._counts(obs, self.boundaries)
        imb_before = imbalance_ratio(loads_in)
        repartitioned, migrated, rounds = False, 0, 0
        if self._should_rebalance(loads_in):
            res = dydd_mod.dydd_1d(obs, cfg.p,
                                   boundaries=self.boundaries.copy())
            self.boundaries = res.boundaries
            repartitioned = True
            migrated = res.total_movement
            rounds = res.rounds
        loads = dydd_mod._counts(obs, self.boundaries)

        dec = dd_mod.decompose_1d(cfg.n, self.boundaries,
                                  overlap=cfg.overlap)
        H1 = cls_mod.observation_operator(cfg.n, obs)
        A = np.concatenate([self._H0, H1], axis=0)
        r = np.ones((A.shape[0],))
        packed_op = ddkf_mod.pack_operator(jnp.asarray(A), jnp.asarray(r),
                                           dec, mu=cfg.mu)

        # Truth-driven observation data: the truth random-walks each cycle
        # (deterministic under cfg.seed, independent of any solve result —
        # which is what makes this whole method pipelineable).
        self._truth = ((1.0 - cfg.truth_drift) * self._truth
                       + cfg.truth_drift * self._rng.normal(size=cfg.n))
        y1 = H1 @ self._truth + cfg.obs_noise * self._rng.normal(
            size=H1.shape[0])

        return _Prepared(cycle=cycle, obs=obs, packed_op=packed_op,
                         H0=self._H0, H1=H1, y1=y1, loads=loads,
                         imbalance_before=imb_before,
                         repartitioned=repartitioned, migrated=migrated,
                         rounds=rounds,
                         pack_time=time.perf_counter() - t0)

    # -- device-side solve (main thread) -----------------------------------

    def _solve(self, prep: _Prepared):
        """Returns (analysis, background) for the cycle."""
        cfg = self.cfg
        background = (np.zeros(cfg.n) if self.analysis is None
                      else np.asarray(self.forecast(self.analysis)))
        y0 = prep.H0 @ background
        packed = ddkf_mod.with_rhs(prep.packed_op,
                                   np.concatenate([y0, prep.y1]))
        if cfg.solver == "shardmap":
            x = ddkf_mod.solve_shardmap(packed, self.mesh,
                                        axis=self.mesh_axis,
                                        iters=cfg.iters,
                                        damping=cfg.damping)
        else:
            x = ddkf_mod.solve_vmapped(packed, iters=cfg.iters,
                                       damping=cfg.damping)
        return x, background

    def _reference_error(self, prep: _Prepared, background: np.ndarray,
                         x: jax.Array) -> float:
        """||x_engine - x_one_shot|| for the cycle's CLS problem."""
        dtype = prep.packed_op.A_loc.dtype
        prob = cls_mod.CLSProblem(
            H0=jnp.asarray(prep.H0, dtype),
            y0=jnp.asarray(prep.H0 @ background, dtype),
            H1=jnp.asarray(prep.H1, dtype),
            y1=jnp.asarray(prep.y1, dtype),
            R0=jnp.ones((prep.H0.shape[0],), dtype),
            R1=jnp.ones((prep.H1.shape[0],), dtype))
        return float(jnp.linalg.norm(x - cls_mod.solve(prob)))

    # -- driver -------------------------------------------------------------

    def run(self, stream: Iterable[np.ndarray]) -> Journal:
        """Consume the stream to exhaustion; returns the journal."""
        cfg = self.cfg
        it = iter(stream)
        self._t_last = time.perf_counter()
        if not cfg.double_buffer:
            for cycle, obs in enumerate(it):
                self._run_cycle(self._prepare(cycle, obs))
            return self.journal

        # Double-buffered: prepare cycle t+1 on the worker while the main
        # thread solves cycle t.  _prepare mutates boundary/truth state, so
        # exactly one prepare is in flight at a time (single worker, next
        # submit only after the previous result is claimed).
        with ThreadPoolExecutor(max_workers=1) as pool:
            try:
                first = next(it)
            except StopIteration:
                return self.journal
            fut = pool.submit(self._prepare, 0, first)
            cycle = 0
            while fut is not None:
                prep = fut.result()
                nxt = next(it, None)
                cycle += 1
                fut = (pool.submit(self._prepare, cycle, nxt)
                       if nxt is not None else None)
                self._run_cycle(prep)
        return self.journal

    def run_scenario(self, name: str, m: int, cycles: int,
                     seed: int = 0, **kw) -> Journal:
        """Convenience: run a registered stream scenario end to end."""
        return self.run(streams_mod.make_stream(name, m, cycles,
                                                seed=seed, **kw))

    def _run_cycle(self, prep: _Prepared) -> None:
        t0 = time.perf_counter()
        x, background = self._solve(prep)
        x = jax.block_until_ready(x)
        now = time.perf_counter()
        solve_time = now - t0
        # Measured wall time since the previous cycle completed — with
        # double buffering this is what the pipelining actually buys
        # (~max(pack, solve), not their sum).
        cycle_time = now - self._t_last
        self._t_last = now
        self.analysis = x

        err = (self._reference_error(prep, background, x)
               if self.cfg.track_reference else float("nan"))
        self.journal.append(CycleMetrics(
            cycle=prep.cycle,
            loads=[int(v) for v in prep.loads],
            imbalance=imbalance_ratio(prep.loads),
            imbalance_before=prep.imbalance_before,
            efficiency=dydd_mod.balance_ratio(prep.loads),
            repartitioned=prep.repartitioned,
            migrated=prep.migrated,
            rounds=prep.rounds,
            pack_time=prep.pack_time,
            solve_time=solve_time,
            cycle_time=cycle_time,
            error_vs_direct=err))
