"""Fleet-batched DD-KF solves: cohorts of same-shape cycle solves.

The multi-tenant serving layer (:mod:`repro.assim.serving`) runs many
independent assimilation streams through one device program.  This
module owns the batching half of that story: given the rhs-injected
:class:`~repro.core.ddkf.PackedDD` of one cycle from each of several
streams, group them into *cohorts* of identical shape/solver
configuration, pad each cohort to a quantized capacity, stack it on a
leading problem axis and dispatch one :func:`~repro.core.ddkf.solve_fleet`
call that advances every member a full cycle.

Shape bucketing.  Two cycle solves may share a compiled program only if
every static property matches: problem sizes ``(n, p, w, m)``, dtype,
the local solver kernel, and the Schwarz loop's static knobs
(``iters``, ``record_residuals``).  ``damping`` is a *traced* operand
of the fleet program (kept out of the compilation key on purpose — it
must also be numerically identical across members of one dispatch, so
it stays in the cohort key).  :func:`cohort_key` hashes exactly this
set; streams whose keys differ land in separate cohorts and separate
compiles.  Under DyDD the per-subdomain width ``w`` of a stream changes
whenever its boundaries move, so cohort membership is recomputed every
fleet round from the cycle's actual packing — a freshly repartitioned
stream simply migrates to whichever cohort its new shape lands in.

Capacity quantization.  Compiles are bounded by rounding each cohort's
batch up to ``k * 2**j`` (``k`` = fleet mesh axis size, 1 off-mesh):
admission and retirement change the live member count every round, and
without quantization each distinct count would trigger a fresh XLA
compile.  Padding slots are copies of member 0 whose results are
discarded — numerically inert because :func:`~repro.core.ddkf.solve_fleet`
maps ``solve_vmapped`` over the problem axis with ``lax.map``, so each
member's op graph (and hence its bits) is independent of who else rides
in the dispatch.  That independence is also what makes fleet results
bitwise-identical to sequential per-engine solves — the property the
determinism tests pin.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax

from repro.core import ddkf as ddkf_mod
from repro.obs import meters as meters_mod
from repro.obs import trace as trace_mod


def cohort_key(packed: "ddkf_mod.PackedDD", iters: int, damping: float,
               record_residuals: bool) -> tuple:
    """Hashable bucket id: everything that must match for two cycle
    solves to share one stacked dispatch (shapes + static solver config
    + damping, which is traced but must agree numerically)."""
    return (packed.n, packed.p, packed.w, packed.m,
            str(packed.A_loc.dtype), packed.solve_kernel,
            packed.solve_block, int(iters), float(damping),
            bool(record_residuals))


def quantize_capacity(size: int, mult: int = 1) -> int:
    """Smallest ``mult * 2**j >= size`` — the padded batch the cohort
    compiles at, so live-count churn between rounds re-uses programs."""
    if size < 1:
        raise ValueError(f"cohort size must be >= 1 (got {size})")
    cap = max(int(mult), 1)
    while cap < size:
        cap *= 2
    return cap


@dataclasses.dataclass
class CohortResult:
    """One batched dispatch's outputs, unstacked per member."""

    xs: List[jax.Array]                  # per-member analysis states
    hists: List[Optional[jax.Array]]     # per-member residual histories
    solve_time: float                    # wall time of the whole dispatch
    capacity: int                        # padded batch size compiled at
    size: int                            # live members in the dispatch


class CohortSolver:
    """Dispatches cohorts of rhs-injected packings through
    :func:`~repro.core.ddkf.solve_fleet`.

    ``mesh``/``axis`` select the sharded fleet path (members spread over
    the mesh axis, ``lax.map`` within each device); without a mesh the
    whole stacked batch runs on one device.  The solver is stateless
    apart from telemetry — jit caching lives in :mod:`repro.core.ddkf`.
    """

    def __init__(self, mesh=None, axis: str = "fleet"):
        self.mesh = mesh
        self.axis = axis
        self.mult = int(mesh.shape[axis]) if mesh is not None else 1
        # Per-key pinned capacity (monotone): round-to-round thread
        # timing shifts cohort sizes, and letting the capacity float
        # with each round's size would compile a fresh stacked program
        # per (shape, capacity) combination.  Pinning to the max
        # quantized size seen keeps one live program per shape.
        self._caps: Dict[tuple, int] = {}

    def solve(self, key: tuple,
              packs: Sequence["ddkf_mod.PackedDD"]) -> CohortResult:
        """Run one cohort (all members sharing ``key``) to completion."""
        (_, _, _, _, _, _, _, iters, damping, record_residuals) = key
        size = len(packs)
        cap = max(quantize_capacity(size, self.mult),
                  self._caps.get(key, 1))
        self._caps[key] = cap
        m = meters_mod.get_meters()
        with trace_mod.span("fleet.cohort", size=size, capacity=cap,
                            n=key[0], p=key[1], w=key[2]) as sp:
            t0 = time.perf_counter()
            if cap == 1:
                # Singleton off-mesh: skip the stack and ride the plain
                # per-problem program — the very same jit cache the
                # sequential engine path warms (bitwise-identical by the
                # lax.map invariant), so a fragmented round (every
                # stream in its own shape bucket) compiles nothing new.
                out = ddkf_mod.solve_vmapped(
                    packs[0], iters=iters, damping=damping,
                    residual_history=record_residuals)
                x = out[0][None] if record_residuals else out[None]
                hist = out[1][None] if record_residuals else None
            else:
                padded = list(packs) + [packs[0]] * (cap - size)
                stacked = ddkf_mod.stack_packed(padded)
                out = ddkf_mod.solve_fleet(
                    stacked, iters=iters, damping=damping,
                    residual_history=record_residuals,
                    mesh=self.mesh, axis=self.axis)
                x = out[0] if record_residuals else out
                hist = out[1] if record_residuals else None
            sp.fence(x)
            solve_time = time.perf_counter() - t0
        m.inc("fleet.cohort.dispatches")
        m.inc("fleet.cohort.members", size)
        m.inc("fleet.cohort.padded_slots", cap - size)
        m.observe("fleet.cohort.solve_time", solve_time)
        xs = [x[i] for i in range(size)]
        hists = ([hist[i] for i in range(size)] if record_residuals
                 else [None] * size)
        return CohortResult(xs=xs, hists=hists, solve_time=solve_time,
                            capacity=cap, size=size)


def group_cohorts(items: Sequence[Tuple[tuple, object]]
                  ) -> Dict[tuple, List[object]]:
    """Bucket ``(key, member)`` pairs by key, preserving arrival order
    within each cohort (the order members are unstacked back out in)."""
    groups: Dict[tuple, List[object]] = {}
    for key, member in items:
        groups.setdefault(key, []).append(member)
    return groups
