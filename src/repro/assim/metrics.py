"""Per-cycle journal for the streaming assimilation engine.

Every cycle appends one :class:`CycleMetrics` record; tests assert on the
records and benchmarks serialize them (``Journal.to_dict`` → JSON).  The
imbalance figures use the max/mean load ratio (1.0 = perfectly balanced,
p = everything on one subdomain) alongside the paper's §6 efficiency
E = min/max.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List

import numpy as np


def imbalance_ratio(loads) -> float:
    """max(load) / mean(load) — 1.0 is perfectly balanced."""
    loads = np.asarray(loads, dtype=np.float64)
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0


@dataclasses.dataclass
class CycleMetrics:
    """One assimilation cycle's worth of accounting."""

    cycle: int
    loads: list                 # per-subdomain observation counts (post-DD)
    loads_before: list          # counts against the *incoming* boundaries
    imbalance: float            # max/mean after any repartition this cycle
    imbalance_before: float     # max/mean against the incoming boundaries
    efficiency: float           # paper's E = min/max after repartition
    repartitioned: bool         # did DyDD fire this cycle?
    migrated: int               # observations moved by the diffusion schedule
    rounds: int                 # scheduling rounds DyDD used
    pack_time: float            # host-side operator packing (s); overlaps
                                # the previous solve under double buffering
    solve_time: float           # device DD-KF solve (s)
    cycle_time: float           # wall time since the previous cycle
                                # completed (s) — the throughput measure;
                                # ~max(pack, solve) when double-buffered

    error_vs_direct: float      # ||x_engine - x_one_shot||, nan if untracked

    # Communication accounting (modelled — solve_shardmap's per-iteration
    # send volume for the cycle's decomposition and configured comm path,
    # times the iteration count; journalled for every solver so vmapped
    # runs still show what a sharded run would move).
    comm_bytes_per_cycle: float = 0.0   # total modelled bytes per cycle
    halo_fraction: float = 0.0          # shared-slot fraction of the
                                        # decomposition (0 = no overlap)
    loads_weighted: list = dataclasses.field(default_factory=list)
                                # obs loads + halo-cost offsets — what the
                                # overlap-aware DyDD schedule balances
                                # (== loads when halo_weight is 0)
    rebalance_suppressed: bool = False
                                # a rebalance trigger armed this cycle but
                                # was suppressed because the previous
                                # cycle's rebalance already left exactly
                                # these loads (an unpopulatable subdomain
                                # would otherwise re-fire the DD step
                                # every cycle)

    # Observability (the telemetry PR's fields — all default-empty so
    # journals written before it round-trip unchanged).
    phases: dict = dataclasses.field(default_factory=dict)
                                # per-phase host durations (s): count,
                                # dydd, halo, pack, data, solve — the
                                # span timings, journalled even when no
                                # tracer is installed
    residual_history: list = dataclasses.field(default_factory=list)
                                # per-iteration Schwarz update norms
                                # ||x^{k+1} - x^k||_F (empty unless
                                # record_residuals)
    comm_edge_bytes_per_cycle: dict = dataclasses.field(
        default_factory=dict)   # "i-j" -> bytes each endpoint sends per
                                # cycle, neighbour-path pricing of the
                                # cycle's halo geometry (modelled for
                                # every comm config, like comm_bytes on
                                # vmapped runs); obs.meters.comm_matrix
                                # turns this into the (p, p) matrix
    comm_mvec_bytes_per_cycle: float = 0.0
                                # m-vector all-reduce bytes per cycle,
                                # summed over devices (comm_bytes_per_
                                # cycle = matrix.sum() + this, neighbour)
    comm_mvec_axis_bytes_per_cycle: dict = dataclasses.field(
        default_factory=dict)   # mesh-axis name -> per-cycle all-reduce
                                # bytes under torus pricing (outer axes
                                # move the full vector per psum hop; the
                                # values sum to comm_mvec_bytes_per_cycle)
    device_solve_times: list = dataclasses.field(default_factory=list)
                                # per-device time-to-shard-ready (s)
                                # since solve dispatch, device order;
                                # [solve_time] on the vmapped path
    straggler_flags: list = dataclasses.field(default_factory=list)
                                # device indices the EWMA-deadline
                                # straggler monitor flagged this cycle
    window: int = -1            # time-window id when the cycle ran under
                                # the parallel-in-time engine (repro.
                                # assim.timepar); -1 on sequential runs.
                                # Deterministic given config (the window
                                # partition is a pure function of the
                                # cycle count), so it stays in the
                                # bitwise deterministic_dict view

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["loads"] = [int(v) for v in self.loads]
        d["loads_before"] = [int(v) for v in self.loads_before]
        d["loads_weighted"] = [int(v) for v in self.loads_weighted]
        d["phases"] = {k: float(v) for k, v in self.phases.items()}
        d["residual_history"] = [float(v) for v in self.residual_history]
        d["comm_edge_bytes_per_cycle"] = {
            k: float(v) for k, v in self.comm_edge_bytes_per_cycle.items()}
        d["comm_mvec_axis_bytes_per_cycle"] = {
            k: float(v)
            for k, v in self.comm_mvec_axis_bytes_per_cycle.items()}
        d["device_solve_times"] = [float(v)
                                   for v in self.device_solve_times]
        d["straggler_flags"] = [int(v) for v in self.straggler_flags]
        # nan (error untracked) is not valid JSON — serialize as null.
        if not np.isfinite(self.error_vs_direct):
            d["error_vs_direct"] = None
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CycleMetrics":
        """Inverse of :meth:`to_dict` (null error back to nan); unknown
        keys are ignored so newer journals load on older readers."""
        d = dict(d)
        if d.get("error_vs_direct") is None:
            d["error_vs_direct"] = float("nan")
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass
class Journal:
    """Append-only per-cycle record list with summary statistics.

    ``meta`` carries the domain descriptor (``Domain.describe()`` — ndim,
    mesh shape, tiling) so a serialized journal is self-describing: 2D
    consumers can reshape the flat per-subdomain ``loads`` back into the
    pr x pc cell table.
    """

    records: List[CycleMetrics] = dataclasses.field(default_factory=list)
    meta: dict = dataclasses.field(default_factory=dict)

    def append(self, rec: CycleMetrics) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    @property
    def repartition_count(self) -> int:
        return sum(r.repartitioned for r in self.records)

    @property
    def migrated_total(self) -> int:
        return sum(r.migrated for r in self.records)

    @property
    def imbalance_trajectory(self) -> list:
        return [r.imbalance for r in self.records]

    @property
    def cycle_times(self) -> list:
        return [r.cycle_time for r in self.records]

    def phase_stats(self) -> dict:
        """Per-phase p50/p99/mean durations (s) across all cycles, from
        the records' ``phases`` dicts: ``{phase: {p50, p99, mean}}``."""
        series: dict = {}
        for r in self.records:
            for k, v in r.phases.items():
                series.setdefault(k, []).append(float(v))
        return {k: {"p50": float(np.percentile(v, 50)),
                    "p99": float(np.percentile(v, 99)),
                    "mean": float(np.mean(v))}
                for k, v in series.items()}

    def summary(self) -> dict:
        if not self.records:
            return {"cycles": 0}
        imb = np.array(self.imbalance_trajectory)
        times = np.array(self.cycle_times)
        errs = np.array([r.error_vs_direct for r in self.records])
        return {
            "cycles": len(self.records),
            "repartitions": self.repartition_count,
            "repartitions_suppressed": int(sum(
                r.rebalance_suppressed for r in self.records)),
            "migrated_total": self.migrated_total,
            "imbalance_max": float(imb.max()),
            "imbalance_mean": float(imb.mean()),
            "cycle_time_mean": float(times.mean()),
            "cycle_time_max": float(times.max()),
            "pack_time_mean": float(np.mean(
                [r.pack_time for r in self.records])),
            "solve_time_mean": float(np.mean(
                [r.solve_time for r in self.records])),
            "error_max": float(np.nanmax(errs)) if np.isfinite(
                errs).any() else None,
            "comm_bytes_per_cycle_mean": float(np.mean(
                [r.comm_bytes_per_cycle for r in self.records])),
            "halo_fraction_mean": float(np.mean(
                [r.halo_fraction for r in self.records])),
            "phases": self.phase_stats(),
            "straggler_flags_total": int(sum(
                len(r.straggler_flags) for r in self.records)),
            "residual_final_mean": (float(np.mean(
                [r.residual_history[-1] for r in self.records
                 if r.residual_history]))
                if any(r.residual_history for r in self.records)
                else None),
        }

    def to_dict(self) -> dict:
        return {"meta": dict(self.meta),
                "records": [r.to_dict() for r in self.records],
                "summary": self.summary()}

    @classmethod
    def from_dict(cls, d: dict) -> "Journal":
        """Rebuild a journal from ``to_dict`` output (summary is
        recomputed, not trusted)."""
        return cls(records=[CycleMetrics.from_dict(r)
                            for r in d.get("records", [])],
                   meta=dict(d.get("meta", {})))

    # Wall-clock-derived record fields: identical inputs produce
    # different values across runs, so the resume/chaos bitwise
    # comparisons strip them (everything else in a record is a pure
    # function of stream + seed + config).
    NONDETERMINISTIC_FIELDS = ("pack_time", "solve_time", "cycle_time",
                               "phases", "device_solve_times",
                               "straggler_flags")

    def deterministic_dict(self) -> dict:
        """``to_dict`` minus wall-clock fields and resume bookkeeping —
        the view under which an interrupted-and-resumed run must be
        *bitwise identical* to an uninterrupted one.  Straggler flags are
        timing-derived too (an injected straggle changes them by design),
        so they are part of the chaos evidence, not this view."""
        records = []
        for r in self.records:
            d = r.to_dict()
            for k in self.NONDETERMINISTIC_FIELDS:
                d.pop(k, None)
            records.append(d)
        meta = {k: v for k, v in self.meta.items() if k != "resume"}
        return {"meta": meta, "records": records}

    def deterministic_json(self) -> str:
        return json.dumps(self.deterministic_dict(), sort_keys=True)

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=2))
