"""Multi-tenant assimilation serving: N streams, one device program.

:class:`FleetServer` runs many independent :class:`AssimilationEngine`
streams concurrently by batching their per-cycle DD-KF solves into
cohort dispatches (:mod:`repro.assim.fleet`) while host-side cycle
preparation runs on a thread pool — the single-engine double-buffering
generalized to a fleet:

* **Continuous batching.**  Streams are submitted to the shared
  :class:`~repro.runtime.scheduler.SlotScheduler`; up to ``max_active``
  are in flight at once, the rest queue FIFO.  A stream retires the
  moment its observation stream is exhausted and its slot is re-filled
  on the next round — admission and retirement never recompile anything
  (cohort capacities are quantized, so the batched programs are reused
  across membership churn).

* **Fleet rounds.**  Each round collects every stream whose host-side
  ``prepare`` has finished, immediately pipelines that stream's *next*
  ``prepare`` onto the pool, injects the carried background
  (``solve_input``), buckets the resulting packings into shape cohorts
  and dispatches each cohort as one stacked solve.  Streams whose
  preparation is still running are simply not in this round — nobody
  waits for the slowest tenant.

* **Per-stream DyDD isolation.**  A stream whose rebalance trigger
  fires does its repartition + repack inside ``prepare`` on a pool
  thread, concurrent with other streams' device solves.  Its changed
  subdomain widths move it to a different cohort on its next round;
  the other streams' cohorts (and compiled programs) are untouched.

Per-stream results are **bitwise identical** to running each engine's
``run`` loop sequentially: the fleet path maps the very same
``solve_vmapped`` program over the problem axis with ``lax.map``
(see :func:`repro.core.ddkf.solve_fleet`), and all engine state
transitions go through the same ``prepare → solve_input →
complete_cycle`` methods in the same per-stream order.
"""
from __future__ import annotations

import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable, Dict, Iterable, Optional

from repro.assim import fleet as fleet_mod
from repro.assim.engine import AssimilationEngine, EngineConfig
from repro.assim.metrics import Journal
from repro.obs import meters as meters_mod
from repro.obs import trace as trace_mod
from repro.runtime import chaos as chaos_mod
from repro.runtime.scheduler import SlotScheduler


class _StreamState:
    """One tenant: an engine, its observation iterator, and the in-flight
    ``prepare`` future (at most one per engine, ever)."""

    def __init__(self, sid, engine: AssimilationEngine, stream: Iterable,
                 checkpoint_dir: Optional[str] = None,
                 snapshot_every: int = 0):
        self.sid = sid
        self.engine = engine
        self.it = iter(stream)
        self.slot: Optional[int] = None
        self.fut = None               # in-flight prepare future
        self.pending = None           # (cycle, obs) of the in-flight
                                      # prepare — what a transient-fault
                                      # retry resubmits verbatim
        self.exhausted = False        # iterator has run dry
        self.cycles = 0
        self.checkpoint_dir = checkpoint_dir
        self.snapshot_every = int(snapshot_every)

    def snap_due(self, cycle: int) -> bool:
        return (self.checkpoint_dir is not None
                and self.snapshot_every > 0
                and (cycle + 1) % self.snapshot_every == 0)


class FleetServer:
    """Continuous-batching server for assimilation streams.

    Usage::

        server = FleetServer(max_active=64)
        for i in range(256):
            server.add_stream(f"s{i}", EngineConfig(n=48, p=4),
                              streams.make_stream("drifting_swarm", 120, 8,
                                                  seed=i))
        journals = server.serve()          # {sid: Journal}

    ``mesh``/``mesh_axis`` spread cohort batches over a device mesh axis
    (e.g. an 8-device ``("fleet",)`` mesh); cohort sizes are padded to a
    multiple of the axis size automatically.  Only ``solver="vmapped"``
    engines can ride a fleet — the shardmap solver owns whole devices
    per subdomain and cannot be stacked.
    """

    def __init__(self, mesh=None, mesh_axis: str = "fleet",
                 max_active: Optional[int] = None, pack_workers: int = 4,
                 gather_window: float = 0.02, solver=None,
                 chaos: "chaos_mod.ChaosInjector | None" = None,
                 max_retries: int = 2, retry_backoff: float = 0.05):
        if pack_workers < 1:
            raise ValueError(f"pack_workers must be >= 1 "
                             f"(got {pack_workers})")
        if gather_window < 0:
            raise ValueError(f"gather_window must be >= 0 "
                             f"(got {gather_window})")
        self.gather_window = gather_window
        # Server-level fault handling: `chaos` injects transient faults
        # at cohort-solve dispatch (site "solve", keyed by round);
        # TransientFaults from any stream's prepare or any cohort solve
        # are retried up to max_retries with exponential backoff before
        # the affected stream(s) are retired as failed.
        self.chaos = chaos
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self.scheduler = SlotScheduler(capacity=max_active,
                                       meters_prefix="fleet.")
        # An explicit solver carries its pinned cohort capacities (and
        # the jit caches keyed off them) across server lifetimes — a
        # long-running service or a benchmark's warmup passes hand the
        # same CohortSolver to each successive server.
        self.solver = solver if solver is not None \
            else fleet_mod.CohortSolver(mesh=mesh, axis=mesh_axis)
        self.pack_workers = pack_workers
        self.journals: Dict[object, Journal] = {}
        self.engines: Dict[object, AssimilationEngine] = {}
        self._sids: set = set()
        # Per-sid intake record (checkpoint_dir / snapshot_every /
        # forecast) — survives the _StreamState, which is dropped when a
        # stream retires or fails, so readmit() can rebuild the stream
        # from its latest snapshot after the fact.
        self._stream_meta: Dict[object, dict] = {}
        self.stats: Dict[str, float] = {}

    # -- stream intake -----------------------------------------------------

    def add_stream(self, sid, config: EngineConfig,
                   stream: Iterable, *,
                   forecast: Optional[Callable] = None,
                   domain=None, engine: Optional[AssimilationEngine]
                   = None, checkpoint_dir: Optional[str] = None,
                   snapshot_every: int = 0,
                   chaos: "chaos_mod.ChaosInjector | None" = None
                   ) -> None:
        """Queue one assimilation stream (engine built here, started at
        admission).  ``sid`` keys the returned journal and must be
        unique.

        ``checkpoint_dir``/``snapshot_every`` enable per-stream periodic
        engine snapshots (taken at cycle boundaries — the stream's next
        prepare is deferred around the save, like the single-engine
        run loop).  ``chaos`` attaches a per-stream fault injector to
        the engine (pack faults surface at claim time and are retried).
        Pass a restored ``engine`` (from
        :func:`repro.runtime.elastic.resume_assim_engine`) to continue
        an interrupted stream mid-fleet — cycle numbering picks up from
        its journal.
        """
        if sid in self._sids:
            raise ValueError(f"duplicate stream id {sid!r}")
        if config.solver != "vmapped":
            raise ValueError(
                f"fleet serving requires solver='vmapped' (stream "
                f"{sid!r} asked for {config.solver!r}); the shardmap "
                f"solver dedicates one device per subdomain and cannot "
                f"be batched on a problem axis")
        self._sids.add(sid)
        self._stream_meta[sid] = {"checkpoint_dir": checkpoint_dir,
                                  "snapshot_every": int(snapshot_every),
                                  "forecast": forecast}
        if engine is None:
            engine = AssimilationEngine(config, forecast=forecast,
                                        domain=domain, chaos=chaos)
        elif chaos is not None:
            engine._chaos = chaos
        engine._stream = stream if hasattr(stream, "cursor") else None
        self.engines[sid] = engine
        self.scheduler.submit(_StreamState(
            sid, engine, stream, checkpoint_dir=checkpoint_dir,
            snapshot_every=snapshot_every))

    def readmit(self, stream_id, *,
                chaos: "chaos_mod.ChaosInjector | None" = None) -> None:
        """Re-admit a retired or crashed stream from its latest
        per-stream snapshot.

        The stream must have been added with a ``checkpoint_dir`` and
        must currently be out of the scheduler (retired after
        exhaustion or failed — i.e. its journal has been recorded).
        The engine and the observation stream continuation are rebuilt
        with :func:`repro.runtime.elastic.resume_assim_engine` (latest
        hash-verified snapshot wins; no completed cycle is replayed)
        and resubmitted through the :class:`SlotScheduler` like any
        new tenant — it queues FIFO and acquires a slot on the next
        admission round.  ``chaos`` optionally attaches a fresh fault
        injector to the resumed engine (the crashed run's injector is
        *not* carried over).  Emits a ``fleet.stream_readmitted`` obs
        event.
        """
        from repro.runtime import elastic as elastic_mod

        if stream_id not in self._sids:
            raise KeyError(f"unknown stream id {stream_id!r}")
        if stream_id not in self.journals:
            raise ValueError(
                f"stream {stream_id!r} is still active or queued; only "
                f"a retired/failed stream can be readmitted")
        meta = self._stream_meta.get(stream_id, {})
        ckpt_dir = meta.get("checkpoint_dir")
        if ckpt_dir is None:
            raise ValueError(
                f"stream {stream_id!r} was added without a "
                f"checkpoint_dir; nothing to readmit from")
        engine, stream = elastic_mod.resume_assim_engine(
            ckpt_dir, forecast=meta.get("forecast"), chaos=chaos)
        if stream is None:
            raise ValueError(
                f"stream {stream_id!r}'s snapshot carries no resumable "
                f"cursor (was it fed a plain iterable?)")
        engine._stream = stream
        self.engines[stream_id] = engine
        # The stale partial journal is superseded by the restored
        # engine's journal (which the next retirement re-records).
        self.journals.pop(stream_id, None)
        m = meters_mod.get_meters()
        m.event("fleet.stream_readmitted", sid=stream_id,
                resume_cycle=len(engine.journal.records))
        m.inc("fleet.streams_readmitted")
        self.scheduler.submit(_StreamState(
            stream_id, engine, stream, checkpoint_dir=ckpt_dir,
            snapshot_every=meta.get("snapshot_every", 0)))

    # -- serving loop ------------------------------------------------------

    def _admit(self, pool: ThreadPoolExecutor) -> None:
        """Fill free slots from the queue; kick off each newcomer's first
        ``prepare``.  Empty streams retire immediately (their journal is
        the empty journal).  Cycle numbering starts at the engine's
        journal length, so a restored engine continues its count."""
        for slot, st in self.scheduler.admit():
            st.slot = slot
            st.engine.reset_clock()
            first = next(st.it, None)
            if first is None:
                st.exhausted = True
                self.journals[st.sid] = st.engine.journal
                self.scheduler.retire(slot)
                continue
            base = len(st.engine.journal.records)
            st.pending = (base, first)
            st.fut = pool.submit(st.engine.prepare, base, first)

    def _submit_next(self, st: _StreamState,
                     pool: ThreadPoolExecutor, cycle: int) -> None:
        """Draw the stream's next observation and pipeline its prepare;
        marks the stream exhausted when the iterator runs dry."""
        nxt = next(st.it, None)
        if nxt is None:
            st.exhausted = True
            return
        st.pending = (cycle, nxt)
        st.fut = pool.submit(st.engine.prepare, cycle, nxt)

    def _fail_stream(self, st: _StreamState, exc: BaseException) -> None:
        """Retire a crashed stream: journal what it completed, reclaim
        its slot (the scheduler re-admits from the queue on the next
        round), and journal the failure as an obs event.  Every stream
        failure path funnels through here — a prepare that raises on the
        pool can no longer leak its slot."""
        m = meters_mod.get_meters()
        m.event("fleet.stream_failed", sid=st.sid,
                cycles_completed=int(st.cycles),
                error=f"{type(exc).__name__}: {exc}")
        m.inc("fleet.streams_failed")
        st.exhausted = True
        st.fut = None
        self.journals[st.sid] = st.engine.journal
        if st.slot is not None:
            self.scheduler.retire(st.slot)
            st.slot = None

    def _claim(self, st: _StreamState, pool: ThreadPoolExecutor):
        """Claim a finished prepare, retrying TransientFaults by
        resubmitting the same (cycle, obs) with exponential backoff —
        injected pack faults fire before any engine state mutation, so
        the retry is bitwise-equivalent.  Non-transient exceptions and
        an exhausted retry budget propagate to the failure path."""
        m = meters_mod.get_meters()
        fut = st.fut
        for attempt in range(self.max_retries + 1):
            try:
                return fut.result()
            except chaos_mod.TransientFault:
                if attempt >= self.max_retries:
                    raise
                cycle, obs = st.pending
                m.event("chaos.retry", site="pack", sid=st.sid,
                        cycle=int(cycle), attempt=attempt + 1)
                m.inc("chaos.retries")
                time.sleep(self.retry_backoff * (2.0 ** attempt))
                fut = pool.submit(st.engine.prepare, cycle, obs)

    def _cohort_solve(self, key, packs, round_no: int):
        """One cohort dispatch behind the server-level fault injector."""
        if self.chaos is not None:
            self.chaos.check("solve", round_no)
        return self.solver.solve(key, packs)

    def serve(self) -> Dict[object, Journal]:
        """Run every queued stream to exhaustion; returns the per-stream
        journals keyed by sid."""
        m = meters_mod.get_meters()
        t_start = time.perf_counter()
        rounds = 0
        with ThreadPoolExecutor(max_workers=self.pack_workers,
                                thread_name_prefix="pack") as pool:
            self._admit(pool)
            while not self.scheduler.idle():
                active = list(self.scheduler.active().values())
                in_flight = [st.fut for st in active if st.fut is not None]
                ready = [st for st in active
                         if st.fut is not None and st.fut.done()]
                if not ready:
                    wait(in_flight, return_when=FIRST_COMPLETED)
                elif len(ready) < len(in_flight) and self.gather_window:
                    # Gather window: give stragglers a short grace to
                    # join this round — fuller rounds mean larger (and
                    # more repeatable) cohorts, hence fewer dispatches
                    # and fewer distinct compiled capacities.  A stream
                    # mid-DyDD-repack that misses the window simply
                    # rides the next round; nobody blocks on it.
                    wait(in_flight, timeout=self.gather_window)
                ready = [st for st in active
                         if st.fut is not None and st.fut.done()]
                if not ready:
                    continue

                # Claim finished preps; pipeline each stream's next
                # prepare onto the pool *before* this round's solve so
                # host packing overlaps device work (the engine's
                # double-buffering, fleet-wide).  On a snapshot-due
                # cycle the next prepare is deferred until after the
                # save (it would mutate the engine state mid-snapshot);
                # a stream whose prepare ultimately failed is retired
                # with its slot reclaimed.
                items = []
                deferred = []
                for st in ready:
                    try:
                        prep = self._claim(st, pool)
                    except Exception as e:
                        self._fail_stream(st, e)
                        continue
                    st.fut = None
                    if st.snap_due(prep.cycle):
                        deferred.append((st, prep))
                    else:
                        self._submit_next(st, pool, prep.cycle + 1)
                    if prep.repartitioned:
                        # DyDD isolation: note the repack; the stream's
                        # new shape re-buckets it below without touching
                        # anyone else's cohort.
                        m.event("fleet.dydd.repack", sid=st.sid,
                                cycle=prep.cycle, migrated=prep.migrated)
                    packed, background = st.engine.solve_input(prep)
                    cfg = st.engine.cfg
                    key = fleet_mod.cohort_key(packed, cfg.iters,
                                               cfg.damping,
                                               cfg.record_residuals)
                    items.append((key, (st, prep, packed, background)))

                with trace_mod.span("fleet.round", round=rounds,
                                    streams=len(items)):
                    for key, members in fleet_mod.group_cohorts(
                            items).items():
                        try:
                            res = chaos_mod.retry_transient(
                                lambda: self._cohort_solve(
                                    key, [pk for (_, _, pk, _)
                                          in members], rounds),
                                retries=self.max_retries,
                                backoff=self.retry_backoff,
                                site="solve", cycle=rounds)
                        except Exception as e:
                            # Cohort lost: retire its members; other
                            # cohorts (and their streams) are untouched.
                            for (st, _, _, _) in members:
                                self._fail_stream(st, e)
                            continue
                        for (st, prep, _, background), x, hist in zip(
                                members, res.xs, res.hists):
                            st.engine.complete_cycle(
                                prep, x, background,
                                solve_time=res.solve_time, hist=hist)
                            st.cycles += 1
                            if (st.engine._chaos is not None
                                    and not st.snap_due(prep.cycle)):
                                st.engine._chaos.maybe_kill(
                                    "cycle_end", prep.cycle)
                rounds += 1
                m.inc("fleet.rounds")

                # Deferred tail of snapshot cycles: the engine is at a
                # clean cycle boundary (solve completed, next prepare
                # not yet submitted) — save, then resume pipelining.
                for st, prep in deferred:
                    if st.exhausted and st.slot is None:
                        continue   # failed during its cohort solve
                    st.engine.save_checkpoint(st.checkpoint_dir,
                                              step=prep.cycle + 1)
                    if st.engine._chaos is not None:
                        st.engine._chaos.maybe_kill("cycle_end",
                                                    prep.cycle)
                    self._submit_next(st, pool, prep.cycle + 1)

                for st in ready:
                    if st.exhausted and st.fut is None \
                            and st.slot is not None:
                        self.journals[st.sid] = st.engine.journal
                        self.scheduler.retire(st.slot)
                        st.slot = None
                self._admit(pool)

        wall = time.perf_counter() - t_start
        total_cycles = sum(len(j) for j in self.journals.values())
        self.stats = {"wall_time": wall, "rounds": rounds,
                      "streams": len(self.journals),
                      "cycles": total_cycles,
                      "cycles_per_sec": (total_cycles / wall if wall
                                         else 0.0)}
        m.gauge("fleet.cycles_per_sec", self.stats["cycles_per_sec"])
        return self.journals
