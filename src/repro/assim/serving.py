"""Multi-tenant assimilation serving: N streams, one device program.

:class:`FleetServer` runs many independent :class:`AssimilationEngine`
streams concurrently by batching their per-cycle DD-KF solves into
cohort dispatches (:mod:`repro.assim.fleet`) while host-side cycle
preparation runs on a thread pool — the single-engine double-buffering
generalized to a fleet:

* **Continuous batching.**  Streams are submitted to the shared
  :class:`~repro.runtime.scheduler.SlotScheduler`; up to ``max_active``
  are in flight at once, the rest queue FIFO.  A stream retires the
  moment its observation stream is exhausted and its slot is re-filled
  on the next round — admission and retirement never recompile anything
  (cohort capacities are quantized, so the batched programs are reused
  across membership churn).

* **Fleet rounds.**  Each round collects every stream whose host-side
  ``prepare`` has finished, immediately pipelines that stream's *next*
  ``prepare`` onto the pool, injects the carried background
  (``solve_input``), buckets the resulting packings into shape cohorts
  and dispatches each cohort as one stacked solve.  Streams whose
  preparation is still running are simply not in this round — nobody
  waits for the slowest tenant.

* **Per-stream DyDD isolation.**  A stream whose rebalance trigger
  fires does its repartition + repack inside ``prepare`` on a pool
  thread, concurrent with other streams' device solves.  Its changed
  subdomain widths move it to a different cohort on its next round;
  the other streams' cohorts (and compiled programs) are untouched.

Per-stream results are **bitwise identical** to running each engine's
``run`` loop sequentially: the fleet path maps the very same
``solve_vmapped`` program over the problem axis with ``lax.map``
(see :func:`repro.core.ddkf.solve_fleet`), and all engine state
transitions go through the same ``prepare → solve_input →
complete_cycle`` methods in the same per-stream order.
"""
from __future__ import annotations

import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable, Dict, Iterable, Optional

from repro.assim import fleet as fleet_mod
from repro.assim.engine import AssimilationEngine, EngineConfig
from repro.assim.metrics import Journal
from repro.obs import meters as meters_mod
from repro.obs import trace as trace_mod
from repro.runtime.scheduler import SlotScheduler


class _StreamState:
    """One tenant: an engine, its observation iterator, and the in-flight
    ``prepare`` future (at most one per engine, ever)."""

    def __init__(self, sid, engine: AssimilationEngine, stream: Iterable):
        self.sid = sid
        self.engine = engine
        self.it = iter(stream)
        self.slot: Optional[int] = None
        self.fut = None               # in-flight prepare future
        self.exhausted = False        # iterator has run dry
        self.cycles = 0


class FleetServer:
    """Continuous-batching server for assimilation streams.

    Usage::

        server = FleetServer(max_active=64)
        for i in range(256):
            server.add_stream(f"s{i}", EngineConfig(n=48, p=4),
                              streams.make_stream("drifting_swarm", 120, 8,
                                                  seed=i))
        journals = server.serve()          # {sid: Journal}

    ``mesh``/``mesh_axis`` spread cohort batches over a device mesh axis
    (e.g. an 8-device ``("fleet",)`` mesh); cohort sizes are padded to a
    multiple of the axis size automatically.  Only ``solver="vmapped"``
    engines can ride a fleet — the shardmap solver owns whole devices
    per subdomain and cannot be stacked.
    """

    def __init__(self, mesh=None, mesh_axis: str = "fleet",
                 max_active: Optional[int] = None, pack_workers: int = 4,
                 gather_window: float = 0.02, solver=None):
        if pack_workers < 1:
            raise ValueError(f"pack_workers must be >= 1 "
                             f"(got {pack_workers})")
        if gather_window < 0:
            raise ValueError(f"gather_window must be >= 0 "
                             f"(got {gather_window})")
        self.gather_window = gather_window
        self.scheduler = SlotScheduler(capacity=max_active,
                                       meters_prefix="fleet.")
        # An explicit solver carries its pinned cohort capacities (and
        # the jit caches keyed off them) across server lifetimes — a
        # long-running service or a benchmark's warmup passes hand the
        # same CohortSolver to each successive server.
        self.solver = solver if solver is not None \
            else fleet_mod.CohortSolver(mesh=mesh, axis=mesh_axis)
        self.pack_workers = pack_workers
        self.journals: Dict[object, Journal] = {}
        self.engines: Dict[object, AssimilationEngine] = {}
        self._sids: set = set()
        self.stats: Dict[str, float] = {}

    # -- stream intake -----------------------------------------------------

    def add_stream(self, sid, config: EngineConfig,
                   stream: Iterable, *,
                   forecast: Optional[Callable] = None,
                   domain=None) -> None:
        """Queue one assimilation stream (engine built here, started at
        admission).  ``sid`` keys the returned journal and must be
        unique."""
        if sid in self._sids:
            raise ValueError(f"duplicate stream id {sid!r}")
        if config.solver != "vmapped":
            raise ValueError(
                f"fleet serving requires solver='vmapped' (stream "
                f"{sid!r} asked for {config.solver!r}); the shardmap "
                f"solver dedicates one device per subdomain and cannot "
                f"be batched on a problem axis")
        self._sids.add(sid)
        engine = AssimilationEngine(config, forecast=forecast,
                                    domain=domain)
        self.engines[sid] = engine
        self.scheduler.submit(_StreamState(sid, engine, stream))

    # -- serving loop ------------------------------------------------------

    def _admit(self, pool: ThreadPoolExecutor) -> None:
        """Fill free slots from the queue; kick off each newcomer's first
        ``prepare``.  Empty streams retire immediately (their journal is
        the empty journal)."""
        for slot, st in self.scheduler.admit():
            st.slot = slot
            st.engine.reset_clock()
            first = next(st.it, None)
            if first is None:
                st.exhausted = True
                self.journals[st.sid] = st.engine.journal
                self.scheduler.retire(slot)
                continue
            st.fut = pool.submit(st.engine.prepare, 0, first)

    def serve(self) -> Dict[object, Journal]:
        """Run every queued stream to exhaustion; returns the per-stream
        journals keyed by sid."""
        m = meters_mod.get_meters()
        t_start = time.perf_counter()
        rounds = 0
        with ThreadPoolExecutor(max_workers=self.pack_workers,
                                thread_name_prefix="pack") as pool:
            self._admit(pool)
            while not self.scheduler.idle():
                active = list(self.scheduler.active().values())
                in_flight = [st.fut for st in active if st.fut is not None]
                ready = [st for st in active
                         if st.fut is not None and st.fut.done()]
                if not ready:
                    wait(in_flight, return_when=FIRST_COMPLETED)
                elif len(ready) < len(in_flight) and self.gather_window:
                    # Gather window: give stragglers a short grace to
                    # join this round — fuller rounds mean larger (and
                    # more repeatable) cohorts, hence fewer dispatches
                    # and fewer distinct compiled capacities.  A stream
                    # mid-DyDD-repack that misses the window simply
                    # rides the next round; nobody blocks on it.
                    wait(in_flight, timeout=self.gather_window)
                ready = [st for st in active
                         if st.fut is not None and st.fut.done()]
                if not ready:
                    continue

                # Claim finished preps; pipeline each stream's next
                # prepare onto the pool *before* this round's solve so
                # host packing overlaps device work (the engine's
                # double-buffering, fleet-wide).
                items = []
                for st in ready:
                    prep = st.fut.result()
                    st.fut = None
                    nxt = next(st.it, None)
                    if nxt is not None:
                        st.fut = pool.submit(st.engine.prepare,
                                             prep.cycle + 1, nxt)
                    else:
                        st.exhausted = True
                    if prep.repartitioned:
                        # DyDD isolation: note the repack; the stream's
                        # new shape re-buckets it below without touching
                        # anyone else's cohort.
                        m.event("fleet.dydd.repack", sid=st.sid,
                                cycle=prep.cycle, migrated=prep.migrated)
                    packed, background = st.engine.solve_input(prep)
                    cfg = st.engine.cfg
                    key = fleet_mod.cohort_key(packed, cfg.iters,
                                               cfg.damping,
                                               cfg.record_residuals)
                    items.append((key, (st, prep, packed, background)))

                with trace_mod.span("fleet.round", round=rounds,
                                    streams=len(items)):
                    for key, members in fleet_mod.group_cohorts(
                            items).items():
                        res = self.solver.solve(
                            key, [pk for (_, _, pk, _) in members])
                        for (st, prep, _, background), x, hist in zip(
                                members, res.xs, res.hists):
                            st.engine.complete_cycle(
                                prep, x, background,
                                solve_time=res.solve_time, hist=hist)
                            st.cycles += 1
                rounds += 1
                m.inc("fleet.rounds")

                for st in ready:
                    if st.exhausted and st.fut is None:
                        self.journals[st.sid] = st.engine.journal
                        self.scheduler.retire(st.slot)
                self._admit(pool)

        wall = time.perf_counter() - t_start
        total_cycles = sum(len(j) for j in self.journals.values())
        self.stats = {"wall_time": wall, "rounds": rounds,
                      "streams": len(self.journals),
                      "cycles": total_cycles,
                      "cycles_per_sec": (total_cycles / wall if wall
                                         else 0.0)}
        m.gauge("fleet.cycles_per_sec", self.stats["cycles_per_sec"])
        return self.journals
