"""Observation-stream scenarios for the streaming assimilation engine.

A *stream* is a named, seeded generator of per-cycle observation locations
— the moving observation network the paper's conclusion names as future
work.  Scenarios declare the dimension of their domain: a 1D scenario
yields sorted ``(m,)`` arrays in [0, 1); a 2D scenario yields ``(m, 2)``
arrays in [0, 1)², lexicographically sorted by (y, x).  Every scenario is
registered under a name so engines, tests and benchmarks can sweep the
registry:

    for name in streams.available(ndim=2):
        for obs in streams.make_stream(name, m=400, cycles=6, seed=0):
            ...  # obs is a lex-sorted (m, 2) float array in [0, 1)^2

Adding a scenario is one decorated function::

    @register("my_scenario", ndim=2)
    def my_scenario(m, cycles, seed):
        rng = np.random.default_rng(seed)
        for c in range(cycles):
            yield _finalize_2d(rng.uniform(0, 1, (m, 2)))

Contract: a scenario must be deterministic under a fixed ``seed`` and
yield exactly ``cycles`` arrays of shape ``(m,)`` (sorted) for ``ndim=1``
or ``(m, 2)`` (lex-sorted by y then x) for ``ndim=2``, every location in
[0, 1).  ``tests/test_assim.py`` enforces this for every registered name,
so a new scenario gets its determinism/shape coverage for free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional

import numpy as np

from repro.data import observations as obs_mod


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """A registered scenario: ``fn(m, cycles, seed)`` yielding locations."""

    name: str
    fn: Callable[..., Iterator[np.ndarray]]
    doc: str
    ndim: int = 1


_REGISTRY: dict = {}


def register(name: str, ndim: int = 1):
    """Register a scenario generator under ``name`` for an ndim-D domain."""
    if ndim not in (1, 2):
        raise ValueError(f"ndim must be 1 or 2 (got {ndim})")

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"stream scenario {name!r} already registered")
        _REGISTRY[name] = StreamSpec(name=name, fn=fn,
                                     doc=(fn.__doc__ or "").strip(),
                                     ndim=ndim)
        return fn
    return deco


def available(ndim: Optional[int] = None) -> tuple:
    """Sorted names of registered scenarios, optionally filtered by ndim."""
    return tuple(sorted(n for n, s in _REGISTRY.items()
                        if ndim is None or s.ndim == ndim))


def get(name: str) -> StreamSpec:
    if name not in _REGISTRY:
        raise ValueError(f"unknown stream scenario {name!r}; "
                         f"available: {available()}")
    return _REGISTRY[name]


def make_stream(name: str, m: int, cycles: int, seed: int = 0,
                **kw) -> Iterator[np.ndarray]:
    """Instantiate scenario ``name`` as an iterator of per-cycle locations."""
    spec = get(name)
    want_shape = (m,) if spec.ndim == 1 else (m, 2)

    def checked():
        count = 0
        for obs in spec.fn(m, cycles, seed, **kw):
            obs = np.asarray(obs, dtype=np.float64)
            assert obs.shape == want_shape, (name, obs.shape)
            yield obs
            count += 1
        assert count == cycles, (name, count, cycles)

    return checked()


class ResumableStream:
    """A stream with a serializable cursor, for checkpoint/resume.

    Wraps the checked scenario iterator and counts cycles consumed.
    ``cursor`` is a JSON-ready dict (scenario name, m, cycles, seed,
    extra kwargs, and the position); :meth:`from_cursor` rebuilds the
    stream and *fast-forwards* it — scenarios are seeded and
    deterministic, so re-drawing and discarding the first ``pos``
    arrays reproduces the generator's internal state exactly without
    replaying any solves.  This is what lets engine resume continue a
    stream bitwise from the cycle after the snapshot.
    """

    def __init__(self, name: str, m: int, cycles: int, seed: int = 0,
                 **kw):
        self.name, self.m, self.cycles, self.seed = name, m, cycles, seed
        self.kw = dict(kw)
        self.pos = 0
        self._it = make_stream(name, m, cycles, seed, **kw)

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        obs = next(self._it)
        self.pos += 1
        return obs

    @property
    def cursor(self) -> dict:
        return {"name": self.name, "m": int(self.m),
                "cycles": int(self.cycles), "seed": int(self.seed),
                "pos": int(self.pos), "kw": dict(self.kw)}

    @classmethod
    def from_cursor(cls, cursor: dict) -> "ResumableStream":
        s = cls(cursor["name"], int(cursor["m"]), int(cursor["cycles"]),
                int(cursor["seed"]), **cursor.get("kw", {}))
        for _ in range(int(cursor["pos"])):   # fast-forward, no solves
            next(s._it)
            s.pos += 1
        return s

    def remaining(self) -> int:
        return self.cycles - self.pos


def _finalize(obs: np.ndarray) -> np.ndarray:
    return np.sort(np.clip(obs, 0.0, np.nextafter(1.0, 0.0)))


def _finalize_2d(pts: np.ndarray) -> np.ndarray:
    """Clip to [0, 1)² and lex-sort by (y, x) for determinism."""
    pts = np.clip(pts, 0.0, np.nextafter(1.0, 0.0))
    return pts[np.lexsort((pts[:, 0], pts[:, 1]))]


# ---------------------------------------------------------------------------
# 1D scenarios.
# ---------------------------------------------------------------------------

@register("drifting_swarm")
def drifting_swarm(m, cycles, seed, width=0.08, start=0.15, stop=0.85):
    """A tight sensor swarm drifting across the domain over the run —
    the configuration that collapses a static DD to E ~ 0."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        center = start + (stop - start) * c / max(cycles - 1, 1)
        yield _finalize(center + width * rng.normal(size=m))


@register("bursty_clusters")
def bursty_clusters(m, cycles, seed, max_clusters=3):
    """A few clusters whose positions re-draw every cycle and whose mass is
    bursty: one dominant cluster absorbs most sensors each cycle."""
    rng = np.random.default_rng(seed)
    for _ in range(cycles):
        k = int(rng.integers(1, max_clusters + 1))
        centers = rng.uniform(0.05, 0.95, k)
        weights = rng.dirichlet(0.35 * np.ones(k))
        which = rng.choice(k, size=m, p=weights)
        yield _finalize(centers[which] + 0.04 * rng.normal(size=m))


@register("sensor_dropout")
def sensor_dropout(m, cycles, seed, p=8):
    """Uniform coverage that loses a growing contiguous block of sensors
    mid-run — whole subdomains go empty, exercising the DyDD DD-step
    (split-the-loaded-neighbour repartition) — then recovers."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        obs = rng.uniform(0, 1, m)
        # Outage window: middle third of the run, blacking out an expanding
        # range of the p-way uniform intervals.
        lo, hi = cycles // 3, max(2 * cycles // 3, cycles // 3 + 1)
        if lo <= c < hi:
            n_dead = min(1 + (c - lo), p - 1)
            dead = tuple(range(n_dead))
            obs = obs_mod.squeeze_out_of_subdomains(obs, dead, p, rng)
        yield _finalize(obs)


@register("diurnal")
def diurnal(m, cycles, seed, period=8, width=0.10):
    """A diurnal oscillation: the observation mass swings back and forth
    across the domain sinusoidally, breathing wider at the turning points."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        phase = 2.0 * np.pi * c / period
        center = 0.5 + 0.35 * np.sin(phase)
        w = width * (1.0 + 0.5 * np.abs(np.cos(phase)))
        yield _finalize(center + w * rng.normal(size=m))


@register("storm_front")
def storm_front(m, cycles, seed, background_frac=0.3):
    """Composite 'storm front': a sparse uniform background network plus a
    sharp front sweeping the domain, intensifying mid-run (drawing sensors
    away from the background) and knocking out coverage behind it."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        t = c / max(cycles - 1, 1)
        front = 0.1 + 0.8 * t
        # Intensity peaks mid-run: the front recruits up to ~90% of sensors.
        intensity = np.sin(np.pi * t)
        m_front = int(m * (1.0 - background_frac) * intensity)
        m_bg = m - m_front
        storm = front + 0.03 * rng.normal(size=m_front)
        # Behind the front the network is knocked out: background sensors
        # only survive ahead of it (and a thin recovering strip at the far
        # left edge).
        bg = np.concatenate([
            rng.uniform(min(front + 0.05, 0.95), 1.0, (2 * m_bg) // 3),
            rng.uniform(0.0, 0.05, m_bg - (2 * m_bg) // 3),
        ])
        yield _finalize(np.concatenate([storm, bg]))


# ---------------------------------------------------------------------------
# 2D scenarios (the paper's Ω ⊂ R² setting, Figures 1-4).
# ---------------------------------------------------------------------------

@register("storm_front_2d", ndim=2)
def storm_front_2d(m, cycles, seed, background_frac=0.25):
    """A storm front sweeping the plane diagonally (lower-left to
    upper-right): a dense band of sensors rides the front line while a
    sparse background survives only ahead of it.  The front keeps moving
    through the final cycle, so a static tiling ends badly unbalanced."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        t = c / max(cycles - 1, 1)
        d = 0.15 + 0.7 * t                      # front offset along x + y
        m_front = int(m * (1.0 - background_frac))
        m_bg = m - m_front
        # Band perpendicular to the (1, 1) sweep direction.
        along = rng.uniform(-0.5, 0.5, m_front)
        across = 0.03 * rng.normal(size=m_front)
        fx = d + along + across
        fy = d - along + across
        # Background only ahead of the front (x + y > 2d).
        bx = rng.uniform(0, 1, 4 * m_bg)
        by = rng.uniform(0, 1, 4 * m_bg)
        ahead = np.where(bx + by > 2 * d)[0][:m_bg]
        if ahead.size < m_bg:  # late cycles: fall back to the far corner
            extra = m_bg - ahead.size
            bx = np.concatenate([bx[ahead], rng.uniform(0.9, 1.0, extra)])
            by = np.concatenate([by[ahead], rng.uniform(0.9, 1.0, extra)])
        else:
            bx, by = bx[ahead], by[ahead]
        pts = np.stack([np.concatenate([fx, bx]),
                        np.concatenate([fy, by])], axis=1)
        yield _finalize_2d(pts)


@register("rotating_swarm", ndim=2)
def rotating_swarm(m, cycles, seed, radius=0.3, width=0.06):
    """A tight sensor swarm orbiting the domain center — every cycle the
    mass sits in a different cell of any static tiling."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        phase = 2.0 * np.pi * c / max(cycles, 1)
        cx = 0.5 + radius * np.cos(phase)
        cy = 0.5 + radius * np.sin(phase)
        pts = np.stack([cx + width * rng.normal(size=m),
                        cy + width * rng.normal(size=m)], axis=1)
        yield _finalize_2d(pts)


@register("coastal_band", ndim=2)
def coastal_band(m, cycles, seed, amplitude=0.2, width=0.05):
    """A coastal observation band: sensors hug a sinusoidal 'shoreline'
    whose phase drifts across the run (a shelf boundary shifting in both
    axes — the Figure 2-4 configuration)."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        phase = 2.0 * np.pi * c / max(2 * cycles, 1)
        x = rng.uniform(0, 1, m)
        coast = 0.5 + amplitude * np.sin(2.0 * np.pi * x + phase) \
            + 0.25 * (c / max(cycles - 1, 1))
        y = coast + width * rng.normal(size=m)
        yield _finalize_2d(np.stack([x, y], axis=1))


@register("satellite_track", ndim=2)
def satellite_track(m, cycles, seed, tracks=3, stations=8, width=0.02):
    """Polar-orbit ground tracks: all mass rides a few thin diagonal
    swaths whose phase precesses each cycle, sampled at a fixed set of
    along-track stations — so the x coordinates are *quantized* (heavy
    ties) and the network is strongly anisotropic.  A shelf tiling
    wastes cells on the empty area between swaths and cannot split a
    heavy station column except at a global strip boundary; the k-d
    domain splits it locally along y."""
    rng = np.random.default_rng(seed)
    xg = (np.arange(stations) + 0.5) / stations
    for c in range(cycles):
        phase = 0.13 * c
        k = rng.integers(0, tracks, m)
        weights = rng.dirichlet(0.5 * np.ones(stations))
        x = xg[rng.choice(stations, size=m, p=weights)]
        y = np.mod(x + k / tracks + phase, 1.0) \
            + width * rng.normal(size=m)
        yield _finalize_2d(np.stack([x, y], axis=1))


@register("river_gauges", ndim=2)
def river_gauges(m, cycles, seed, gauges=10, width=0.015):
    """Stream gauges on a meandering river: observations sit at a fixed
    set of gauge stations (tied x) along a curved band y = f(x), and a
    flood pulse travels downstream through the run, concentrating the
    sampling mass gauge by gauge — a curved, strongly anisotropic
    network whose hot spot moves every cycle."""
    rng = np.random.default_rng(seed)
    xg = np.sort(rng.uniform(0.05, 0.95, gauges))
    for c in range(cycles):
        t = c / max(cycles - 1, 1)
        pulse = 0.1 + 0.8 * t
        w = np.exp(-((xg - pulse) / 0.25) ** 2) + 0.15
        x = xg[rng.choice(gauges, size=m, p=w / w.sum())]
        y = 0.5 + 0.3 * np.sin(2.2 * np.pi * x + 0.4) \
            + width * rng.normal(size=m)
        yield _finalize_2d(np.stack([x, y], axis=1))


@register("grid_dropout", ndim=2)
def grid_dropout(m, cycles, seed, pr=2, pc=2):
    """A uniform 2D sensor network that loses a growing rectangle of
    pr x pc tiling cells mid-run — whole cells go empty (Figure 1's
    configuration, exercising the empty-cell DD-step) and the outage
    persists through the final cycle."""
    rng = np.random.default_rng(seed)
    lo = cycles // 3
    for c in range(cycles):
        pts = rng.uniform(0, 1, (m, 2))
        if c >= lo:
            # Dead rectangle of cells grows from the lower-left corner:
            # first along x to the full row, then up rows — never the
            # whole domain (the top strip always survives).
            k = c - lo
            kc = min(1 + k, pc)
            kr = min(1 + max(k - (pc - 1), 0), max(pr - 1, 1))
            pts = obs_mod.squeeze_out_of_rect(pts, kc / pc, kr / pr, rng)
        yield _finalize_2d(pts)
