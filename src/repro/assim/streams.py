"""Observation-stream scenarios for the streaming assimilation engine.

A *stream* is a named, seeded generator of per-cycle observation locations
in [0, 1) — the moving observation network the paper's conclusion names as
future work.  Every scenario is registered under a name so engines, tests
and benchmarks can sweep the whole registry:

    for name in streams.available():
        for obs in streams.make_stream(name, m=400, cycles=6, seed=0):
            ...  # obs is a sorted (m,) float array in [0, 1)

Adding a scenario is one decorated function::

    @register("my_scenario")
    def my_scenario(m, cycles, seed):
        rng = np.random.default_rng(seed)
        for c in range(cycles):
            yield np.sort(rng.uniform(0, 1, m))

Contract: a scenario must be deterministic under a fixed ``seed``, yield
exactly ``cycles`` arrays of shape ``(m,)``, sorted, with every location
in [0, 1).  ``tests/test_assim.py`` enforces this for every registered
name, so a new scenario gets its determinism/shape coverage for free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np

from repro.data import observations as obs_mod


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """A registered scenario: ``fn(m, cycles, seed)`` yielding locations."""

    name: str
    fn: Callable[..., Iterator[np.ndarray]]
    doc: str


_REGISTRY: dict = {}


def register(name: str):
    """Register a scenario generator under ``name``."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"stream scenario {name!r} already registered")
        _REGISTRY[name] = StreamSpec(name=name, fn=fn,
                                     doc=(fn.__doc__ or "").strip())
        return fn
    return deco


def available() -> tuple:
    """Sorted names of all registered scenarios."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> StreamSpec:
    if name not in _REGISTRY:
        raise ValueError(f"unknown stream scenario {name!r}; "
                         f"available: {available()}")
    return _REGISTRY[name]


def make_stream(name: str, m: int, cycles: int, seed: int = 0,
                **kw) -> Iterator[np.ndarray]:
    """Instantiate scenario ``name`` as an iterator of per-cycle locations."""
    spec = get(name)

    def checked():
        count = 0
        for obs in spec.fn(m, cycles, seed, **kw):
            obs = np.asarray(obs, dtype=np.float64)
            assert obs.shape == (m,), (name, obs.shape)
            yield obs
            count += 1
        assert count == cycles, (name, count, cycles)

    return checked()


def _finalize(obs: np.ndarray) -> np.ndarray:
    return np.sort(np.clip(obs, 0.0, np.nextafter(1.0, 0.0)))


# ---------------------------------------------------------------------------
# Scenarios.
# ---------------------------------------------------------------------------

@register("drifting_swarm")
def drifting_swarm(m, cycles, seed, width=0.08, start=0.15, stop=0.85):
    """A tight sensor swarm drifting across the domain over the run —
    the configuration that collapses a static DD to E ~ 0."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        center = start + (stop - start) * c / max(cycles - 1, 1)
        yield _finalize(center + width * rng.normal(size=m))


@register("bursty_clusters")
def bursty_clusters(m, cycles, seed, max_clusters=3):
    """A few clusters whose positions re-draw every cycle and whose mass is
    bursty: one dominant cluster absorbs most sensors each cycle."""
    rng = np.random.default_rng(seed)
    for _ in range(cycles):
        k = int(rng.integers(1, max_clusters + 1))
        centers = rng.uniform(0.05, 0.95, k)
        weights = rng.dirichlet(0.35 * np.ones(k))
        which = rng.choice(k, size=m, p=weights)
        yield _finalize(centers[which] + 0.04 * rng.normal(size=m))


@register("sensor_dropout")
def sensor_dropout(m, cycles, seed, p=8):
    """Uniform coverage that loses a growing contiguous block of sensors
    mid-run — whole subdomains go empty, exercising the DyDD DD-step
    (split-the-loaded-neighbour repartition) — then recovers."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        obs = rng.uniform(0, 1, m)
        # Outage window: middle third of the run, blacking out an expanding
        # range of the p-way uniform intervals.
        lo, hi = cycles // 3, max(2 * cycles // 3, cycles // 3 + 1)
        if lo <= c < hi:
            n_dead = min(1 + (c - lo), p - 1)
            dead = tuple(range(n_dead))
            obs = obs_mod.squeeze_out_of_subdomains(obs, dead, p, rng)
        yield _finalize(obs)


@register("diurnal")
def diurnal(m, cycles, seed, period=8, width=0.10):
    """A diurnal oscillation: the observation mass swings back and forth
    across the domain sinusoidally, breathing wider at the turning points."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        phase = 2.0 * np.pi * c / period
        center = 0.5 + 0.35 * np.sin(phase)
        w = width * (1.0 + 0.5 * np.abs(np.cos(phase)))
        yield _finalize(center + w * rng.normal(size=m))


@register("storm_front")
def storm_front(m, cycles, seed, background_frac=0.3):
    """Composite 'storm front': a sparse uniform background network plus a
    sharp front sweeping the domain, intensifying mid-run (drawing sensors
    away from the background) and knocking out coverage behind it."""
    rng = np.random.default_rng(seed)
    for c in range(cycles):
        t = c / max(cycles - 1, 1)
        front = 0.1 + 0.8 * t
        # Intensity peaks mid-run: the front recruits up to ~90% of sensors.
        intensity = np.sin(np.pi * t)
        m_front = int(m * (1.0 - background_frac) * intensity)
        m_bg = m - m_front
        storm = front + 0.03 * rng.normal(size=m_front)
        # Behind the front the network is knocked out: background sensors
        # only survive ahead of it (and a thin recovering strip at the far
        # left edge).
        bg = np.concatenate([
            rng.uniform(min(front + 0.05, 0.95), 1.0, (2 * m_bg) // 3),
            rng.uniform(0.0, 0.05, m_bg - (2 * m_bg) // 3),
        ])
        yield _finalize(np.concatenate([storm, bg]))
