"""Parallel-in-time assimilation: a time-windowed Parareal engine.

The sequential engine is strictly serial in time — cycle t+1's rhs needs
cycle t's analysis — while everything *else* about a cycle (the DyDD
decision, the repartition, the operator packing, the observation data)
depends only on the stream and the boundary state.  This module exploits
that split, following the DD-DA space-time companions of the source
paper (PAPERS.md: arXiv:2312.00007, arXiv:1807.07107):

  1. **Prepare sweep** — run :meth:`AssimilationEngine.prepare` for every
     cycle of the stream up front, sequentially.  This replays the exact
     rng/domain/truth mutation chain of the sequential engine (per-window
     DyDD is the same DyDD: each window's repartitions flow through this
     one sweep), so the packed operators are bitwise identical to the
     sequential run's; only the backgrounds are unknown.
  2. **Coarse sweep** — a cheap sequential pass (``pint_coarse_iters``
     Schwarz iterations per cycle, default iters//10) chains approximate
     window-boundary states b_w through the stream.
  3. **Fine sweeps, in parallel across windows** — each Parareal
     iteration propagates every window from its current boundary state
     with the *full* solver, all windows at once: the per-cycle packings
     are width-padded (:func:`ddkf.pad_packed_width`), stacked
     (:func:`ddkf.stack_packed`) and solved on a ``("time", "sub")``
     device mesh (:func:`ddkf.solve_window_stack` — windows shard over
     ``time``, subdomains over ``sub``), multiplying the usable device
     count beyond the p-subdomain cap.  With ``pint_fine_iters > 0``
     each fine solve warm-starts from the coarse trajectory of the same
     cycle (``x0=`` on the solve entry points) and runs only that many
     Schwarz iterations — coarse + fine iterations together buy the
     accuracy (the work-optimal Parareal variant; the default 0 keeps
     fine solves cold at the full ``iters``).
  4. **Parareal correction** — sequentially update the boundary states
     ``b_{w+1} <- F(b_w) + G(b_w^new) - G(b_w^old)`` and journal the max
     correction norm per iteration; stop when it drops under
     ``pint_tol`` (the per-cycle map is affine and strongly contracting
     in the background — the prior rows outweigh it — so this converges
     in a few iterations, and in at most W by Parareal's finite
     termination).

Contract: **tolerance, not bitwise** — the windowed analysis chain
matches the sequential engine's within ``pint_tol`` (plus reduction-
order ULPs from the padded/stacked solves).  The degenerate settings
``time_windows=1`` or ``pint_max_iters=0`` skip all of the above and run
the sequential engine itself: bitwise identity by construction.

Checkpoints land on *window boundaries*: the prepare sweep stashes the
host-side state (:meth:`AssimilationEngine.host_state`) at each
boundary, and the ordered completion phase assembles a
``SNAPSHOT_VERSION=2`` checkpoint from it (``snapshot_every`` counts
windows here, not cycles).  A resumed engine continues sequentially from
the boundary.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

import numpy as np
import jax

from repro.core import ddkf as ddkf_mod
from repro.core import _compat as compat_mod
from repro.obs import meters as meters_mod
from repro.obs import trace as trace_mod
from repro.runtime import chaos as chaos_mod
from repro.assim.engine import AssimilationEngine, CycleStep, EngineConfig
from repro.assim.metrics import Journal
from repro.assim import streams as streams_mod


def window_bounds(cycles: int, windows: int) -> list:
    """Near-even partition of ``cycles`` into ``windows`` contiguous
    windows: W+1 boundary indices (window w is [bounds[w], bounds[w+1])).
    Pure function of the two counts — the window ids journalled per
    cycle are deterministic."""
    W = max(1, min(int(windows), int(cycles)))
    return [cycles * w // W for w in range(W + 1)]


def resolve_time_mesh(time_windows: int, p: int, time_axis: str = "time",
                      sub_axis: str = "sub"):
    """Build a ``("time", "sub")`` mesh over all visible devices, or
    None when the device count does not factor (the caller falls back to
    a single-dispatch ``lax.map`` over windows).

    Picks the largest time-axis size kt such that kt divides the device
    count, kt covers at most ``time_windows`` windows, and the remaining
    ks = ndev/kt divides p (``solve_window_stack`` needs both axes to
    divide their problem dimension)."""
    ndev = len(jax.devices())
    for kt in range(min(int(time_windows), ndev), 0, -1):
        if ndev % kt:
            continue
        ks = ndev // kt
        if p % ks == 0:
            return compat_mod.make_device_mesh((kt, ks),
                                               (time_axis, sub_axis))
    return None


class TimeParEngine:
    """Time-windowed Parareal driver around an :class:`AssimilationEngine`.

    Usage::

        cfg = EngineConfig(n=128, p=2, iters=120, time_windows=4)
        eng = TimeParEngine(cfg)
        journal = eng.run(streams.make_stream("drifting_swarm", 400, 16))
        eng.analyses          # per-cycle analysis chain (np arrays)
        journal.meta["pint"]  # iterations, correction norms, convergence

    The inner engine journals every cycle exactly as the sequential
    engine does (same phases, same comm accounting, window-tagged
    records); ``journal.meta["pint"]`` carries the Parareal evidence.
    With ``time_windows=1`` or ``pint_max_iters=0`` the run *is* the
    sequential engine (bitwise identical journal, no pint meta).

    ``mesh`` (optional) must carry the ``time``/``sub`` axes; by default
    one is built over all visible devices when the device count factors
    (:func:`resolve_time_mesh`), else the fine sweeps run as one
    ``lax.map`` dispatch per window-step on the default device.
    """

    def __init__(self, config: EngineConfig,
                 forecast: Optional[Callable] = None,
                 domain=None, mesh=None,
                 time_axis: str = "time", sub_axis: str = "sub",
                 chaos: "chaos_mod.ChaosInjector | None" = None):
        self.cfg = config
        self.time_axis = time_axis
        self.sub_axis = sub_axis
        self._degenerate = (config.time_windows <= 1
                            or config.pint_max_iters == 0)
        # The windowed path dispatches fine solves itself through the
        # window-stacked entry point; the inner engine only prepares,
        # journals and (in degenerate mode) runs — so it stays on the
        # single-dispatch solver.
        eng_cfg = config if self._degenerate else dataclasses.replace(
            config, solver="vmapped")
        self.engine = AssimilationEngine(eng_cfg, forecast=forecast,
                                         domain=domain, chaos=chaos)
        if mesh is not None:
            for ax in (time_axis, sub_axis):
                if ax not in mesh.shape:
                    raise ValueError(
                        f"mesh is missing the {ax!r} axis (has "
                        f"{tuple(mesh.shape)})")
            if self.engine.p % int(mesh.shape[sub_axis]):
                raise ValueError(
                    f"p={self.engine.p} subdomains do not divide over "
                    f"the {int(mesh.shape[sub_axis])}-device "
                    f"'{sub_axis}' mesh axis")
        self.mesh = mesh if not self._degenerate else None
        self._auto_mesh = mesh is None
        self.analyses: list = []
        self.engine.on_analysis = \
            lambda cycle, x: self.analyses.append(np.asarray(x))

    # -- conveniences mirroring the sequential engine ----------------------

    @property
    def journal(self) -> Journal:
        return self.engine.journal

    @property
    def analysis(self):
        return self.engine.analysis

    def run_scenario(self, name: str, m: int, cycles: int,
                     seed: int = 0, **kw) -> Journal:
        spec = streams_mod.get(name)
        if spec.ndim != self.engine.domain.ndim:
            raise ValueError(
                f"scenario {name!r} is {spec.ndim}D but the engine "
                f"domain is {self.engine.domain.ndim}D")
        return self.run(streams_mod.make_stream(name, m, cycles,
                                                seed=seed, **kw))

    # -- driver -------------------------------------------------------------

    def run(self, stream: Iterable[np.ndarray], *,
            checkpoint_dir: str | None = None,
            snapshot_every: int = 0) -> Journal:
        """Consume the stream to exhaustion; returns the journal.

        Degenerate configs (``time_windows=1`` / ``pint_max_iters=0``)
        delegate to :meth:`AssimilationEngine.run` unchanged — including
        its per-cycle snapshot cadence.  The windowed path snapshots on
        window boundaries instead, every ``snapshot_every`` *windows*.
        """
        if self._degenerate:
            return self.engine.run(stream, checkpoint_dir=checkpoint_dir,
                                   snapshot_every=snapshot_every)
        return self._run_windowed(stream, checkpoint_dir, snapshot_every)

    def _background(self, x) -> np.ndarray:
        eng = self.engine
        return (np.zeros(eng.n) if x is None
                else np.asarray(eng.forecast(x)))

    def _coarse_window(self, preps, bounds, w: int, x):
        """Chain the coarse propagator through window w from boundary
        state ``x`` (None = cold zero background).  Returns the end
        state plus the per-cycle coarse trajectory — the warm starts the
        next fine sweep of this window reuses."""
        cfg = self.cfg
        coarse_iters = cfg.pint_coarse_iters or max(1, cfg.iters // 10)
        traj = []
        for c in range(bounds[w], bounds[w + 1]):
            prep = preps[c]
            bg = self._background(x)
            # The stream-wide padded operator (one solver program per
            # run instead of one per distinct DyDD block width; the
            # coarse propagator is a tolerance path already).
            packed = ddkf_mod.with_rhs(
                self._padded_ops[c],
                np.concatenate([prep.H0 @ bg, prep.y1]))
            x = ddkf_mod.solve_vmapped(packed, iters=coarse_iters,
                                       damping=cfg.damping)
            traj.append(np.asarray(jax.block_until_ready(x)))
        return traj[-1], traj

    def _solve_stack(self, packs: list, x0s=None) -> np.ndarray:
        """One fine dispatch for a same-shape group of active windows.

        ``x0s`` (optional, one (n,) array per pack) warm-starts each
        window's solve — set only when ``pint_fine_iters`` trims the
        fine iteration count; the default cold full-``iters`` sweep
        passes None and keeps the historic zero start."""
        cfg = self.cfg
        iters = cfg.pint_fine_iters or cfg.iters
        if self.mesh is not None:
            kt = int(self.mesh.shape[self.time_axis])
            pad = (-len(packs)) % kt
            stacked = ddkf_mod.stack_packed(packs + [packs[0]] * pad)
            x0 = (None if x0s is None
                  else np.stack(list(x0s) + [x0s[0]] * pad))
            xs = ddkf_mod.solve_window_stack(
                stacked, self.mesh, time_axis=self.time_axis,
                sub_axis=self.sub_axis, iters=iters,
                damping=cfg.damping, x0=x0)
            return np.asarray(jax.block_until_ready(xs))[:len(packs)]
        stacked = ddkf_mod.stack_packed(packs)
        x0 = None if x0s is None else np.stack(x0s)
        return np.asarray(jax.block_until_ready(ddkf_mod.solve_fleet(
            stacked, iters=iters, damping=cfg.damping, x0=x0)))

    def _fine_sweep(self, preps, bounds, b_in, coarse_traj=None):
        """Propagate every window from its boundary state with the full
        solver, windows advancing in lockstep (window-step j solves one
        cycle of every still-active window in one stacked dispatch).

        When ``pint_fine_iters`` is set, ``coarse_traj`` (per-window
        per-cycle coarse analyses, computed from the *same* boundary
        states ``b_in``) warm-starts every solve: the fine sweep then
        only spends the iterations that close the coarse-to-fine gap
        instead of re-converging from zero.

        Returns (per-window end states, per-cycle analyses/backgrounds/
        solve-time shares)."""
        W = len(bounds) - 1
        lens = [bounds[w + 1] - bounds[w] for w in range(W)]
        x = list(b_in[:W])
        C = len(preps)
        warm = self.cfg.pint_fine_iters > 0 and coarse_traj is not None
        analyses = [None] * C
        backgrounds = [None] * C
        solve_times = [0.0] * C
        for j in range(max(lens)):
            active = [w for w in range(W) if lens[w] > j]
            # Same-shape grouping: DyDD can change the max block width
            # mid-stream and scenarios can vary the per-cycle row count,
            # so bucket by the stack key (width already padded to the
            # stream-wide max).
            groups: dict = {}
            bgs = {}
            for w in active:
                c = bounds[w] + j
                prep = preps[c]
                bg = self._background(x[w])
                bgs[w] = bg
                pk = ddkf_mod.with_rhs(
                    self._padded_ops[c],
                    np.concatenate([prep.H0 @ bg, prep.y1]))
                key = (pk.m, pk.w, pk.solve_block)
                groups.setdefault(key, []).append((w, pk))
            t0 = time.perf_counter()
            for grp in groups.values():
                x0s = ([coarse_traj[w][j] for w, _ in grp] if warm
                       else None)
                xs = self._solve_stack([pk for _, pk in grp], x0s=x0s)
                for (w, _), xw in zip(grp, xs):
                    x[w] = np.asarray(xw)
            dt = (time.perf_counter() - t0) / max(len(active), 1)
            for w in active:
                c = bounds[w] + j
                analyses[c] = x[w]
                backgrounds[c] = bgs[w]
                solve_times[c] = dt
        return x, analyses, backgrounds, solve_times

    def _run_windowed(self, stream, checkpoint_dir, snapshot_every):
        eng = self.engine
        cfg = self.cfg
        retries = max(cfg.solve_retries, 0)
        eng._stream = stream if hasattr(stream, "cursor") else None
        pos0 = getattr(stream, "pos", 0)
        obs_list = list(stream)
        C = len(obs_list)
        if C == 0:
            return eng.journal
        base = len(eng.journal.records)
        bounds = window_bounds(C, cfg.time_windows)
        W = len(bounds) - 1
        lens = [bounds[w + 1] - bounds[w] for w in range(W)]
        if self._auto_mesh:
            self.mesh = resolve_time_mesh(W, eng.p, self.time_axis,
                                          self.sub_axis)
        eng.reset_clock()
        m = meters_mod.get_meters()

        # -- 1. prepare sweep (the sequential engine's exact mutation
        # chain), stashing host state at each window boundary ------------
        steps: list = []
        window_host: dict = {}
        with trace_mod.span("pint.prepare", cycles=C, windows=W):
            for w in range(W):
                for c in range(bounds[w], bounds[w + 1]):
                    step = CycleStep(cycle=base + c, obs=obs_list[c],
                                     window=w)
                    step.prep = chaos_mod.retry_transient(
                        lambda: eng.prepare(step.cycle, step.obs,
                                            window=step.window),
                        retries=retries, site="pack", cycle=step.cycle)
                    steps.append(step)
                hs = eng.host_state()
                if hs["cursor"] is not None:
                    # The stream is fully drained; rewind the recorded
                    # cursor to this boundary so resume fast-forwards to
                    # exactly here.
                    hs["cursor"]["pos"] = pos0 + bounds[w + 1]
                window_host[w] = hs
        preps = [s.prep for s in steps]
        self._w_max = max(p.packed_op.w for p in preps)
        # Width-padded operators, built once: both sweeps re-solve each
        # cycle every Parareal iteration, and padding is boundary-state
        # independent.
        self._padded_ops = [
            ddkf_mod.pad_packed_width(p.packed_op, self._w_max)
            for p in preps]

        # -- 2. coarse init sweep ----------------------------------------
        b = [None] * (W + 1)
        b[0] = (None if eng.analysis is None
                else np.asarray(eng.analysis))
        G_old = [None] * W
        G_traj = [None] * W
        with trace_mod.span("pint.coarse", windows=W):
            for w in range(W):
                G_old[w], G_traj[w] = self._coarse_window(preps, bounds,
                                                          w, b[w])
                b[w + 1] = G_old[w]

        # -- 3./4. Parareal iterations -----------------------------------
        correction_norms: list = []
        converged = False
        analyses = backgrounds = solve_times = None
        iters_done = 0
        for k in range(cfg.pint_max_iters):
            with trace_mod.span("pint.fine", iteration=k, windows=W):
                F_end, analyses, backgrounds, solve_times = \
                    self._fine_sweep(preps, bounds, b, G_traj)
            iters_done = k + 1
            m.inc("pint.iterations")
            with trace_mod.span("pint.correct", iteration=k):
                new_b = [b[0]] + [None] * W
                max_corr = 0.0
                for w in range(W):
                    g_new, G_traj[w] = self._coarse_window(
                        preps, bounds, w, new_b[w])
                    s = F_end[w] + g_new - G_old[w]
                    G_old[w] = g_new
                    max_corr = max(max_corr, float(np.max(np.abs(
                        s - b[w + 1]))))
                    new_b[w + 1] = s
                b = new_b
            correction_norms.append(max_corr)
            m.observe("pint.correction_norm", max_corr)
            if max_corr <= cfg.pint_tol:
                converged = True
                break
        m.event("pint.converged" if converged else "pint.exhausted",
                iters=iters_done, windows=W,
                final_norm=correction_norms[-1])

        # The pint evidence is deterministic given (stream, seed,
        # config) — it lives in the journal meta and survives the
        # bitwise deterministic view (sequential-vs-resumed comparisons
        # never mix engines).
        eng.journal.meta["pint"] = {
            "time_windows": W,
            "window_sizes": lens,
            "coarse_iters": (cfg.pint_coarse_iters
                             or max(1, cfg.iters // 10)),
            "fine_iters": cfg.pint_fine_iters or cfg.iters,
            "warm_start": bool(cfg.pint_fine_iters),
            "iters": iters_done,
            "max_iters": cfg.pint_max_iters,
            "correction_norms": [float(v) for v in correction_norms],
            "converged": bool(converged),
            "tol": float(cfg.pint_tol),
            "mesh": (dict((str(a), int(s)) for a, s in
                          self.mesh.shape.items())
                     if self.mesh is not None else None),
        }

        # -- 5. ordered completion: journal every cycle with the last
        # fine sweep's analyses; checkpoints on window boundaries --------
        for c, step in enumerate(steps):
            step.analysis = analyses[c]
            step.background = backgrounds[c]
            step.solve_time = solve_times[c]
            eng.finish_step(step)
            w = step.window
            if (c + 1 == bounds[w + 1] and checkpoint_dir is not None
                    and snapshot_every > 0
                    and (w + 1) % snapshot_every == 0):
                eng.save_checkpoint(
                    checkpoint_dir, step=base + c + 1,
                    host_state=window_host[w],
                    extra_meta={"pint": {"window": w,
                                         "time_windows": W}})
        return eng.journal
