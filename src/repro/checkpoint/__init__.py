"""Fault-tolerant checkpointing: atomic writes, async, elastic re-shard."""
from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager, save_pytree, restore_pytree)
