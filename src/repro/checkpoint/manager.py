"""Checkpointing designed for 1000+-node fault tolerance (DESIGN.md §8).

Properties:
  * **atomic**: a checkpoint is written into ``step_XXXX.tmp`` and
    os.replace'd into place only after every leaf and the manifest (with a
    content hash) are durably on disk — a killed writer can never leave a
    half-checkpoint that restore would pick up;
  * **async**: ``CheckpointManager.save(..., blocking=False)`` hands the
    (host-fetched) arrays to a background thread, so the train loop only
    blocks for the device->host copy;
  * **mesh-agnostic / elastic**: leaves are stored unsharded by logical
    path; ``restore_pytree`` re-shards onto whatever mesh/sharding the
    restarted job provides (scale up/down between saves — property-tested);
  * **self-describing**: the manifest records tree structure, dtypes,
    shapes, step and user metadata (e.g. data-loader state), so restore
    needs no model code.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue
import shutil
import threading
from typing import Any

import numpy as np
import jax

from repro.obs import meters as meters_mod


_SEP = "/"


def _fsync_dir(path: str) -> None:
    """fsync a directory so the rename that just landed in it is durable
    (POSIX: os.replace orders the entry but does not persist it until
    the directory inode is synced).  Best-effort — some filesystems
    refuse O_RDONLY dir fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except (PermissionError, OSError):
        # exists but owned by someone else / unknown — assume live
        return True
    return True


def _tmp_writer_pid(name: str) -> int | None:
    """Parse the writer pid out of a ``step_X.{pid}-{tid}.tmp`` staging
    dir name; None if the name doesn't match that convention."""
    if not name.endswith(".tmp"):
        return None
    stem = name[:-len(".tmp")]
    tag = stem.rsplit(".", 1)
    if len(tag) != 2 or "-" not in tag[1]:
        return None
    pid_s = tag[1].split("-", 1)[0]
    return int(pid_s) if pid_s.isdigit() else None


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out, treedef


def save_pytree(tree, directory: str, step: int, metadata: dict | None
                = None) -> str:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    # unique tmp name: concurrent writers of the same step (async + final
    # blocking save) must not clobber each other's staging dir; os.replace
    # keeps the last completed one atomically.
    tmp = f"{final}.{os.getpid()}-{threading.get_ident()}.tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "metadata": metadata or {}}
    hasher = hashlib.sha256()
    for key in sorted(flat):
        arr = np.asarray(jax.device_get(flat[key]))
        fname = hashlib.sha1(key.encode()).hexdigest()[:16] + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        hasher.update(key.encode())
        hasher.update(arr.tobytes())
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    manifest["hash"] = hasher.hexdigest()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    # The rename is only crash-durable once the parent directory's inode
    # is on disk; without this a power cut can resurrect the pre-rename
    # state even though save() returned.
    _fsync_dir(directory)
    return final


def _load_manifest(path: str):
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def verify(path: str) -> bool:
    """Recompute the manifest hash; False for torn/corrupt checkpoints."""
    try:
        manifest = _load_manifest(path)
        hasher = hashlib.sha256()
        for key in sorted(manifest["leaves"]):
            info = manifest["leaves"][key]
            arr = np.load(os.path.join(path, info["file"]))
            hasher.update(key.encode())
            hasher.update(arr.tobytes())
        return hasher.hexdigest() == manifest["hash"]
    except Exception:
        return False


def restore_pytree(directory_or_path: str, like=None, shardings=None,
                   step: int | None = None):
    """Restore (optionally re-sharded).

    like: a pytree (arrays or ShapeDtypeStructs) giving the target
    structure; if None the flat {path: array} dict is returned.
    shardings: matching pytree of jax.sharding.Sharding — arrays are
    device_put with them (elastic re-shard onto the current mesh).
    Returns (tree, manifest).
    """
    path = directory_or_path
    if step is not None:
        path = os.path.join(directory_or_path, f"step_{step:08d}")
    elif not os.path.basename(path).startswith("step_"):
        path = latest_checkpoint(directory_or_path)
        if path is None:
            raise FileNotFoundError(f"no checkpoint in {directory_or_path}")
    manifest = _load_manifest(path)
    flat = {}
    for key, info in manifest["leaves"].items():
        flat[key] = np.load(os.path.join(path, info["file"]))
    if like is None:
        return flat, manifest

    flat_like, treedef = _flatten(like)
    missing = set(flat_like) - set(flat)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    if shardings is not None:
        flat_sh, _ = _flatten(shardings)
    leaves = []
    for key in flat_like:
        arr = flat[key]
        want = flat_like[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {want.shape}")
        arr = arr.astype(want.dtype)
        if shardings is not None and key in flat_sh and \
                flat_sh[key] is not None:
            arr = jax.device_put(arr, flat_sh[key])
        leaves.append(arr)
    # flat_like preserves canonical flatten order (insertion-ordered dict)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest


def latest_checkpoint(directory: str) -> str | None:
    """Newest checkpoint that passes hash verification (torn checkpoints
    and .tmp directories are skipped — the restart path after a crash)."""
    if not os.path.isdir(directory):
        return None
    cands = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp")
                   and os.path.isdir(os.path.join(directory, d)))
    for d in reversed(cands):
        path = os.path.join(directory, d)
        if verify(path):
            return path
        # Torn/corrupt candidate skipped — the event is the operator's
        # only signal that a checkpoint was silently lost to a crash.
        meters_mod.get_meters().event("checkpoint.corrupt_skipped",
                                      path=path)
        meters_mod.get_meters().inc("checkpoint.corrupt_skipped")
    return None


class CheckpointManager:
    """Async manager with retention and auto-resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._queue: "queue.Queue[tuple]" = queue.Queue()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: list = []

    def _run(self):
        while True:
            item = self._queue.get()
            if item is None:
                return
            tree, step, metadata = item
            try:
                save_pytree(tree, self.directory, step, metadata)
                self._gc()
            except Exception as e:
                # Surface at failure time, not just on wait(): an async
                # save that dies silently means the next crash loses far
                # more progress than the operator believes.
                self._errors.append(e)
                meters_mod.get_meters().event(
                    "checkpoint.save_failed", step=int(step),
                    error=f"{type(e).__name__}: {e}")
                meters_mod.get_meters().inc("checkpoint.save_failed")
            finally:
                self._queue.task_done()

    def _gc(self):
        entries = os.listdir(self.directory)
        cands = sorted(d for d in entries
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for d in cands[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)
        # Stale staging dirs from crashed writers accumulate forever
        # otherwise; skip dirs whose writer pid is still alive (another
        # process mid-save) and our own (this thread pool mid-save).
        for d in entries:
            pid = _tmp_writer_pid(d)
            if pid is None or pid == os.getpid() or _pid_alive(pid):
                continue
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)
            meters_mod.get_meters().inc("checkpoint.stale_tmp_removed")

    def save(self, tree, step: int, metadata: dict | None = None,
             blocking: bool = True):
        # fetch to host immediately (cheap, avoids racing live buffers)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        if blocking:
            return save_pytree(host_tree, self.directory, step, metadata)
        self._queue.put((host_tree, step, metadata))

    def wait(self):
        self._queue.join()
        if self._errors:
            raise self._errors.pop()

    def restore_latest(self, like=None, shardings=None):
        path = latest_checkpoint(self.directory)
        if path is None:
            return None
        return restore_pytree(path, like=like, shardings=shardings)

    def close(self):
        self._queue.put(None)
        self._worker.join(timeout=5)
