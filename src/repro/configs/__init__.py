"""Architecture configs (one module per assigned arch) + shape registry."""
from __future__ import annotations

import importlib

ARCHS = (
    "recurrentgemma_9b",
    "gemma_7b",
    "yi_6b",
    "gemma3_1b",
    "glm4_9b",
    "whisper_large_v3",
    "mixtral_8x22b",
    "olmoe_1b_7b",
    "phi3_vision_4_2b",
    "mamba2_1_3b",
)

# CLI ids (dashes) -> module names.
ARCH_IDS = {a.replace("_", "-"): a for a in ARCHS}
ARCH_IDS.update({a: a for a in ARCHS})
# canonical ids with dots / odd hyphenation
ARCH_IDS.update({
    "mamba2-1.3b": "mamba2_1_3b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "phi3-vision-4.2b": "phi3_vision_4_2b",
})


def get_config(arch: str):
    """Full-size ModelConfig for an arch id (dashes or underscores)."""
    mod = importlib.import_module(f"repro.configs.{ARCH_IDS[arch]}")
    return mod.config()


def get_smoke_config(arch: str):
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{ARCH_IDS[arch]}")
    return mod.smoke_config()
