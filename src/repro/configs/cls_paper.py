"""The paper's own experimental configuration (§6 'DyDD set up').

Omega subset R^2 (we use the 1D reduction for the reference stack — see
DESIGN.md §3), mesh size n = 2048, m observations, p = 2..64 subdomains.
The four validation examples correspond to the paper's Tables 1-12.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CLSCase:
    name: str
    n: int                 # mesh size (paper: 2048)
    m: int                 # observations
    p: int                 # subdomains / processors
    graph: str             # chain | star
    empty_subdomains: tuple = ()
    distribution: str = "beta"   # non-uniform sparse observations


EXAMPLE1 = (
    CLSCase("ex1_case1", 2048, 1500, 2, "chain"),
    CLSCase("ex1_case2", 2048, 1500, 2, "chain", empty_subdomains=(1,)),
)

EXAMPLE2 = tuple(
    CLSCase(f"ex2_case{k+1}", 2048, 1500, 4, "chain",
            empty_subdomains=tuple(range(k)))
    for k in range(4)
)

EXAMPLE3 = tuple(
    CLSCase(f"ex3_p{p}", 2048, 1032, p, "star") for p in (2, 4, 8, 16, 32)
)

EXAMPLE4 = tuple(
    CLSCase(f"ex4_p{p}", 2048, 2000, p, "chain") for p in (2, 4, 8, 16, 32)
)
