"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global attention (window 512), 128k context, GeGLU, head_dim=256,
dual rope theta (10k local / 1M global).  [hf:google/gemma-3-1b-pt]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense",
        num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1,
        head_dim=256, d_ff=6912, vocab_size=262144,
        act="gelu", gated_mlp=True,
        attn_pattern=("local", "local", "local", "local", "local",
                      "global"),
        window=512, rope_theta=1000000.0,
        scale_embeddings=True, tie_embeddings=True,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512, sharding_profile="dp",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=6, d_model=48, num_heads=4, num_kv_heads=1, head_dim=12,
        d_ff=96, vocab_size=512, window=16, dtype="float32", remat="none",
        loss_chunk=0, fsdp=False)
