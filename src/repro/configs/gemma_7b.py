"""gemma-7b [dense]: 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.

GeGLU MLP, head_dim=256, full global attention, gemma-style embedding
scaling.  [arXiv:2403.08295; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
        head_dim=256, d_ff=24576, vocab_size=256000,
        act="gelu", gated_mlp=True,
        attn_pattern=("global",), rope_theta=10000.0,
        scale_embeddings=True, tie_embeddings=True,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32", remat="none",
        loss_chunk=0, fsdp=False)
