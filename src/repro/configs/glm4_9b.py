"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.

RoPE, GQA, SwiGLU.  [hf:THUDM/glm-4-9b]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
        head_dim=128, d_ff=13696, vocab_size=151552,
        act="silu", gated_mlp=True,
        attn_pattern=("global",), rope_theta=10000.0,
        tie_embeddings=False,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=192, vocab_size=512, dtype="float32", remat="none",
        loss_chunk=0, fsdp=False)
