"""mamba2-1.3b [ssm]: 48L d_model=2048 attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality), expand=2, headdim=64.
[arXiv:2405.21060]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
        head_dim=0, d_ff=0, vocab_size=50280,
        attn_pattern=("ssd",),
        ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_headdim=64,
        ssm_ngroups=1, ssm_chunk=256,
        tie_embeddings=True,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=3, d_model=64, vocab_size=512, ssm_state=16,
        ssm_headdim=16, ssm_chunk=8, dtype="float32", remat="none",
        loss_chunk=0, fsdp=False)
