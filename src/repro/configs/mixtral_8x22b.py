"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention (4096).
DyDD expert balancing ON (the paper-representative MoE cell).
[arXiv:2401.04088; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=32768,
        act="silu", gated_mlp=True,
        attn_pattern=("local",), window=4096, rope_theta=1000000.0,
        num_experts=8, experts_per_token=2, capacity_factor=1.25,
        moe_dydd_balance=True, moe_ep=True, moe_virtual_experts=2,
        tie_embeddings=False,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512, train_accum=8,
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=96, vocab_size=512, window=32, num_experts=4,
        experts_per_token=2, dtype="float32", remat="none", loss_chunk=0,
        fsdp=False)
