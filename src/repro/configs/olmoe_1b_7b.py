"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8.  The 64-expert ring gives DyDD its richest processor
graph among the assigned archs.  [arXiv:2409.02060; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1024, vocab_size=50304,
        act="silu", gated_mlp=True,
        attn_pattern=("global",), rope_theta=10000.0,
        num_experts=64, experts_per_token=8, capacity_factor=1.25,
        moe_dydd_balance=True, moe_ep=True,
        tie_embeddings=False,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=512, num_experts=8, experts_per_token=2,
        dtype="float32", remat="none", loss_chunk=0, fsdp=False)
