"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend STUBBED: ``input_specs``
provides precomputed patch embeddings (B, num_patches, d_model) prepended
to the token sequence.  [hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
        head_dim=96, d_ff=8192, vocab_size=32064,
        act="silu", gated_mlp=True,
        attn_pattern=("global",), rope_theta=10000.0,
        frontend="vision_stub", num_patches=144,
        tie_embeddings=False,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, num_patches=8, dtype="float32",
        remat="none", loss_chunk=0, fsdp=False)
