"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, pattern (R, R, A) (2 recurrent per
1 attention), window 2048, lru_width=4096.  [arXiv:2402.19427]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        head_dim=256, d_ff=12288, vocab_size=256000,
        act="gelu", gated_mlp=True,
        attn_pattern=("rglru", "rglru", "local"),
        window=2048, rope_theta=10000.0, lru_width=4096,
        scale_embeddings=True, tie_embeddings=True,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, window=16, lru_width=64,
        dtype="float32", remat="none", loss_chunk=0, fsdp=False)
