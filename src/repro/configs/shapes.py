"""Input-shape registry: the 4 assigned shapes x 10 archs = 40 cells.

  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill step
  decode_32k   seq 32768,  global_batch 128   -> serve_step (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524288, global_batch 1     -> serve_step; run only for
                                                 sub-quadratic-cache archs
                                                 (see DESIGN.md §5)

``input_specs`` returns jax.ShapeDtypeStruct stand-ins (weak-type correct,
shardable, zero allocation) for every model input of a given (arch, shape)
cell — the pattern the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524288, 1, "decode"),
}

# Pure full-attention archs skip long_500k (unbounded KV cache; DESIGN.md
# §5).  whisper skips it because the enc-dec family has no 500k decode
# state (decoder context <= 448 architecturally).
LONG_CONTEXT_OK = {
    "recurrentgemma-9b", "mamba2-1.3b", "mixtral-8x22b", "gemma3-1b",
}


def cell_supported(cfg: ModelConfig, shape: str) -> tuple:
    """(supported, reason)."""
    if shape == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, ("pure full-attention (or bounded enc-dec) arch: "
                       "unbounded 500k KV cache excluded per DESIGN.md §5")
    return True, ""


def _token_struct(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStruct stand-ins for every input of this (arch, shape).

    train  -> {"tokens", "labels", "mask"} (+ modality stubs)
    prefill-> {"tokens"} (+ modality stubs)
    decode -> {"tokens" (B,1)}; the KV cache comes from
              ``decode_cache_specs``.
    """
    case = SHAPES[shape]
    B, S = case.global_batch, case.seq_len
    dt = jnp.dtype(cfg.dtype)

    extras = {}
    if cfg.frontend == "audio_stub":
        extras["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), dt)
    if cfg.frontend == "vision_stub" and case.kind != "decode":
        extras["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), dt)

    if case.kind == "train":
        return {"tokens": _token_struct(B, S),
                "labels": _token_struct(B, S),
                "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
                **extras}
    if case.kind == "prefill":
        return {"tokens": _token_struct(B, S), **extras}
    # decode: one new token against a cache of length S
    return {"tokens": _token_struct(B, 1), **extras}


def decode_cache_specs(cfg: ModelConfig, shape: str):
    """ShapeDtypeStructs of the decode cache for this cell (no alloc)."""
    from repro.models import transformer
    case = SHAPES[shape]
    cache = jax.eval_shape(
        lambda: transformer.init_decode_cache(cfg, case.global_batch,
                                              case.seq_len))
    return cache
