"""whisper-large-v3 [audio]: enc-dec, 32L each, d_model=1280 20H (MHA)
d_ff=5120 vocab=51866 — conv frontend STUBBED: ``input_specs`` provides
precomputed frame embeddings (B, 1500, 1280).  GELU (non-gated), LayerNorm,
learned positions.  [arXiv:2212.04356]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        head_dim=64, d_ff=5120, vocab_size=51866,
        act="gelu", gated_mlp=False,
        attn_pattern=("global",), rope_theta=0.0,
        is_encoder_decoder=True, encoder_layers=32, encoder_seq=1500,
        frontend="audio_stub",
        tie_embeddings=True, norm="layernorm",
        fsdp=True, remat="block", dtype="bfloat16", loss_chunk=512, attn_q_chunk=512, sharding_profile="dp",
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        encoder_seq=24, dtype="float32", remat="none", loss_chunk=0,
        fsdp=False)
