"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

Llama-architecture with GQA, SwiGLU.  [arXiv:2403.04652; hf]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=11008, vocab_size=64000,
        act="silu", gated_mlp=True,
        attn_pattern=("global",), rope_theta=5000000.0,
        tie_embeddings=False,
        norm="rmsnorm", fsdp=True, remat="block", dtype="bfloat16",
        loss_chunk=512, attn_q_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=512, dtype="float32", remat="none",
        loss_chunk=0, fsdp=False)
