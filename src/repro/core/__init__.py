"""Core paper library: CLS, Kalman Filter, DD-CLS, DyDD (1D/2D), DD-KF,
and the dimension-agnostic Domain layer (interval / shelf / k-d tree)."""
from repro.core import (  # noqa: F401
    balance, cls, dd, ddkf, domain, dydd, dydd2d, kalman, kdtree)
