"""Core paper library: CLS, Kalman Filter, DD-CLS, DyDD (1D/2D), DD-KF."""
from repro.core import balance, cls, dd, ddkf, dydd, dydd2d, kalman  # noqa: F401
