"""Version compatibility shims for the jax sharding API.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and its replication check was renamed ``check_rep`` -> ``check_vma``);
``jax.make_mesh`` gained ``axis_types`` along the way.  Resolve whichever
this runtime ships so the sharded DD-KF path works on both.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """shard_map with the replication/VMA check off (the DD-KF collectives
    mix psum/psum_scatter/all_gather patterns the checker rejects)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_device_mesh(shape, axis_names):
    """jax.make_mesh across the axis_types API change."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axis_names),
                             axis_types=(axis_type.Auto,) * len(shape))
    return jax.make_mesh(tuple(shape), tuple(axis_names))
