"""Generic load-balancing API on top of the DyDD scheduler.

This is the bridge between the paper's algorithm and the LM framework
layers: the data pipeline balances *documents/tokens* across data-parallel
shards, and the MoE layer balances *routed tokens* across experts.  Both
reduce to "integer loads on the vertices of a fixed device-topology graph",
which is exactly DyDD's scheduling problem (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import dydd


@dataclasses.dataclass(frozen=True)
class Topology:
    """A device/shard topology graph with precomputed solve operators."""

    p: int
    edges: tuple
    pinvL: np.ndarray       # (p, p) Laplacian pseudo-inverse
    incidence: np.ndarray   # (E, p) signed incidence matrix

    @staticmethod
    def ring(p: int) -> "Topology":
        return Topology.from_edges(p, dydd.ring_edges(p))

    @staticmethod
    def chain(p: int) -> "Topology":
        return Topology.from_edges(p, dydd.chain_edges(p))

    @staticmethod
    def torus2d(rows: int, cols: int) -> "Topology":
        return Topology.from_edges(rows * cols,
                                   dydd.grid_edges(rows, cols, torus=True))

    @staticmethod
    def from_edges(p: int, edges: Sequence) -> "Topology":
        L = dydd.laplacian(p, edges)
        pinvL = np.linalg.pinv(L) if p > 1 else np.zeros((1, 1))
        return Topology(p=p, edges=tuple(edges), pinvL=pinvL,
                        incidence=dydd.incidence_matrix(p, edges))

    def neighbours(self, i: int):
        out = []
        for a, b in self.edges:
            if a == i:
                out.append(b)
            elif b == i:
                out.append(a)
        return sorted(out)


@dataclasses.dataclass(frozen=True)
class MovePlan:
    """A concrete migration plan: moves[k] = (src, dst, count)."""

    moves: tuple
    loads_before: np.ndarray
    loads_after: np.ndarray

    @property
    def total_moved(self) -> int:
        return sum(c for _, _, c in self.moves)

    @property
    def efficiency(self) -> float:
        return dydd.balance_ratio(self.loads_after)


def plan(loads: np.ndarray, topo: Topology,
         max_rounds: int = 16) -> MovePlan:
    """Compute a neighbour-only migration plan that levels ``loads``."""
    loads = np.asarray(loads, dtype=np.int64)
    final, schedules = dydd.balance(loads, list(topo.edges),
                                    max_rounds=max_rounds)
    moves = []
    for sch in schedules:
        for (i, j), d in zip(sch.edges, sch.deltas):
            if d > 0:
                moves.append((int(i), int(j), int(d)))
            elif d < 0:
                moves.append((int(j), int(i), int(-d)))
    return MovePlan(moves=tuple(moves), loads_before=loads,
                    loads_after=final)
