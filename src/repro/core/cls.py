"""Constrained Least Squares (CLS) model — the paper's prototype DA problem.

The CLS problem (paper §3.1) combines two overdetermined linear systems,

    state:        H0 x = y0,   H0 in R^{m0 x n},  rank(H0) = n, m0 > n
    observations: H1 x = y1,   H1 in R^{m1 x n},  m1 > 0

into  S: A x = b  with  A = [H0; H1], b = [y0; y1] and weight
R = diag(R0, R1).  The CLS estimate minimizes

    J(x) = ||A x - b||_R^2 = ||H0 x - y0||_{R0}^2 + ||H1 x - y1||_{R1}^2

and is given by the normal equations (eq. 18-19)

    (A^T R A) x = A^T R b.

Everything here is pure JAX and differentiable; shapes are static.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CLSProblem:
    """A CLS problem instance.

    Attributes:
      H0: (m0, n) state operator, full column rank.
      y0: (m0,) state data.
      H1: (m1, n) observation operator.
      y1: (m1,) observation data.
      R0: (m0,) diagonal of the state weight matrix (paper: R diagonal).
      R1: (m1,) diagonal of the observation weight matrix.
    """

    H0: jax.Array
    y0: jax.Array
    H1: jax.Array
    y1: jax.Array
    R0: jax.Array
    R1: jax.Array

    @property
    def n(self) -> int:
        return self.H0.shape[1]

    @property
    def m0(self) -> int:
        return self.H0.shape[0]

    @property
    def m1(self) -> int:
        return self.H1.shape[0]

    def stacked(self):
        """Return (A, b, r) with A = [H0; H1], b = [y0; y1], r = diag(R)."""
        A = jnp.concatenate([self.H0, self.H1], axis=0)
        b = jnp.concatenate([self.y0, self.y1], axis=0)
        r = jnp.concatenate([self.R0, self.R1], axis=0)
        return A, b, r


def objective(prob: CLSProblem, x: jax.Array) -> jax.Array:
    """J(x) = ||H0 x - y0||_{R0}^2 + ||H1 x - y1||_{R1}^2  (eq. 17)."""
    r0 = prob.H0 @ x - prob.y0
    r1 = prob.H1 @ x - prob.y1
    return jnp.sum(prob.R0 * r0 * r0) + jnp.sum(prob.R1 * r1 * r1)


def normal_matrix(prob: CLSProblem) -> jax.Array:
    """A^T R A = H0^T R0 H0 + H1^T R1 H1."""
    return (prob.H0.T * prob.R0) @ prob.H0 + (prob.H1.T * prob.R1) @ prob.H1


def normal_rhs(prob: CLSProblem) -> jax.Array:
    """A^T R b = H0^T R0 y0 + H1^T R1 y1."""
    return prob.H0.T @ (prob.R0 * prob.y0) + prob.H1.T @ (prob.R1 * prob.y1)


@jax.jit
def solve(prob: CLSProblem) -> jax.Array:
    """Closed-form CLS solution via Cholesky on the normal equations (eq. 19).

    A^T R A is SPD because rank(H0) = n and R > 0, so Cholesky is the
    MXU-friendly solve (two triangular solves, no pivoting).
    """
    N = normal_matrix(prob)
    c = normal_rhs(prob)
    chol = jnp.linalg.cholesky(N)
    z = jax.scipy.linalg.solve_triangular(chol, c, lower=True)
    return jax.scipy.linalg.solve_triangular(chol.T, z, lower=False)


@jax.jit
def solve_cg(prob: CLSProblem, x0: jax.Array | None = None,
             tol: float = 1e-10, maxiter: int = 2000) -> jax.Array:
    """Matrix-free CG on the normal equations — used when n is large and
    materializing A^T R A is undesirable."""
    def matvec(x):
        return (prob.H0.T @ (prob.R0 * (prob.H0 @ x))
                + prob.H1.T @ (prob.R1 * (prob.H1 @ x)))

    c = normal_rhs(prob)
    x, _ = jax.scipy.sparse.linalg.cg(matvec, c, x0=x0, tol=tol,
                                      maxiter=maxiter)
    return x


def state_operator(n: int, smooth: float = 0.25):
    """H0 of the paper's PDE setting: identity rows plus ``smooth``-weighted
    second-difference rows (a discretized diffusion/background term) —
    banded, m0 = 2n - 2 > n, rank n.  Returns a numpy (2n-2, n) array."""
    import numpy as np
    eye = np.eye(n)
    d2 = np.zeros((n - 2, n))
    for i in range(n - 2):
        d2[i, i:i + 3] = (-1.0, 2.0, -1.0)
    return np.concatenate([eye, smooth * d2], axis=0)


def observation_operator(n: int, obs_locations, stencil: int = 3,
                         block: int | None = None):
    """H1 of the paper's PDE setting: each observation at location
    ``obs_locations[k] in [0,1)`` maps to a ``stencil``-point interpolation
    row around the nearest mesh point — the row is *local to the subdomain
    containing the observation*, which is what makes DyDD's row balancing
    meaningful.  Returns a numpy (m1, n) array.

    ``block`` confines each stencil window to the size-``block`` aligned
    chunk of columns containing its center: on a raster-ordered 2D mesh
    (``block = nx``) this stops a window near a mesh-row edge from leaking
    onto the physically distant first column of the next row."""
    import numpy as np
    obs = np.asarray(obs_locations, dtype=np.float64)
    m1 = obs.shape[0]
    H1 = np.zeros((m1, n))
    centers = np.clip((obs * n).astype(np.int64), 0, n - 1)
    half = stencil // 2
    for kk in range(m1):
        lo, hi = 0, n
        if block is not None:
            lo = (centers[kk] // block) * block
            hi = min(n, lo + block)
        lo = max(lo, centers[kk] - half)
        hi = min(hi, centers[kk] + half + 1)
        wts = np.exp(-0.5 * (np.arange(lo, hi) - obs[kk] * n) ** 2)
        H1[kk, lo:hi] = wts / wts.sum()
    return H1


def local_problem(key: jax.Array, n: int, obs_locations,
                  stencil: int = 3, dtype=jnp.float64,
                  smooth: float = 0.25) -> CLSProblem:
    """A spatially-local CLS instance mirroring the paper's PDE setting
    (see :func:`state_operator` and :func:`observation_operator`)."""
    import numpy as np
    obs = np.asarray(obs_locations, dtype=np.float64)
    m1 = obs.shape[0]
    k1, k2 = jax.random.split(key)

    H0 = state_operator(n, smooth=smooth)
    H1 = observation_operator(n, obs, stencil=stencil)

    x_true = jax.random.normal(k1, (n,), dtype)
    noise = 1e-3 * jax.random.normal(k2, (H0.shape[0] + m1,), dtype)
    H0 = jnp.asarray(H0, dtype)
    H1 = jnp.asarray(H1, dtype)
    y0 = H0 @ x_true + noise[:H0.shape[0]]
    y1 = H1 @ x_true + noise[H0.shape[0]:]
    return CLSProblem(H0=H0, y0=y0, H1=H1, y1=y1,
                      R0=jnp.ones((H0.shape[0],), dtype),
                      R1=jnp.ones((m1,), dtype))


def random_problem(key: jax.Array, n: int, m0: int, m1: int,
                   dtype=jnp.float64) -> CLSProblem:
    """A random well-conditioned CLS instance (used by tests/benchmarks)."""
    k0, k1, k2, k3 = jax.random.split(key, 4)
    H0 = jax.random.normal(k0, (m0, n), dtype) + jnp.eye(m0, n, dtype=dtype)
    H1 = jax.random.normal(k1, (m1, n), dtype)
    x_true = jax.random.normal(k2, (n,), dtype)
    noise = 1e-3 * jax.random.normal(k3, (m0 + m1,), dtype)
    y0 = H0 @ x_true + noise[:m0]
    y1 = H1 @ x_true + noise[m0:]
    R0 = jnp.ones((m0,), dtype)
    R1 = jnp.ones((m1,), dtype)
    return CLSProblem(H0=H0, y0=y0, H1=H1, y1=y1, R0=R0, R1=R1)
