"""Domain Decomposition of CLS problems (DD-CLS) — paper §4.

Implements:
  * matrix/vector reduction + extension operators (Definitions 3-4),
  * geometric 1D decomposition of the state index set I = {1..n} with
    optional overlap s (eq. 21-22),
  * the Alternating Schwarz DD-CLS iteration (eq. 24-28), both the
    multiplicative (sequential sweep) and additive (parallel, what DD-KF
    distributes) variants, with the overlap regularization term mu*O_{i,j},
  * assembly of the global estimate (eq. 28).

The fixed point of the non-overlapping iteration is exactly the block
Gauss-Seidel solution of the normal equations (A^T R A) x = A^T R b, i.e.
the CLS/KF estimate — which is why the paper observes error_DD-DA ~ 1e-11.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import defaultdict
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cls as cls_mod
from repro.obs import meters as meters_mod
from repro.obs import trace as trace_mod


# ---------------------------------------------------------------------------
# Reduction / extension operators (Definitions 3-4).
# ---------------------------------------------------------------------------

def restrict_cols(B: jax.Array, idx: jax.Array) -> jax.Array:
    """B|_I — reduction of a matrix to the columns in idx (Definition 3)."""
    return B[:, idx]


def restrict_rows(B: jax.Array, idx: jax.Array) -> jax.Array:
    """Reduction of a matrix to the rows in idx (Remark 4, 2D DD)."""
    return B[idx, :]


def restrict_vec(w: jax.Array, idx: jax.Array) -> jax.Array:
    """w|_I — reduction of a vector (Definition 4)."""
    return w[idx]


def extend_vec(w: jax.Array, idx: jax.Array, size: int) -> jax.Array:
    """EO_{I_r}(w) — extension by zero of w to a vector of ``size``
    (Definition 4): out[idx] = w, zero elsewhere."""
    out = jnp.zeros((size,), dtype=w.dtype)
    return out.at[idx].set(w)


# ---------------------------------------------------------------------------
# Neighbour-only halo exchange metadata.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class HaloExchange:
    """Precomputed neighbour-exchange schedule of a Decomposition.

    The paper's overhead model (T^p_oh) charges each subdomain only for
    traffic with its grid-graph neighbours; this object is the machinery
    that realizes exactly that communication pattern on device.  It is
    graph-general: an *edge* is any pair of subdomains whose column sets
    intersect — the grid-graph neighbours for a cross-shaped 2D halo, the
    chain neighbours in 1D, plus any halo∩halo pairs a wide overlap
    creates (e.g. diagonal cells whose halos meet at a tiling corner).

    Each edge (i, j) induces two directed *arcs* i->j and j->i; the arcs
    are coloured with an optimal bipartite (Konig) edge colouring so that
    within one colour class every device sends to at most one partner and
    receives from at most one (possibly different) partner.  One
    ``jax.lax.ppermute`` of a single packed ``h``-lane buffer per class
    moves every arc of the class — exactly ``rounds = max degree`` of the
    neighbour graph permutes per iteration, regardless of how many edges
    meet at a device (the greedy undirected matching schedule needed up
    to ``2*maxdeg - 1``).  Payloads are padded to the widest edge (``h``
    lanes); slot ``w`` of the padded local vector is the dump slot both
    for gather padding (reads zero) and scatter padding.

    Attributes:
      p: subdomain count.
      w: padded local slot width (= ``max |col_set|``, the PackedDD pad
        width); also the dump slot index.
      h: widest per-edge shared-column count (payload lanes per round).
      rounds: number of colour classes (= ppermute rounds per iteration
        = max degree of the neighbour graph).
      edges: ((i, j), ...) with i < j — column-sharing subdomain pairs.
      shared: per edge, the ascending global column indices both own.
      send_slots: per edge, ``(slots_in_i, slots_in_j)`` — positions of
        ``shared`` inside each endpoint's local column set.  Endpoint i
        gathers its payload at ``slots_in_i`` and endpoint j scatters the
        received payload at ``slots_in_j`` (and vice versa): the send map
        of one side *is* the recv map of the other.
      perms: per round, the ((src, dst), ...) directed arcs handed to
        ppermute — each device appears at most once as src and at most
        once as dst per round.
      pack_idx: (p, rounds, h) int32 — device d's round-r *send* buffer
        lane k gathers from local slot ``pack_idx[d, r, k]`` (``w`` =
        dump: reads the zero pad, for unused lanes and idle senders).
      unpack_idx: (p, rounds, h) int32 — device d's round-r *received*
        buffer lane k scatter-adds into local slot
        ``unpack_idx[d, r, k]`` (``w`` = dump for unused lanes and idle
        receivers).  Separate from ``pack_idx`` because in a directed
        round d's send partner need not be its recv partner.
    """

    p: int
    w: int
    h: int
    rounds: int
    edges: tuple
    shared: tuple
    send_slots: tuple
    perms: tuple
    pack_idx: np.ndarray
    unpack_idx: np.ndarray

    def edge_send_bytes(self, itemsize: int) -> dict:
        """Per-iteration bytes each endpoint of each edge sends, keyed
        ``"i-j"`` (JSON-friendly) — the single source of the per-edge
        pricing every accounting layer (``ddkf.comm_model``,
        ``PackedDD.edge_send_bytes``, the bench JSON) derives from."""
        return {f"{i}-{j}": int(s.size) * int(itemsize)
                for (i, j), s in zip(self.edges, self.shared)}

    def device_send_bytes(self, itemsize: int) -> np.ndarray:
        """(p,) per-iteration bytes each device sends over all its edges."""
        out = np.zeros((self.p,), dtype=np.int64)
        for (i, j), s in zip(self.edges, self.shared):
            out[i] += s.size * int(itemsize)
            out[j] += s.size * int(itemsize)
        return out


def _bipartite_arc_coloring(arcs, p: int) -> list:
    """Colour directed arcs so that within one colour no device sends
    twice and no device receives twice — the send side and the recv side
    are the two shores of a bipartite multigraph, so Konig's theorem
    applies and the alternating-path algorithm below colours the arcs
    with exactly ``maxdeg`` colours (maxdeg = the largest number of
    neighbours any device has; both directions of every edge are arcs,
    so out-degree == in-degree == degree).

    For each arc (u, v): take ``a`` = the smallest colour free at sender
    u and ``b`` = the smallest free at receiver v.  If they differ, walk
    the alternating a/b path starting at v (an a-arc at a receiver, then
    a b-arc at its sender, ...) and swap its colours — the path can never
    reach u (u has no a-arc), so afterwards ``a`` is free at both ends.
    """
    snd: list = [dict() for _ in range(p)]   # sender side: colour -> arc
    rcv: list = [dict() for _ in range(p)]   # receiver side
    color = [-1] * len(arcs)

    def mex(used):
        c = 0
        while c in used:
            c += 1
        return c

    for e, (u, v) in enumerate(arcs):
        a = mex(snd[u])
        b = mex(rcv[v])
        if a != b:
            # Collect the maximal a/b-alternating path from v, then flip.
            path = []
            node, node_is_rcv, want = v, True, a
            while True:
                table = rcv[node] if node_is_rcv else snd[node]
                arc = table.get(want)
                if arc is None:
                    break
                path.append(arc)
                au, av = arcs[arc]
                node, node_is_rcv = (au, False) if node_is_rcv else (av, True)
                want = b if want == a else a
            # Two-phase flip: consecutive path arcs share an endpoint, so
            # deleting and re-inserting arc by arc would clobber the
            # neighbour's fresh entry.  Clear every old slot first.
            for arc in path:
                au, av = arcs[arc]
                del snd[au][color[arc]], rcv[av][color[arc]]
            for arc in path:
                au, av = arcs[arc]
                new = b if color[arc] == a else a
                color[arc] = new
                snd[au][new] = arc
                rcv[av][new] = arc
        color[e] = a
        snd[u][a] = e
        rcv[v][a] = e
    return color


# ---------------------------------------------------------------------------
# Geometric 1D decomposition.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Decomposition:
    """A decomposition of I = {0..n-1} into p (possibly overlapping) blocks.

    ``col_sets`` (and the per-column multiplicity derived from them) are
    the source of truth: each subdomain's set is its core ∪ halo columns
    on an *arbitrary* processor graph — 1D interval chains, 2D shelf
    tilings, or anything else that partitions-with-overlap the index set.
    Everything downstream (:class:`SchwarzSolver`, ``ddkf.pack_operator``)
    reads only these general fields.

    Attributes:
      n: global number of columns (state size).
      col_sets: tuple of p int arrays — column indices per subdomain,
        ascending; sets may share columns (the Schwarz halo) and may be
        empty.
      overlap: halo width s >= 0 the decomposition was built with (eq. 21:
        how many mesh columns/rows each subdomain absorbs per neighbour).
      boundaries: optional (p+1,) float array in [0, 1] — geometric
        interval edges, metadata kept only by the 1D constructor
        :func:`decompose_1d` (subdomain i covers
        [boundaries[i], boundaries[i+1])).  ``None`` for graph-general
        decompositions (2D tilings); nothing in the solver/packing layer
        dereferences it.
    """

    n: int
    col_sets: tuple
    overlap: int
    boundaries: np.ndarray | None = None

    @property
    def p(self) -> int:
        return len(self.col_sets)

    @functools.cached_property
    def column_multiplicity(self) -> np.ndarray:
        """(n,) count of subdomains owning each column (>= 2 on halos).

        This is the weight of the partition-of-unity assembly (eq. 28):
        overlap columns are averaged with weight 1/multiplicity.
        """
        counts = np.zeros(self.n, dtype=np.int64)
        for c in self.col_sets:
            counts[np.asarray(c)] += 1
        return counts

    @property
    def has_overlap(self) -> bool:
        """True iff some column is shared (multiplicity > 1) — what gates
        the mu-regularization term of eq. 25/26."""
        return bool(self.column_multiplicity.max(initial=0) > 1)

    @property
    def pad_width(self) -> int:
        """The padded local slot width w = max |col_set| (>= 1) — the
        layout ``ddkf.pack_operator`` packs into and the dump slot index
        of the halo-exchange payload maps."""
        return max(1, max((int(np.asarray(c).shape[0])
                           for c in self.col_sets), default=1))

    @functools.cached_property
    def halo_sizes(self) -> np.ndarray:
        """(p,) count of halo columns (multiplicity > 1) each subdomain
        carries — the per-subdomain overlap work the overlap-aware DyDD
        weighting adds to the observation loads."""
        counts = self.column_multiplicity
        return np.array([int((counts[np.asarray(c)] > 1).sum())
                         for c in self.col_sets], dtype=np.int64)

    @functools.cached_property
    def halo_fraction(self) -> float:
        """Fraction of owned column slots that are halo (shared) slots —
        0.0 for a non-overlapping decomposition."""
        total = sum(int(np.asarray(c).shape[0]) for c in self.col_sets)
        return float(self.halo_sizes.sum() / total) if total else 0.0

    @functools.cached_property
    def halo_exchange(self) -> HaloExchange:
        """Cached neighbour-exchange schedule (see :class:`HaloExchange`).

        Edges are discovered from actual ``col_sets`` intersections via an
        inverted owner index (O(n * multiplicity^2)), so the schedule is
        correct on any graph — including the halo∩halo pairs a wide
        overlap creates between non-adjacent subdomains.  Empty-core
        subdomains own no columns, so they acquire no edges and their
        ``pack_idx``/``unpack_idx`` rows are all dump.
        """
        with trace_mod.span("halo.build", p=self.p,
                            overlap=int(self.overlap)):
            return self._build_halo_exchange()

    def _build_halo_exchange(self) -> HaloExchange:
        sets = [np.asarray(c) for c in self.col_sets]
        w = self.pad_width
        # Inverted index: columns with multiplicity > 1 -> owner pairs.
        owners = defaultdict(list)
        for i, c in enumerate(sets):
            for col in c[self.column_multiplicity[c] > 1].tolist():
                owners[col].append(i)
        edge_cols = defaultdict(list)
        for col, own in owners.items():
            for a in range(len(own)):
                for b in range(a + 1, len(own)):
                    edge_cols[(own[a], own[b])].append(col)
        edges = tuple(sorted(edge_cols))
        shared = tuple(np.array(sorted(edge_cols[e]), dtype=np.int64)
                       for e in edges)
        h = max((s.size for s in shared), default=0)
        send_slots = []
        for (i, j), s in zip(edges, shared):
            # col_sets are ascending, so position-in-set == searchsorted.
            si = np.searchsorted(sets[i], s)
            sj = np.searchsorted(sets[j], s)
            send_slots.append((si.astype(np.int64), sj.astype(np.int64)))
        # Directed packed schedule: both arcs of every edge, coloured so
        # each round is a permutation fragment (every device <= 1 send
        # and <= 1 recv).  Konig colouring uses exactly maxdeg rounds.
        arcs = [a for e in edges for a in (e, e[::-1])]
        color = _bipartite_arc_coloring(arcs, self.p)
        rounds = max(color) + 1 if arcs else 0
        pack_idx = np.full((self.p, rounds, h), w, dtype=np.int32)
        unpack_idx = np.full((self.p, rounds, h), w, dtype=np.int32)
        perms: list = [[] for _ in range(rounds)]
        for a, ((src, dst), c) in enumerate(zip(arcs, color)):
            k = a // 2                       # arcs 2k, 2k+1 belong to edge k
            s = shared[k]
            si, sj = send_slots[k]
            ssend, srecv = (si, sj) if src < dst else (sj, si)
            pack_idx[src, c, :s.size] = ssend
            unpack_idx[dst, c, :s.size] = srecv
            perms[int(c)].append((src, dst))
        m = meters_mod.get_meters()
        m.inc("halo.builds")
        m.inc("halo.edges", len(edges))
        m.event("halo.build", p=self.p, overlap=int(self.overlap),
                edges=len(edges), rounds=rounds, payload_lanes=int(h))
        m.gauge("halo.rounds", rounds)
        return HaloExchange(p=self.p, w=w, h=h, rounds=rounds,
                           edges=edges, shared=shared,
                           send_slots=tuple(send_slots),
                           perms=tuple(tuple(pr) for pr in perms),
                           pack_idx=pack_idx, unpack_idx=unpack_idx)

    def overlap_sets(self):
        """I_{i,i+1} — shared indices between consecutive subdomains."""
        out = []
        for i in range(self.p - 1):
            a = set(np.asarray(self.col_sets[i]).tolist())
            b = set(np.asarray(self.col_sets[i + 1]).tolist())
            out.append(np.array(sorted(a & b), dtype=np.int64))
        return out


def mesh_positions(n: int) -> np.ndarray:
    """Cell-centred positions of the n mesh points in [0, 1]."""
    return (np.arange(n) + 0.5) / n


def decompose_1d(n: int, boundaries: Sequence[float],
                 overlap: int = 0) -> Decomposition:
    """Decompose I = {0..n-1} according to geometric interval boundaries.

    Columns are assigned to the interval containing their mesh position;
    each interior boundary then donates ``overlap`` columns to both sides
    (eq. 21: I_2 starts at n_1 - s + 1).
    """
    boundaries = np.asarray(boundaries, dtype=np.float64)
    p = len(boundaries) - 1
    assert boundaries[0] == 0.0 and abs(boundaries[-1] - 1.0) < 1e-12
    pos = mesh_positions(n)
    owner = np.clip(np.searchsorted(boundaries, pos, side="right") - 1, 0,
                    p - 1)
    col_sets = []
    for i in range(p):
        core = np.where(owner == i)[0]
        lo = int(core[0]) if core.size else 0
        hi = int(core[-1]) + 1 if core.size else 0
        lo = max(0, lo - (overlap if i > 0 else 0))
        hi = min(n, hi + (overlap if i < p - 1 else 0))
        col_sets.append(np.arange(lo, hi, dtype=np.int64))
    return Decomposition(n=n, col_sets=tuple(col_sets),
                         boundaries=boundaries, overlap=overlap)


def uniform_boundaries(p: int) -> np.ndarray:
    return np.linspace(0.0, 1.0, p + 1)


def assign_rows(locations: np.ndarray, boundaries: np.ndarray):
    """Assign observation rows to subdomains by spatial location
    (Remark 5: row DD is what DyDD balances)."""
    p = len(boundaries) - 1
    owner = np.clip(np.searchsorted(boundaries, locations, side="right") - 1,
                    0, p - 1)
    return [np.where(owner == i)[0].astype(np.int64) for i in range(p)]


# ---------------------------------------------------------------------------
# DD-CLS Schwarz iteration (eqs. 24-28).
# ---------------------------------------------------------------------------

def _local_factor(prob: cls_mod.CLSProblem, cols: np.ndarray,
                  mu: float, ov_mask: np.ndarray):
    """Cholesky factor of A_i^T R A_i + mu * diag(ov_mask) (eq. 25)."""
    A_i = jnp.concatenate(
        [restrict_cols(prob.H0, cols), restrict_cols(prob.H1, cols)], axis=0)
    r = jnp.concatenate([prob.R0, prob.R1])
    N = (A_i.T * r) @ A_i
    if mu > 0.0:
        N = N + mu * jnp.diag(jnp.asarray(ov_mask, N.dtype))
    return A_i, jnp.linalg.cholesky(N)


def _chol_solve(L: jax.Array, rhs: jax.Array) -> jax.Array:
    z = jax.scipy.linalg.solve_triangular(L, rhs, lower=True)
    return jax.scipy.linalg.solve_triangular(L.T, z, lower=False)


@dataclasses.dataclass
class SchwarzSolver:
    """Alternating-Schwarz solver for a CLS problem under a Decomposition.

    mode='multiplicative' sweeps subdomains sequentially with newest iterates
    (eq. 24); mode='additive' updates all subdomains from the previous global
    iterate — the form DD-KF parallelizes (each subdomain = one processor).

    With overlap > 0, the local objective gains the regularization term
    mu * ||x_i|_ov - x_glob|_ov||^2 (eq. 25-26) and the global assembly
    averages the overlap values (eq. 28 with the paper's mu/2 weighting at
    mu = 1).
    """

    prob: cls_mod.CLSProblem
    dec: Decomposition
    mu: float = 1.0
    damping: float = 1.0  # additive mode under-relaxation

    def __post_init__(self):
        p = self.dec.p
        self._A = []     # local column blocks of A
        self._L = []     # local Cholesky factors
        self._ov_masks = []
        counts = self.dec.column_multiplicity
        self._multiplicity = jnp.asarray(np.maximum(counts, 1))
        mu_eff = self.mu if self.dec.has_overlap else 0.0
        for i in range(p):
            cols = np.asarray(self.dec.col_sets[i])
            ov = (counts[cols] > 1).astype(np.float64)
            A_i, L_i = _local_factor(self.prob, cols, mu_eff, ov)
            self._A.append(A_i)
            self._L.append(L_i)
            self._ov_masks.append(jnp.asarray(ov))
        self._r = jnp.concatenate([self.prob.R0, self.prob.R1])
        self._b = jnp.concatenate([self.prob.y0, self.prob.y1])

    # -- single local solve (eq. 25/27) -----------------------------------
    def _solve_local(self, i: int, x_global: jax.Array) -> jax.Array:
        cols = jnp.asarray(self.dec.col_sets[i])
        A_i = self._A[i]
        # b - sum_{j != i} A_j x_j  ==  b - A x + A_i x_i  (cheap form).
        Ax = self._apply_A(x_global)
        resid = self._b - Ax + A_i @ x_global[cols]
        rhs = A_i.T @ (self._r * resid)
        if self.dec.has_overlap and self.mu > 0.0:
            rhs = rhs + self.mu * self._ov_masks[i] * x_global[cols]
        return _chol_solve(self._L[i], rhs)

    def _apply_A(self, x: jax.Array) -> jax.Array:
        A0x = self.prob.H0 @ x
        A1x = self.prob.H1 @ x
        return jnp.concatenate([A0x, A1x])

    def _assemble(self, locals_: list, x_prev: jax.Array) -> jax.Array:
        """eq. 28: additive assembly with overlap averaging."""
        acc = jnp.zeros_like(x_prev)
        for i, xi in enumerate(locals_):
            cols = jnp.asarray(self.dec.col_sets[i])
            acc = acc.at[cols].add(xi)
        return acc / self._multiplicity.astype(acc.dtype)

    # -- outer iterations ---------------------------------------------------
    def step_multiplicative(self, x: jax.Array) -> jax.Array:
        for i in range(self.dec.p):
            cols = jnp.asarray(self.dec.col_sets[i])
            xi = self._solve_local(i, x)
            if self.dec.has_overlap:
                # keep a consistent global iterate: average into overlap
                old = x[cols]
                ov = self._ov_masks[i].astype(x.dtype)
                xi = ov * 0.5 * (xi + old) + (1.0 - ov) * xi
            x = x.at[cols].set(xi)
        return x

    def step_additive(self, x: jax.Array) -> jax.Array:
        locals_ = [self._solve_local(i, x) for i in range(self.dec.p)]
        x_new = self._assemble(locals_, x)
        return (1.0 - self.damping) * x + self.damping * x_new

    def solve(self, x0: jax.Array | None = None, iters: int = 100,
              tol: float = 1e-13, mode: str = "multiplicative"):
        """Iterate to convergence; returns (x, n_iters, residual_history)."""
        x = jnp.zeros((self.dec.n,), dtype=self.prob.H0.dtype) \
            if x0 is None else x0
        step = (self.step_multiplicative if mode == "multiplicative"
                else self.step_additive)
        hist = []
        for k in range(iters):
            x_new = step(x)
            delta = float(jnp.linalg.norm(x_new - x))
            hist.append(delta)
            x = x_new
            if delta < tol * max(1.0, float(jnp.linalg.norm(x))):
                return x, k + 1, hist
        return x, iters, hist
