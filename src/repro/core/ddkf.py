"""DD-KF — the distributed Kalman-Filter solve of a decomposed CLS problem.

Each subdomain (= processor) iterates the *additive* Schwarz update of
``repro.core.dd``: given the current global iterate, it solves its local
regularized VAR-KF problem (eq. 25/27) and the updates are assembled
(eq. 28).  The only inter-processor communication per iteration is

    Ax = sum_j A_j x_j            (one all-reduce of an m-vector)

plus the boundary/overlap exchange folded into the assembly — exactly the
communication structure the paper counts in its overhead T^p_oh.

Two execution paths share the same step function:
  * ``solve_vmapped``   — subdomains on the leading axis of a batch
                          (single-device correctness/reference path);
  * ``solve_shardmap``  — one device per subdomain on a 1D chain or a
                          2D ``pr x pc`` grid mesh (the production path;
                          ``psum`` for the m-vector, ``psum_scatter`` +
                          ``all_gather`` for the overlap exchange;
                          exercised under forced multi-device XLA in
                          tests and by the launch dry-run).

Static shapes: local blocks are padded to the max block width; padded
columns carry an identity diagonal in the local normal matrix and zero
right-hand side, so their solution stays exactly zero.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cls as cls_mod
from repro.core import dd as dd_mod
from repro.core import _compat
from repro.kernels import ops as ops_mod


@partial(jax.tree_util.register_dataclass,
         data_fields=("A_loc", "L_loc", "cols", "mask", "muov", "wdiv",
                      "mult", "r", "b"),
         meta_fields=("n", "p", "w"))
@dataclasses.dataclass(frozen=True)
class PackedDD:
    """Host-side packing of a Decomposition into padded device arrays."""

    A_loc: jax.Array      # (p, m, w) local column blocks, zero-padded
    L_loc: jax.Array      # (p, w, w) Cholesky of local normal matrices
    cols: jax.Array       # (p, w) global column index per local slot (or -1)
    mask: jax.Array       # (p, w) 1.0 for real columns, 0.0 for padding
    muov: jax.Array       # (p, w) mu on overlap slots (regularization)
    wdiv: jax.Array       # (p, w) mask / column-multiplicity: partition of
                          # unity so sum_i A_i (x_i * wdiv_i) == A x_glob
    mult: jax.Array       # (n,) column multiplicity (overlap counting)
    r: jax.Array          # (m,) weight diagonal
    b: jax.Array          # (m,) stacked data
    n: int
    p: int
    w: int


def pack(prob: cls_mod.CLSProblem, dec: dd_mod.Decomposition,
         mu: float = 1.0) -> PackedDD:
    A = jnp.concatenate([prob.H0, prob.H1], axis=0)
    r = jnp.concatenate([prob.R0, prob.R1])
    b = jnp.concatenate([prob.y0, prob.y1])
    return with_rhs(pack_operator(A, r, dec, mu=mu), b)


@partial(jax.jit, static_argnames=("gram_mode", "gram_block"))
def _factor_batched(A_loc: jax.Array, r: jax.Array, diag_add: jax.Array,
                    gram_mode: str = "auto",
                    gram_block: int | None = None) -> jax.Array:
    """Batched local normal matrices + Cholesky factors, on device.

    N_i = A_i^T diag(r) A_i comes from the ``kernels.ops.gram`` kernel
    (Pallas on TPU, jnp reference elsewhere); ``diag_add`` carries the
    mu-regularization on overlap slots plus the identity on padded slots
    that keeps every factor nonsingular.  ``gram_block`` is the autotuned
    reduction tile, resolved by the caller outside jit
    (``ops.gram_block_for``).
    """
    p = A_loc.shape[0]
    N = ops_mod.gram(A_loc, jnp.broadcast_to(r, (p, r.shape[0])),
                     mode=gram_mode, block_m=gram_block)
    N = N + jax.vmap(jnp.diag)(diag_add.astype(N.dtype))
    return jax.vmap(jnp.linalg.cholesky)(N)


def pack_operator(A: jax.Array, r: jax.Array, dec: dd_mod.Decomposition,
                  mu: float = 1.0, gram_mode: str = "auto") -> PackedDD:
    """Pack the *operator* part of a decomposed CLS problem.

    The host slices the p column blocks into the padded (p, m, w) layout;
    the p local normal matrices N_i = A_i^T diag(r) A_i and their Cholesky
    factors are then built *on device* in one batched shot
    (:func:`_factor_batched`: ``kernels.ops.gram`` + ``vmap(cholesky)``)
    instead of a per-subdomain ``np.linalg.cholesky`` Python loop.  The
    packing depends only on (A, r, dec), not on the data vector b, so the
    streaming engine runs it for cycle t+1 while the device is solving
    cycle t, then injects the cycle's rhs with :func:`with_rhs` (a cheap
    ``dataclasses.replace``).

    ``gram_mode`` selects the kernel path ("auto": Pallas on TPU, jnp
    reference elsewhere — see :mod:`repro.kernels.ops`).

    The returned ``PackedDD`` carries a zero rhs; pass it through
    :func:`with_rhs` before solving.
    """
    m, n = A.shape
    p = dec.p
    w = max(1, max(int(np.asarray(c).shape[0]) for c in dec.col_sets))

    # Per-column multiplicity is the decomposition's source of truth: the
    # halo columns (multiplicity > 1) carry the mu-regularization and the
    # 1/multiplicity partition-of-unity assembly weight, on any graph.
    counts = dec.column_multiplicity
    halo_mu = dec.has_overlap and mu > 0.0

    A_loc = np.zeros((p, m, w), dtype=np.asarray(A).dtype)
    cols = -np.ones((p, w), dtype=np.int64)
    mask = np.zeros((p, w), dtype=np.asarray(A).dtype)
    muov = np.zeros((p, w), dtype=np.asarray(A).dtype)
    A_np = np.asarray(A)
    for i, c in enumerate(dec.col_sets):
        c = np.asarray(c)
        k = c.shape[0]
        A_loc[i, :, :k] = A_np[:, c]
        cols[i, :k] = c
        mask[i, :k] = 1.0
        if halo_mu:
            muov[i, :k] = mu * (counts[c] > 1).astype(muov.dtype)
    A_loc = jnp.asarray(A_loc)
    r = jnp.asarray(r, A_loc.dtype)
    # mu on overlap slots; identity on padded slots (mask == 0).  The
    # gram reduction tile is autotuned host-side (first call per shape,
    # cached) and handed to the jitted factor build as a static arg.
    gram_block = ops_mod.gram_block_for((p, m, w), A_loc.dtype,
                                        mode=gram_mode)
    L_loc = _factor_batched(A_loc, r, jnp.asarray(muov + (1.0 - mask)),
                            gram_mode=gram_mode, gram_block=gram_block)
    mult_at = np.maximum(counts, 1)[np.clip(cols, 0, n - 1)]
    wdiv = mask / mult_at
    return PackedDD(A_loc=A_loc, L_loc=L_loc,
                    cols=jnp.asarray(cols), mask=jnp.asarray(mask),
                    muov=jnp.asarray(muov), wdiv=jnp.asarray(wdiv),
                    mult=jnp.asarray(np.maximum(counts, 1)).astype(A.dtype),
                    r=r, b=jnp.zeros((m,), dtype=A_loc.dtype), n=n, p=p,
                    w=w)


def with_rhs(packed: PackedDD, b: jax.Array) -> PackedDD:
    """Inject the data vector b = [y0; y1] into an operator-only packing."""
    return dataclasses.replace(packed, b=jnp.asarray(b, packed.A_loc.dtype))


def _chol_solve(L, rhs):
    z = jax.scipy.linalg.solve_triangular(L, rhs, lower=True)
    return jax.scipy.linalg.solve_triangular(L.T, z, lower=False)


def _local_update(A_i, L_i, mask_i, muov_i, x_i, Ax, r, b):
    """One local regularized VAR-KF solve given the global product Ax
    (eq. 25/27): the mu-term anchors the overlap slots to the current
    consistent global iterate x_i (= x_glob gathered)."""
    resid = b - Ax + A_i @ x_i
    rhs = (A_i.T @ (r * resid) + muov_i * x_i) * mask_i
    return _chol_solve(L_i, rhs) * mask_i


# ---------------------------------------------------------------------------
# Reference path: subdomains on a batch axis.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("iters",))
def solve_vmapped(packed: PackedDD, iters: int = 60,
                  damping: float = 1.0) -> jax.Array:
    """Additive-Schwarz DD-KF; returns the assembled global estimate."""

    def body(_, x_loc):
        # partition of unity: overlap columns contribute once to A x_glob
        Ax_parts = jnp.einsum("pmw,pw->pm", packed.A_loc,
                              x_loc * packed.wdiv)
        Ax = jnp.sum(Ax_parts, axis=0)
        new = jax.vmap(
            lambda A_i, L_i, m_i, mu_i, x_i: _local_update(
                A_i, L_i, m_i, mu_i, x_i, Ax, packed.r, packed.b)
        )(packed.A_loc, packed.L_loc, packed.mask, packed.muov, x_loc)
        x_loc2 = (1.0 - damping) * x_loc + damping * new
        # Overlap consistency: average duplicated columns globally, then
        # gather back (eq. 28).
        x_glob = assemble(packed, x_loc2)
        return gather_local(packed, x_glob)

    x0 = jnp.zeros((packed.p, packed.w), dtype=packed.A_loc.dtype)
    x_loc = jax.lax.fori_loop(0, iters, body, x0)
    return assemble(packed, x_loc)


def assemble(packed: PackedDD, x_loc: jax.Array) -> jax.Array:
    """Scatter local iterates into the global vector, averaging overlaps."""
    flat_cols = jnp.where(packed.cols >= 0, packed.cols, packed.n)
    acc = jnp.zeros((packed.n + 1,), dtype=x_loc.dtype)
    acc = acc.at[flat_cols.reshape(-1)].add(
        (x_loc * packed.mask).reshape(-1))
    return acc[:packed.n] / packed.mult


def gather_local(packed: PackedDD, x_glob: jax.Array) -> jax.Array:
    safe = jnp.where(packed.cols >= 0, packed.cols, 0)
    return x_glob[safe] * packed.mask


# ---------------------------------------------------------------------------
# Production path: subdomains sharded over a mesh axis.
# ---------------------------------------------------------------------------

def solve_shardmap(packed: PackedDD, mesh, axis="sub",
                   iters: int = 60, damping: float = 1.0) -> jax.Array:
    """Same iteration with one device per subdomain, on a 1D or 2D mesh.

    ``axis`` is one mesh axis name or a tuple of names — pass
    ``("row", "col")`` to run subdomain ``r * pc + c`` on device (r, c)
    of a ``pr x pc`` mesh (the paper's processor topology: grid axes map
    onto the mesh axes, so neighbour-halo traffic stays on-axis).

    Per iteration the communication is one ``psum`` of the (m,) product —
    the m-vector all-reduce the paper accounts as overhead — plus the
    overlap-averaging exchange of the (n,) assembled estimate, done as a
    ``psum_scatter`` + ``all_gather`` pair along the innermost mesh axis
    (reduce-scatter is the bandwidth-optimal form of that all-reduce on a
    real torus; for a banded A it would further specialize to neighbour
    ppermute, we keep the general graph form).  Only the n-vector moves —
    the (w,) local iterates never leave their device.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    sizes = [mesh.shape[a] for a in axes]
    if int(np.prod(sizes)) != packed.p:
        raise ValueError(
            f"mesh axes {axes} have {int(np.prod(sizes))} devices but the "
            f"packing has p={packed.p} subdomains")
    # Innermost axis carries the scatter; pad the accumulator so its
    # length splits evenly (the last slot doubles as the -1-column dump).
    ks = int(mesh.shape[axes[-1]])
    n_pad = -(-(packed.n + 1) // ks) * ks

    def nvec_allreduce(part):
        """Sum an (n_pad,) partial over every mesh axis: plain psum on the
        outer axes, reduce-scatter + all-gather on the innermost."""
        if len(axes) > 1:
            part = jax.lax.psum(part, axes[:-1])
        chunk = jax.lax.psum_scatter(part, axes[-1], scatter_dimension=0,
                                     tiled=True)
        return jax.lax.all_gather(chunk, axes[-1], tiled=True)

    def per_device(A_i, L_i, mask_i, muov_i, wdiv_i, cols_i):
        # Leading axis of size 1 (= this device's subdomain).
        A_i, L_i, mask_i, muov_i, wdiv_i, cols_i = (
            A_i[0], L_i[0], mask_i[0], muov_i[0], wdiv_i[0], cols_i[0])
        safe = jnp.where(cols_i >= 0, cols_i, n_pad - 1)

        def scatter_part(x_i):
            return jnp.zeros((n_pad,), x_i.dtype).at[safe].add(
                x_i * mask_i)

        def body(_, x_i):
            Ax = jax.lax.psum(A_i @ (x_i * wdiv_i), axes)
            new = _local_update(A_i, L_i, mask_i, muov_i, x_i, Ax,
                                packed.r, packed.b)
            x_i2 = (1.0 - damping) * x_i + damping * new
            # Overlap consistency (eq. 28): multiplicity-weighted average
            # of the duplicated columns, then gather back.
            x_glob = nvec_allreduce(scatter_part(x_i2))[:packed.n] \
                / packed.mult
            return x_glob[jnp.where(cols_i >= 0, cols_i, 0)] * mask_i

        x_i = jnp.zeros((packed.w,), dtype=A_i.dtype)
        x_i = jax.lax.fori_loop(0, iters, body, x_i)
        return (nvec_allreduce(scatter_part(x_i))[:packed.n]
                / packed.mult)[None]

    specs = P(axes if len(axes) > 1 else axes[0])
    fn = _compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(specs,) * 6,
        out_specs=specs)
    out = fn(packed.A_loc, packed.L_loc, packed.mask, packed.muov,
             packed.wdiv, packed.cols)
    return out[0]


# ---------------------------------------------------------------------------
# Convenience driver: DyDD + DD-KF end to end on a 1D domain.
# ---------------------------------------------------------------------------

def ddkf_with_dydd(prob: cls_mod.CLSProblem, obs_locations: np.ndarray,
                   p: int, overlap: int = 0, iters: int = 60,
                   mu: float = 1.0):
    """Balance observations with DyDD, decompose, and solve with DD-KF.

    Returns (x_ddkf, dydd_result, decomposition).
    """
    from repro.core import dydd as dydd_mod

    res = dydd_mod.dydd_1d(obs_locations, p)
    dec = dd_mod.decompose_1d(prob.n, res.boundaries, overlap=overlap)
    packed = pack(prob, dec, mu=mu)
    x = solve_vmapped(packed, iters=iters)
    return x, res, dec
