"""DD-KF — the distributed Kalman-Filter solve of a decomposed CLS problem.

Each subdomain (= processor) iterates the *additive* Schwarz update of
``repro.core.dd``: given the current global iterate, it solves its local
regularized VAR-KF problem (eq. 25/27) and the updates are assembled
(eq. 28).  The only inter-processor communication per iteration is

    Ax = sum_j A_j x_j            (one all-reduce of an m-vector)

plus the boundary/overlap exchange folded into the assembly — exactly the
communication structure the paper counts in its overhead T^p_oh.

Two execution paths share the same step function:
  * ``solve_vmapped``   — subdomains on the leading axis of a batch
                          (single-device correctness/reference path);
  * ``solve_shardmap``  — one device per subdomain on a 1D chain or a
                          2D ``pr x pc`` grid mesh (the production path,
                          exercised under forced multi-device XLA in
                          tests and by the launch dry-run).  The m-vector
                          all-reduce is a ``psum`` or — in the dense-
                          network regime m >> n — a ``psum_scatter`` +
                          ``all_gather`` pair; the overlap exchange is
                          either the same reduce-scatter pair on the
                          (n,) assembly (``comm="allreduce"``) or
                          neighbour-only ``ppermute`` rounds of just the
                          halo slots over the decomposition's coloured
                          edge schedule (``comm="neighbour"`` — the
                          paper's T^p_oh pattern: per-iteration traffic
                          proportional to the overlap width s, not n).
                          :func:`comm_model` prices both paths.

Static shapes: local blocks are padded to the max block width; padded
columns carry an identity diagonal in the local normal matrix and zero
right-hand side, so their solution stays exactly zero.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import cls as cls_mod
from repro.core import dd as dd_mod
from repro.core import _compat
from repro.kernels import ops as ops_mod


@partial(jax.tree_util.register_dataclass,
         data_fields=("A_loc", "L_loc", "cols", "mask", "muov", "wdiv",
                      "mult", "mult_loc", "scatter_cols", "gather_cols",
                      "r", "b"),
         meta_fields=("n", "p", "w", "solve_kernel", "solve_block"))
@dataclasses.dataclass(frozen=True)
class PackedDD:
    """Host-side packing of a Decomposition into padded device arrays."""

    A_loc: jax.Array      # (p, m, w) local column blocks, zero-padded
    L_loc: jax.Array      # (p, w, w) Cholesky of local normal matrices
    cols: jax.Array       # (p, w) global column index per local slot (or -1)
    mask: jax.Array       # (p, w) 1.0 for real columns, 0.0 for padding
    muov: jax.Array       # (p, w) mu on overlap slots (regularization)
    wdiv: jax.Array       # (p, w) mask / column-multiplicity: partition of
                          # unity so sum_i A_i (x_i * wdiv_i) == A x_glob
    mult: jax.Array       # (n,) column multiplicity (overlap counting)
    mult_loc: jax.Array   # (p, w) multiplicity gathered to local slots
                          # (1.0 on padding) — the neighbour-exchange
                          # assembly divisor
    scatter_cols: jax.Array  # (p, w) cols with padding redirected to the
                             # dump slot n — precomputed scatter map
    gather_cols: jax.Array   # (p, w) cols with padding clipped to 0 —
                             # precomputed (mask-guarded) gather map
    r: jax.Array          # (m,) weight diagonal
    b: jax.Array          # (m,) stacked data
    n: int
    p: int
    w: int
    solve_kernel: str = "jnp"   # resolved iteration-kernel path: "jnp" |
                                # "fused" | "fused_interpret" | "fused_ref"
    solve_block: int | None = None  # autotuned fused-kernel m-tile (None
                                    # when the path has no blocking)

    @property
    def m(self) -> int:
        """Stacked row count (background + observation rows)."""
        return int(self.r.shape[0])

    def edge_send_bytes(self, halo: "dd_mod.HaloExchange") -> dict:
        """Per-iteration bytes each endpoint of each halo edge sends on
        the ``comm='neighbour'`` path, priced at this packing's dtype."""
        return halo.edge_send_bytes(np.dtype(self.A_loc.dtype).itemsize)

    def comm_stats(self, halo: "dd_mod.HaloExchange | None" = None,
                   comm: str = "allreduce",
                   mesh_shape: tuple | None = None) -> dict:
        """Modelled per-iteration communication volume for this packing
        (see :func:`comm_model`)."""
        return comm_model(self.n, self.m, self.p,
                          np.dtype(self.A_loc.dtype).itemsize,
                          halo=halo, comm=comm, mesh_shape=mesh_shape)


# Dense-network regime switch: when the stacked row count m is at least
# this multiple of n, the (m,) observation-space product dominates the
# per-iteration traffic and the solve reduce-scatters it along the
# innermost mesh axis (bandwidth-optimal all-reduce) instead of a plain
# psum — the ROADMAP "psum_scatter the (m,) product when m >> n" item.
MVEC_SCATTER_RATIO = 2.0


def _axis_allreduce_elems(length: int, mesh_shape: tuple) -> list:
    """Per-device element sends of the hierarchical all-reduce
    ``solve_shardmap.axis_allreduce`` actually runs, per mesh axis.

    Outer axes take a *plain psum* of the full vector — on a torus that
    is a neighbour-hop ring without a scatter, so each of the (k - 1)
    hops moves the whole ``length``-vector: ``(k - 1) * length`` element
    sends per device.  Only the innermost axis gets the
    bandwidth-optimal reduce-scatter + all-gather pair at
    ``2 * (k - 1) / k * length``.  Pricing them identically (the old
    single-ring model) understates outer-axis cost on any mesh with
    more than one axis.
    """
    per_axis = []
    for i, k in enumerate(mesh_shape):
        k = int(k)
        if k <= 1:
            per_axis.append(0.0)
        elif i == len(mesh_shape) - 1:
            per_axis.append(2.0 * (k - 1) / k * length)
        else:
            per_axis.append(float(k - 1) * length)
    return per_axis


def comm_model(n: int, m: int, p: int, itemsize: int,
               halo: "dd_mod.HaloExchange | None" = None,
               comm: str = "allreduce",
               mesh_shape: tuple | None = None) -> dict:
    """Modelled per-iteration send volume of one ``solve_shardmap`` sweep.

    The model counts payload bytes leaving each device per Schwarz
    iteration, the quantity the paper's overhead term T^p_oh charges:

      * ``mvec`` — the (m,) observation-space product every path
        all-reduces, priced per mesh axis (``mesh_shape``, outer to
        inner; default ``(p,)``): outer axes pay full-vector psum hops,
        the innermost the bandwidth-optimal reduce-scatter + all-gather
        ring — see :func:`_axis_allreduce_elems`.
      * state exchange — ``comm="allreduce"``: the (n,)-assembled
        estimate through the same per-axis hierarchy, *independent of
        the overlap width*; ``comm="neighbour"``: only the halo slots,
        ``sum(|shared|)`` elements per edge endpoint — proportional to
        the overlap width s and to nothing else.

    Returns a JSON-ready dict with per-device and total bytes, the
    per-axis mvec breakdown, and the per-edge breakdown (empty for the
    allreduce path).
    """
    if comm not in ("allreduce", "neighbour"):
        raise ValueError(f"comm must be 'allreduce' or 'neighbour' "
                         f"(got {comm!r})")
    mesh_shape = tuple(int(k) for k in (mesh_shape or (p,)))
    if int(np.prod(mesh_shape)) != p:
        raise ValueError(f"mesh_shape {mesh_shape} does not factor "
                         f"p={p} devices")
    mvec_axis = [e * itemsize for e in _axis_allreduce_elems(m, mesh_shape)]
    mvec_dev = float(sum(mvec_axis))
    if comm == "allreduce":
        state_axis = [e * itemsize
                      for e in _axis_allreduce_elems(n, mesh_shape)]
        state_dev = np.full((p,), sum(state_axis))
        per_edge: dict = {}
        rounds = 0
    else:
        if halo is None:
            raise ValueError("comm='neighbour' needs the decomposition's "
                             "halo_exchange metadata")
        state_dev = halo.device_send_bytes(itemsize).astype(np.float64)
        per_edge = halo.edge_send_bytes(itemsize)
        rounds = halo.rounds
    return {
        "comm": comm,
        "mesh_shape": list(mesh_shape),
        "mvec_bytes_per_device": mvec_dev,
        "mvec_bytes_per_device_per_axis": [float(b) for b in mvec_axis],
        "state_bytes_per_device_max": float(state_dev.max(initial=0.0)),
        "state_bytes_per_device_mean": float(state_dev.mean()
                                             if p else 0.0),
        "state_bytes_total": float(state_dev.sum()),
        "bytes_per_iter_total": float(state_dev.sum() + p * mvec_dev),
        "per_edge_bytes": per_edge,
        "permute_rounds": rounds,
    }


def pack(prob: cls_mod.CLSProblem, dec: dd_mod.Decomposition,
         mu: float = 1.0, solver_kernel: str = "auto") -> PackedDD:
    A = jnp.concatenate([prob.H0, prob.H1], axis=0)
    r = jnp.concatenate([prob.R0, prob.R1])
    b = jnp.concatenate([prob.y0, prob.y1])
    return with_rhs(pack_operator(A, r, dec, mu=mu,
                                  solver_kernel=solver_kernel), b)


# Iteration-kernel selection: how the per-iteration local step runs.
# "jnp" is the historic composition (three HBM passes over A_loc per
# iteration, bit-identical to every previous release); the "fused_*"
# variants run the two-pass fused step of ``kernels/schwarz_step.py``
# through the matching ops-mode ("fused" resolves per backend: the
# native Pallas kernel on TPU, the single-pass stacked-matmat jnp
# reference elsewhere; "fused_interpret" forces the kernel in interpret
# mode — the CPU-CI ULP-parity path; "fused_ref" forces the reference).
SOLVER_KERNELS = ("auto", "jnp", "fused", "fused_interpret", "fused_ref")
_KERNEL_OPS_MODE = {"fused": "auto", "fused_interpret": "interpret",
                    "fused_ref": "ref"}


def _resolve_solver_kernel(solver_kernel: str) -> str:
    if solver_kernel not in SOLVER_KERNELS:
        raise ValueError(f"solver_kernel must be one of {SOLVER_KERNELS} "
                         f"(got {solver_kernel!r})")
    if solver_kernel == "auto":
        # Default to the fused kernel only where it is a different (and
        # faster) program: on TPU.  Elsewhere "auto" keeps the historic
        # jnp composition so default numerics stay bit-identical.
        return "fused" if jax.default_backend() == "tpu" else "jnp"
    return solver_kernel


@partial(jax.jit, static_argnames=("gram_mode", "gram_block"))
def _factor_batched(A_loc: jax.Array, r: jax.Array, diag_add: jax.Array,
                    gram_mode: str = "auto",
                    gram_block: int | None = None) -> jax.Array:
    """Batched local normal matrices + Cholesky factors, on device.

    N_i = A_i^T diag(r) A_i comes from the ``kernels.ops.gram`` kernel
    (Pallas on TPU, jnp reference elsewhere); ``diag_add`` carries the
    mu-regularization on overlap slots plus the identity on padded slots
    that keeps every factor nonsingular.  ``gram_block`` is the autotuned
    reduction tile, resolved by the caller outside jit
    (``ops.gram_block_for``).
    """
    p = A_loc.shape[0]
    N = ops_mod.gram(A_loc, jnp.broadcast_to(r, (p, r.shape[0])),
                     mode=gram_mode, block_m=gram_block)
    N = N + jax.vmap(jnp.diag)(diag_add.astype(N.dtype))
    return jax.vmap(jnp.linalg.cholesky)(N)


def pack_operator(A: jax.Array, r: jax.Array, dec: dd_mod.Decomposition,
                  mu: float = 1.0, gram_mode: str = "auto",
                  solver_kernel: str = "auto") -> PackedDD:
    """Pack the *operator* part of a decomposed CLS problem.

    The host slices the p column blocks into the padded (p, m, w) layout;
    the p local normal matrices N_i = A_i^T diag(r) A_i and their Cholesky
    factors are then built *on device* in one batched shot
    (:func:`_factor_batched`: ``kernels.ops.gram`` + ``vmap(cholesky)``)
    instead of a per-subdomain ``np.linalg.cholesky`` Python loop.  The
    packing depends only on (A, r, dec), not on the data vector b, so the
    streaming engine runs it for cycle t+1 while the device is solving
    cycle t, then injects the cycle's rhs with :func:`with_rhs` (a cheap
    ``dataclasses.replace``).

    ``gram_mode`` selects the kernel path ("auto": Pallas on TPU, jnp
    reference elsewhere — see :mod:`repro.kernels.ops`).
    ``solver_kernel`` selects the per-iteration step path the solves will
    run (:data:`SOLVER_KERNELS`); it is resolved here, host-side — the
    fused paths autotune their ``block_m`` once per shape
    (``ops.schwarz_block_for``) and the choice rides along statically in
    the packing's meta fields.

    The returned ``PackedDD`` carries a zero rhs; pass it through
    :func:`with_rhs` before solving.
    """
    m, n = A.shape
    p = dec.p
    w = max(1, max(int(np.asarray(c).shape[0]) for c in dec.col_sets))

    # Per-column multiplicity is the decomposition's source of truth: the
    # halo columns (multiplicity > 1) carry the mu-regularization and the
    # 1/multiplicity partition-of-unity assembly weight, on any graph.
    counts = dec.column_multiplicity
    halo_mu = dec.has_overlap and mu > 0.0

    A_loc = np.zeros((p, m, w), dtype=np.asarray(A).dtype)
    cols = -np.ones((p, w), dtype=np.int64)
    mask = np.zeros((p, w), dtype=np.asarray(A).dtype)
    muov = np.zeros((p, w), dtype=np.asarray(A).dtype)
    A_np = np.asarray(A)
    for i, c in enumerate(dec.col_sets):
        c = np.asarray(c)
        k = c.shape[0]
        A_loc[i, :, :k] = A_np[:, c]
        cols[i, :k] = c
        mask[i, :k] = 1.0
        if halo_mu:
            muov[i, :k] = mu * (counts[c] > 1).astype(muov.dtype)
    A_loc = jnp.asarray(A_loc)
    r = jnp.asarray(r, A_loc.dtype)
    # mu on overlap slots; identity on padded slots (mask == 0).  The
    # gram reduction tile is autotuned host-side (first call per shape,
    # cached) and handed to the jitted factor build as a static arg.
    gram_block = ops_mod.gram_block_for((p, m, w), A_loc.dtype,
                                        mode=gram_mode)
    L_loc = _factor_batched(A_loc, r, jnp.asarray(muov + (1.0 - mask)),
                            gram_mode=gram_mode, gram_block=gram_block)
    solve_kernel = _resolve_solver_kernel(solver_kernel)
    solve_block = (ops_mod.schwarz_block_for(
        (p, m, w), A_loc.dtype, mode=_KERNEL_OPS_MODE[solve_kernel])
        if solve_kernel != "jnp" else None)
    mult_at = np.maximum(counts, 1)[np.clip(cols, 0, n - 1)]
    wdiv = mask / mult_at
    # Precomputed index maps: scatter redirects padding to the dump slot
    # n, gather clips it to 0 (mask kills the value) — built once here
    # instead of a where(cols >= 0, ...) membership mask per call.
    mult_loc = np.where(cols >= 0, mult_at, 1.0)
    scatter_cols = np.where(cols >= 0, cols, n)
    gather_cols = np.where(cols >= 0, cols, 0)
    return PackedDD(A_loc=A_loc, L_loc=L_loc,
                    cols=jnp.asarray(cols), mask=jnp.asarray(mask),
                    muov=jnp.asarray(muov), wdiv=jnp.asarray(wdiv),
                    mult=jnp.asarray(np.maximum(counts, 1)).astype(A.dtype),
                    mult_loc=jnp.asarray(mult_loc, A_loc.dtype),
                    scatter_cols=jnp.asarray(scatter_cols),
                    gather_cols=jnp.asarray(gather_cols),
                    r=r, b=jnp.zeros((m,), dtype=A_loc.dtype), n=n, p=p,
                    w=w, solve_kernel=solve_kernel, solve_block=solve_block)


def with_rhs(packed: PackedDD, b: jax.Array) -> PackedDD:
    """Inject the data vector b = [y0; y1] into an operator-only packing."""
    return dataclasses.replace(packed, b=jnp.asarray(b, packed.A_loc.dtype))


def pad_packed_width(packed: PackedDD, w_new: int) -> PackedDD:
    """Re-pad a packing to a larger local block width ``w_new``.

    Different cycles of a stream decompose with different max block
    widths (DyDD moves boundaries), so their packings cannot be stacked
    (:func:`stack_packed` requires equal ``w``).  Padding widens every
    per-slot field with the same conventions ``pack_operator`` uses for
    its own padding — zero columns in ``A_loc``, identity diagonal in
    ``L_loc``, ``cols=-1``/``mask=0``, multiplicity 1, scatter to the
    dump slot ``n`` — so the padded slots solve to exactly zero and the
    assembled estimate is unchanged up to reduction order.  This is a
    *tolerance-path* helper (the window-stacked Parareal fine solves):
    widening changes the einsum reduction extents, so results agree with
    the unpadded solve to ULPs, not bitwise.
    """
    if w_new < packed.w:
        raise ValueError(f"cannot shrink a packing: w={packed.w} -> "
                         f"{w_new}")
    if w_new == packed.w:
        return packed
    pad = w_new - packed.w
    p, w = packed.p, packed.w
    L = jnp.zeros((p, w_new, w_new), packed.L_loc.dtype)
    L = L.at[:, :w, :w].set(packed.L_loc)
    diag = jnp.arange(w, w_new)
    L = L.at[:, diag, diag].set(1.0)
    pad2 = ((0, 0), (0, pad))
    return dataclasses.replace(
        packed,
        A_loc=jnp.pad(packed.A_loc, ((0, 0), (0, 0), (0, pad))),
        L_loc=L,
        cols=jnp.pad(packed.cols, pad2, constant_values=-1),
        mask=jnp.pad(packed.mask, pad2),
        muov=jnp.pad(packed.muov, pad2),
        wdiv=jnp.pad(packed.wdiv, pad2),
        mult_loc=jnp.pad(packed.mult_loc, pad2, constant_values=1.0),
        scatter_cols=jnp.pad(packed.scatter_cols, pad2,
                             constant_values=packed.n),
        gather_cols=jnp.pad(packed.gather_cols, pad2),
        w=w_new)


def _chol_solve(L, rhs):
    z = jax.scipy.linalg.solve_triangular(L, rhs, lower=True)
    return jax.scipy.linalg.solve_triangular(L.T, z, lower=False)


def _local_update(A_i, L_i, mask_i, muov_i, x_i, Ax, r, b):
    """One local regularized VAR-KF solve given the global product Ax
    (eq. 25/27): the mu-term anchors the overlap slots to the current
    consistent global iterate x_i (= x_glob gathered)."""
    resid = b - Ax + A_i @ x_i
    rhs = (A_i.T @ (r * resid) + muov_i * x_i) * mask_i
    return _chol_solve(L_i, rhs) * mask_i


# ---------------------------------------------------------------------------
# Reference path: subdomains on a batch axis.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("iters", "residual_history"))
def solve_vmapped(packed: PackedDD, iters: int = 60,
                  damping: float = 1.0,
                  residual_history: bool = False,
                  x0=None):
    """Additive-Schwarz DD-KF; returns the assembled global estimate.

    With ``residual_history=True`` the iteration runs under ``lax.scan``
    and the call returns ``(x, hist)`` where ``hist[k]`` is the global
    update norm ``||x_loc^{k+1} - x_loc^k||_F`` — the per-iteration
    Schwarz residual history the observability layer journals.  The
    default path is the historic ``fori_loop`` (identical numerics, no
    per-iteration output).

    ``x0`` is an optional (n,) global warm start: the iteration begins
    from its local gather instead of zeros.  The Schwarz map contracts to
    the same fixed point from any start, so a warm start from a nearby
    estimate (e.g. a coarse Parareal trajectory) buys the same accuracy
    in fewer iterations; ``x0=None`` keeps the historic zero start
    bitwise.

    The per-iteration local step follows the packing's resolved
    ``solve_kernel``: the historic jnp composition, or the fused
    two-pass step of :mod:`repro.kernels.schwarz_step` (reduction-order
    ULP parity with the jnp path).
    """
    kern = packed.solve_kernel

    def step(x_loc):
        if kern == "jnp":
            # partition of unity: overlap columns contribute once to
            # A x_glob
            Ax_parts = jnp.einsum("pmw,pw->pm", packed.A_loc,
                                  x_loc * packed.wdiv)
            Ax = jnp.sum(Ax_parts, axis=0)
            new = jax.vmap(
                lambda A_i, L_i, m_i, mu_i, x_i: _local_update(
                    A_i, L_i, m_i, mu_i, x_i, Ax, packed.r, packed.b)
            )(packed.A_loc, packed.L_loc, packed.mask, packed.muov, x_loc)
        else:
            mode = _KERNEL_OPS_MODE[kern]
            y, u = ops_mod.schwarz_fwd(packed.A_loc, x_loc, packed.wdiv,
                                       mode=mode,
                                       block_m=packed.solve_block)
            Ax = jnp.sum(y, axis=0)
            rhs = ops_mod.schwarz_bwd(packed.A_loc, packed.r, packed.b,
                                      Ax, u, x_loc, packed.muov,
                                      packed.mask, mode=mode,
                                      block_m=packed.solve_block)
            new = jax.vmap(_chol_solve)(packed.L_loc, rhs) * packed.mask
        x_loc2 = (1.0 - damping) * x_loc + damping * new
        # Overlap consistency: average duplicated columns globally, then
        # gather back (eq. 28).
        x_glob = assemble(packed, x_loc2)
        return gather_local(packed, x_glob)

    if x0 is None:
        x_init = jnp.zeros((packed.p, packed.w), dtype=packed.A_loc.dtype)
    else:
        x_init = gather_local(packed, jnp.asarray(x0, packed.A_loc.dtype))
    if not residual_history:
        x_loc = jax.lax.fori_loop(0, iters, lambda _, x: step(x), x_init)
        return assemble(packed, x_loc)

    def body(x_loc, _):
        nxt = step(x_loc)
        return nxt, jnp.linalg.norm(nxt - x_loc)

    x_loc, hist = jax.lax.scan(body, x_init, None, length=iters)
    return assemble(packed, x_loc), hist


def assemble(packed: PackedDD, x_loc: jax.Array) -> jax.Array:
    """Scatter local iterates into the global vector, averaging overlaps.

    Uses the scatter map precomputed at pack time (padding lands on the
    dump slot n) — no per-call membership mask rebuild."""
    acc = jnp.zeros((packed.n + 1,), dtype=x_loc.dtype)
    acc = acc.at[packed.scatter_cols.reshape(-1)].add(
        (x_loc * packed.mask).reshape(-1))
    return acc[:packed.n] / packed.mult


def gather_local(packed: PackedDD, x_glob: jax.Array) -> jax.Array:
    return x_glob[packed.gather_cols] * packed.mask


# ---------------------------------------------------------------------------
# Fleet path: independent *problems* on a leading batch axis.
# ---------------------------------------------------------------------------

def stack_packed(packs) -> PackedDD:
    """Stack same-shape packings onto a leading *problem* axis.

    Every data field gains a leading axis of size ``len(packs)`` (the
    fleet/cohort axis); the meta fields — which must agree exactly across
    the stack, including the resolved ``solve_kernel``/``solve_block`` —
    are carried through unchanged.  The result is what
    :func:`solve_fleet` consumes: one device dispatch advancing every
    problem in the cohort.

    Shape agreement is a *cohort key* responsibility of the caller
    (``repro.assim.fleet`` buckets streams by it); a mismatch here is a
    programming error and raises.
    """
    packs = list(packs)
    if not packs:
        raise ValueError("stack_packed needs at least one packing")
    ref = packs[0]
    key0 = (ref.n, ref.p, ref.w, ref.m, ref.solve_kernel, ref.solve_block,
            ref.A_loc.dtype)
    for pk in packs[1:]:
        key = (pk.n, pk.p, pk.w, pk.m, pk.solve_kernel, pk.solve_block,
               pk.A_loc.dtype)
        if key != key0:
            raise ValueError(
                f"cannot stack packings with different shapes/kernels: "
                f"{key} vs {key0} — bucket them into separate cohorts")
    # One jitted dispatch for all ~12 field stacks (cached per pytree
    # structure/shape, i.e. per (cohort shape, capacity) — bounded by the
    # serving layer's capacity quantization).  Eager per-field jnp.stack
    # costs a device dispatch per field per round, which dominated the
    # fleet's round overhead.
    return _stack_jit(tuple(packs))


@jax.jit
def _stack_jit(packs):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *packs)


@partial(jax.jit, static_argnames=("iters", "residual_history"))
def _solve_fleet_map(stacked: PackedDD, iters: int, damping,
                     residual_history: bool):
    return jax.lax.map(
        lambda pk: solve_vmapped(pk, iters=iters, damping=damping,
                                 residual_history=residual_history),
        stacked)


@partial(jax.jit, static_argnames=("iters", "residual_history"))
def _solve_fleet_map_warm(stacked: PackedDD, x0, iters: int, damping,
                          residual_history: bool):
    # Separate jit from the cold path so x0=None callers keep their
    # historic trace (and bitwise output) untouched.
    return jax.lax.map(
        lambda arg: solve_vmapped(arg[0], iters=iters, damping=damping,
                                  residual_history=residual_history,
                                  x0=arg[1]),
        (stacked, x0))


def _fleet_sharded_fn(mesh, axis: str, iters: int, residual_history: bool):
    """Jitted shard_map of the per-problem sweep over the fleet mesh axis
    (cached per (mesh, axis, iters, residual_history) — mesh objects
    hash)."""
    key = (mesh, axis, iters, residual_history)
    fn = _FLEET_SHARDED_CACHE.get(key)
    if fn is not None:
        return fn

    def body(pk, damping):
        return jax.lax.map(
            lambda q: solve_vmapped(q, iters=iters, damping=damping,
                                    residual_history=residual_history),
            pk)

    fn = jax.jit(_compat.shard_map(
        body, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(axis)))
    _FLEET_SHARDED_CACHE[key] = fn
    return fn


_FLEET_SHARDED_CACHE: dict = {}


def solve_fleet(stacked: PackedDD, iters: int = 60, damping: float = 1.0,
                residual_history: bool = False, mesh=None,
                axis: str = "fleet", x0=None):
    """Advance every problem of a stacked cohort one solve in one dispatch.

    The per-problem sweep is ``lax.map`` over the leading problem axis —
    each problem executes the *identical op graph* as a standalone
    :func:`solve_vmapped` call, so the fleet results are **bitwise
    identical** to per-problem solves (an extra ``vmap`` axis would
    reassociate the matvec/triangular-solve reductions; ``lax.map`` does
    not).  With ``mesh=`` the problem axis is additionally sharded over
    the ``axis`` mesh axis via ``shard_map`` — one slice of the cohort
    per device, still ``lax.map`` inside, still bitwise — which is where
    the fleet throughput comes from on real multi-core/multi-device
    hardware (the cohort size must divide evenly; the serving layer pads
    cohorts with dummy slots to the mesh multiple).

    Returns the (S, n) stacked estimates, or ``(x, hist)`` with ``hist``
    of shape (S, iters) under ``residual_history=True``.

    ``x0`` (single-device path only) is an optional (S, n) stack of
    global warm starts, one per problem — see :func:`solve_vmapped`.
    """
    if mesh is None:
        if x0 is not None:
            return _solve_fleet_map_warm(
                stacked, jnp.asarray(x0, stacked.A_loc.dtype),
                iters=iters, damping=damping,
                residual_history=residual_history)
        return _solve_fleet_map(stacked, iters=iters, damping=damping,
                                residual_history=residual_history)
    if x0 is not None:
        raise NotImplementedError(
            "solve_fleet warm start is single-device only (the sharded "
            "fleet path has no x0 plumbing)")
    k = int(mesh.shape[axis])
    S = int(stacked.A_loc.shape[0])
    if S % k:
        raise ValueError(
            f"cohort size {S} does not divide over the {k}-device "
            f"'{axis}' mesh axis — pad the cohort to a multiple of {k}")
    fn = _fleet_sharded_fn(mesh, axis, iters, residual_history)
    return fn(stacked, damping)


# ---------------------------------------------------------------------------
# Production path: subdomains sharded over a mesh axis.
# ---------------------------------------------------------------------------

def solve_shardmap(packed: PackedDD, mesh, axis="sub",
                   iters: int = 60, damping: float = 1.0,
                   comm: str = "allreduce",
                   halo: "dd_mod.HaloExchange | None" = None,
                   mvec: str = "auto",
                   residual_history: bool = False,
                   return_per_device: bool = False):
    """Same iteration with one device per subdomain, on a 1D or 2D mesh.

    ``axis`` is one mesh axis name or a tuple of names — pass
    ``("row", "col")`` to run subdomain ``r * pc + c`` on device (r, c)
    of a ``pr x pc`` mesh (the paper's processor topology: grid axes map
    onto the mesh axes, so neighbour-halo traffic stays on-axis).

    Per iteration the communication is the all-reduce of the (m,)
    observation-space product — ``mvec="psum"`` as a plain psum, or
    ``mvec="scatter"`` as the bandwidth-optimal reduce-scatter +
    all-gather pair along the innermost axis; ``"auto"`` picks scatter
    in the dense-network regime (m >= ``MVEC_SCATTER_RATIO`` * n, read
    off the packed shapes) — plus the overlap-consistency exchange of
    the state estimate, with two paths:

      * ``comm="allreduce"`` — assemble the full (n,) global estimate
        with psum_scatter + all_gather along the innermost mesh axis and
        gather back.  O(n) bytes per device per iteration regardless of
        the overlap width.
      * ``comm="neighbour"`` — the paper's T^p_oh communication pattern:
        ``jax.lax.ppermute`` rounds over the precomputed edge schedule
        (``halo`` = the decomposition's cached ``halo_exchange``; one
        permute per graph-colouring class), exchanging *only the halo
        slots*.  O(s) bytes per device per iteration — proportional to
        the overlap width, not the problem size.  Multiplicity-1 columns
        never leave their device; the single full-vector assembly happens
        once, after the final iteration, to emit the global estimate.

    Both paths iterate the identical additive-Schwarz update and agree to
    reduction-order ULPs (collective associativity only).

    Observability hooks: ``residual_history=True`` switches the inner
    loop to ``lax.scan`` and returns ``(x, hist)`` with ``hist[k]`` the
    psum'd global update norm per iteration (identical on every device);
    ``return_per_device=True`` returns the full sharded (p, n) assembly
    instead of row 0, so the caller can observe per-device shard-ready
    times (``x.addressable_shards``) before collapsing to the global
    estimate — what feeds the straggler monitor's per-device rows.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    sizes = [mesh.shape[a] for a in axes]
    if int(np.prod(sizes)) != packed.p:
        raise ValueError(
            f"mesh axes {axes} have {int(np.prod(sizes))} devices but the "
            f"packing has p={packed.p} subdomains")
    if comm not in ("allreduce", "neighbour"):
        raise ValueError(f"comm must be 'allreduce' or 'neighbour' "
                         f"(got {comm!r})")
    if comm == "neighbour":
        if halo is None:
            raise ValueError(
                "comm='neighbour' needs the halo-exchange schedule: pass "
                "halo=dec.halo_exchange (cached on the Decomposition)")
        if halo.p != packed.p or halo.w != packed.w:
            raise ValueError(
                f"halo schedule shape (p={halo.p}, w={halo.w}) does not "
                f"match the packing (p={packed.p}, w={packed.w})")
    if mvec == "auto":
        mvec = ("scatter" if packed.m >= MVEC_SCATTER_RATIO * packed.n
                else "psum")
    if mvec not in ("psum", "scatter"):
        raise ValueError(f"mvec must be 'auto', 'psum' or 'scatter' "
                         f"(got {mvec!r})")
    ppermute_axis = axes if len(axes) > 1 else axes[0]
    # Innermost axis carries the scatters; pad the reduced vectors so
    # their length splits evenly (the n-vector keeps one extra slot as
    # the -1-column dump).
    ks = int(mesh.shape[axes[-1]])
    n_pad = -(-(packed.n + 1) // ks) * ks
    m_pad = -(-packed.m // ks) * ks

    def axis_allreduce(part):
        """All-reduce a ks-divisible vector over every mesh axis: plain
        psum on the outer axes, reduce-scatter + all-gather (the
        bandwidth-optimal all-reduce on a torus) on the innermost."""
        if len(axes) > 1:
            part = jax.lax.psum(part, axes[:-1])
        chunk = jax.lax.psum_scatter(part, axes[-1], scatter_dimension=0,
                                     tiled=True)
        return jax.lax.all_gather(chunk, axes[-1], tiled=True)

    def mvec_allreduce(part):
        if mvec == "psum":
            return jax.lax.psum(part, axes)
        pad = m_pad - packed.m
        if pad:
            part = jnp.concatenate([part, jnp.zeros((pad,), part.dtype)])
        return axis_allreduce(part)[:packed.m]

    # Neighbour-path schedule arrays (sharded like the packing).  The
    # perms and round count are static Python; only the per-device
    # pack/unpack payload maps travel as operands — int32 end to end
    # (the schedule indexes w + 1 <= 2^31 slots; int64 operands would
    # silently downcast under default x32 and double the index payload).
    rounds = halo.rounds if comm == "neighbour" else 0
    empty = np.zeros((packed.p, 0, 0), np.int32)
    pack_idx = jnp.asarray(halo.pack_idx if comm == "neighbour" else empty,
                           jnp.int32)
    unpack_idx = jnp.asarray(halo.unpack_idx if comm == "neighbour"
                             else empty, jnp.int32)
    kern = packed.solve_kernel

    def per_device(A_i, L_i, mask_i, muov_i, wdiv_i, scat_i, gath_i,
                   mloc_i, pack_i, unpack_i):
        # Leading axis of size 1 (= this device's subdomain).
        (A_i, L_i, mask_i, muov_i, wdiv_i, scat_i, gath_i, mloc_i,
         pack_i, unpack_i) = (A_i[0], L_i[0], mask_i[0], muov_i[0],
                              wdiv_i[0], scat_i[0], gath_i[0], mloc_i[0],
                              pack_i[0], unpack_i[0])

        def scatter_part(x_i):
            # scat_i parks padding on slot n (< n_pad): same dump trick.
            return jnp.zeros((n_pad,), x_i.dtype).at[scat_i].add(
                x_i * mask_i)

        def exchange_allreduce(x_i2):
            # Overlap consistency (eq. 28): multiplicity-weighted average
            # of the duplicated columns via the global assembly, then
            # gather back.
            x_glob = axis_allreduce(scatter_part(x_i2))[:packed.n] \
                / packed.mult
            return x_glob[gath_i] * mask_i

        def exchange_neighbour(x_i2):
            # Same average, neighbour-only: own contribution plus the
            # halo slots received over the directed coloured rounds,
            # divided by the local multiplicity.  Each round is ONE
            # ppermute of one packed h-lane buffer — this device gathers
            # its outgoing payload at pack_idx (send partner) and
            # scatter-adds the received buffer at unpack_idx (recv
            # partner, not necessarily the same device) — exactly
            # halo.rounds permutes per iteration however many edges meet
            # here.  Slot w is the dump: it gathers zero (payload
            # padding) and absorbs scatter padding.
            xm = x_i2 * mask_i
            acc = jnp.concatenate([xm, jnp.zeros((1,), xm.dtype)])
            xm_pad = acc
            for rnd in range(rounds):
                buf = xm_pad[pack_i[rnd]]
                got = jax.lax.ppermute(buf, ppermute_axis,
                                       perm=halo.perms[rnd])
                acc = acc.at[unpack_i[rnd]].add(got)
            return acc[:packed.w] / mloc_i

        exchange = (exchange_neighbour if comm == "neighbour"
                    else exchange_allreduce)

        def step(x_i):
            if kern == "jnp":
                Ax = mvec_allreduce(A_i @ (x_i * wdiv_i))
                new = _local_update(A_i, L_i, mask_i, muov_i, x_i, Ax,
                                    packed.r, packed.b)
            else:
                mode = _KERNEL_OPS_MODE[kern]
                y, u = ops_mod.schwarz_fwd(A_i[None], x_i[None],
                                           wdiv_i[None], mode=mode,
                                           block_m=packed.solve_block)
                Ax = mvec_allreduce(y[0])
                rhs = ops_mod.schwarz_bwd(A_i[None], packed.r, packed.b,
                                          Ax, u, x_i[None], muov_i[None],
                                          mask_i[None], mode=mode,
                                          block_m=packed.solve_block)[0]
                new = _chol_solve(L_i, rhs) * mask_i
            return exchange((1.0 - damping) * x_i + damping * new)

        x_i = jnp.zeros((packed.w,), dtype=A_i.dtype)
        if residual_history:
            # Per-iteration global update norm: local squared delta,
            # psum'd over the whole mesh — every device carries the
            # identical history (overlap slots count with multiplicity,
            # matching solve_vmapped's (p, w) Frobenius norm).
            def sbody(x_prev, _):
                nxt = step(x_prev)
                d2 = jax.lax.psum(jnp.sum((nxt - x_prev) ** 2), axes)
                return nxt, jnp.sqrt(d2)

            x_i, hist = jax.lax.scan(sbody, x_i, None, length=iters)
        else:
            x_i = jax.lax.fori_loop(0, iters, lambda _, x: step(x), x_i)
            hist = jnp.zeros((0,), dtype=A_i.dtype)
        # One full assembly at the end (both paths): emit the global
        # estimate.  On the neighbour path this is the only O(n)
        # collective of the whole solve.
        return ((axis_allreduce(scatter_part(x_i))[:packed.n]
                 / packed.mult)[None], hist[None])

    specs = P(axes if len(axes) > 1 else axes[0])
    fn = _compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(specs,) * 10,
        out_specs=(specs, specs))
    out, hist = fn(packed.A_loc, packed.L_loc, packed.mask, packed.muov,
                   packed.wdiv, packed.scatter_cols, packed.gather_cols,
                   packed.mult_loc, pack_idx, unpack_idx)
    x = out if return_per_device else out[0]
    if residual_history:
        return x, hist[0]
    return x


# ---------------------------------------------------------------------------
# Parallel-in-time path: independent *windows* x subdomains on a
# ("time", "sub") mesh.
# ---------------------------------------------------------------------------

def _window_sharded_fn(mesh, time_axis: str, sub_axis: str, iters: int,
                       n: int):
    """Jitted shard_map of the window-stacked Schwarz sweep (cached per
    (mesh, axes, iters, n) — mesh objects hash; shapes recompile under
    jit as usual)."""
    key = (mesh, time_axis, sub_axis, iters, n)
    fn = _WINDOW_SHARDED_CACHE.get(key)
    if fn is not None:
        return fn
    ks = int(mesh.shape[sub_axis])
    # The (n,)-assembly keeps one extra slot as the -1-column dump and
    # must split evenly over the sub axis for the reduce-scatter pair.
    n_pad = -(-(n + 1) // ks) * ks

    def per_device(A, L, mask, muov, wdiv, scat, gath, mult, r, b, x0,
                   damping):
        # A: (Kl, pl, m, w) — this device's window slice x subdomain
        # slice; mult: (Kl, n); r, b, x0: (Kl, ·).  Windows are
        # independent problems: every collective reduces over ``sub``
        # only.
        def scatter_part(xm):
            def one(sc, x_k):
                return jnp.zeros((n_pad,), x_k.dtype).at[
                    sc.reshape(-1)].add(x_k.reshape(-1))
            return jax.vmap(one)(scat, xm)          # (Kl, n_pad)

        def assemble_glob(x):
            part = scatter_part(x * mask)
            chunk = jax.lax.psum_scatter(part, sub_axis,
                                         scatter_dimension=1, tiled=True)
            glob = jax.lax.all_gather(chunk, sub_axis, axis=1,
                                      tiled=True)   # (Kl, n_pad)
            return glob[:, :n] / mult               # (Kl, n)

        def step(x):
            # One additive-Schwarz iteration per window, batched over
            # this device's (Kl, pl) slice — the jnp composition of
            # solve_vmapped (fused-kernel packings ride this path too;
            # the two steps agree to reduction-order ULPs).
            Ax = jax.lax.psum(
                jnp.einsum("kpmw,kpw->km", A, x * wdiv), sub_axis)
            resid = (b[:, None, :] - Ax[:, None, :]
                     + jnp.einsum("kpmw,kpw->kpm", A, x))
            rhs = (jnp.einsum("kpmw,kpm->kpw", A, r[:, None, :] * resid)
                   + muov * x) * mask
            new = jax.vmap(jax.vmap(_chol_solve))(L, rhs) * mask
            x2 = (1.0 - damping) * x + damping * new
            x_glob = assemble_glob(x2)
            return jax.vmap(lambda xg, g: xg[g])(x_glob, gath) * mask

        # Warm start: gather the (Kl, n) global x0 into the local slots
        # (an all-zero x0 gathers to exactly the historic zero start).
        x_init = jax.vmap(lambda xg, g: xg[g])(x0, gath) * mask
        x = jax.lax.fori_loop(0, iters, lambda _, v: step(v), x_init)
        # (Kl, 1, n): the sub axis carries one replicated copy out.
        return assemble_glob(x)[:, None, :]

    ws = P(time_axis, sub_axis)
    wt = P(time_axis)
    fn = jax.jit(_compat.shard_map(
        per_device, mesh=mesh,
        in_specs=(ws, ws, ws, ws, ws, ws, ws, wt, wt, wt, wt, P()),
        out_specs=ws))
    _WINDOW_SHARDED_CACHE[key] = fn
    return fn


_WINDOW_SHARDED_CACHE: dict = {}


def solve_window_stack(stacked: PackedDD, mesh, time_axis: str = "time",
                       sub_axis: str = "sub", iters: int = 60,
                       damping: float = 1.0, x0=None) -> jax.Array:
    """Solve a window-stacked packing on a 2D ``("time", "sub")`` mesh.

    ``stacked`` is a :func:`stack_packed` result whose leading axis is K
    independent *windows* (one cycle's rhs-injected packing per active
    window of the Parareal fine sweep).  The window axis shards over
    ``time_axis`` and the subdomain axis over ``sub_axis`` — K * p
    problems-by-subdomains on kt * ks devices, multiplying the usable
    device count beyond the p-subdomain cap of :func:`solve_shardmap`.
    Every collective (the (m,) product psum and the overlap-consistency
    assembly's reduce-scatter + all-gather pair) runs over ``sub`` only:
    windows never communicate, which is what makes the time axis free
    parallelism.

    The iteration is the jnp additive-Schwarz composition of
    :func:`solve_vmapped` with allreduce state exchange — per-window
    results agree with standalone ``solve_vmapped`` calls to
    reduction-order ULPs (a tolerance contract; the Parareal driver's
    bitwise degeneration path never reaches this function).

    ``x0`` is an optional (K, n) stack of global warm starts, one per
    window — see :func:`solve_vmapped`.  None starts from zeros (the
    historic behaviour, bitwise).

    Returns the (K, n) per-window global estimates.
    """
    K = int(stacked.A_loc.shape[0])
    kt = int(mesh.shape[time_axis])
    ks = int(mesh.shape[sub_axis])
    if K % kt:
        raise ValueError(
            f"window count {K} does not divide over the {kt}-device "
            f"'{time_axis}' mesh axis — pad the stack to a multiple")
    if stacked.p % ks:
        raise ValueError(
            f"p={stacked.p} subdomains do not divide over the "
            f"{ks}-device '{sub_axis}' mesh axis")
    fn = _window_sharded_fn(mesh, time_axis, sub_axis, iters, stacked.n)
    dt = stacked.A_loc.dtype
    x0 = (jnp.zeros((K, stacked.n), dt) if x0 is None
          else jnp.asarray(x0, dt))
    out = fn(stacked.A_loc, stacked.L_loc, stacked.mask, stacked.muov,
             stacked.wdiv, stacked.scatter_cols, stacked.gather_cols,
             stacked.mult, stacked.r, stacked.b, x0,
             jnp.asarray(damping, dt))
    return out[:, 0]


# ---------------------------------------------------------------------------
# Convenience driver: DyDD + DD-KF end to end on a 1D domain.
# ---------------------------------------------------------------------------

def ddkf_with_dydd(prob: cls_mod.CLSProblem, obs_locations: np.ndarray,
                   p: int, overlap: int = 0, iters: int = 60,
                   mu: float = 1.0):
    """Balance observations with DyDD, decompose, and solve with DD-KF.

    Returns (x_ddkf, dydd_result, decomposition).
    """
    from repro.core import dydd as dydd_mod

    res = dydd_mod.dydd_1d(obs_locations, p)
    dec = dd_mod.decompose_1d(prob.n, res.boundaries, overlap=overlap)
    packed = pack(prob, dec, mu=mu)
    x = solve_vmapped(packed, iters=iters)
    return x, res, dec
