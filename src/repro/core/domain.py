"""Dimension-agnostic domain layer for the streaming DD-KF engine.

The paper's setting is Ω ⊂ R² (Figures 1-4), but the streaming engine of
PR 1 was hardwired to 1D interval boundaries.  This module abstracts the
four domain responsibilities the engine needs behind one protocol:

  * **count**   — per-subdomain observation loads against the *current*
                  boundaries (what the rebalance trigger policy reads);
  * **rebalance** — run DyDD (DD-step for empty subdomains, diffusion
                  scheduling on the processor graph, geometric boundary
                  migration) and adopt the moved boundaries;
  * **decompose** — emit a :class:`repro.core.dd.Decomposition` of the
                  raster-ordered state mesh for the operator packing;
  * **graph**   — expose the processor adjacency used by the scheduling
                  step (chain in 1D, pr x pc grid in 2D).

Two implementations:

  * :class:`Interval1D`   — wraps ``dydd.dydd_1d`` / ``dd.decompose_1d``
    (the PR 1 behaviour, bit-for-bit).
  * :class:`ShelfTiling2D` — wraps ``dydd2d.dydd_2d`` /
    ``dydd2d.cell_col_sets``: a shelf tiling of pr strips x pc cells whose
    y- and per-strip x-edges migrate independently (the paper's Figure 3
    moves applied per axis), with the empty-cell DD-step of Figure 1.

A ``ShelfTiling2D`` with ``pr == 1`` and ``ny == 1`` degenerates *exactly*
to ``Interval1D`` — same loads, same boundaries, same decomposition, same
observation raster positions — which ``tests/test_assim.py`` asserts
bit-for-bit against the 1D engine.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core import dd as dd_mod
from repro.core import dydd as dydd_mod
from repro.core import dydd2d as dydd2d_mod


def raster_positions(obs: np.ndarray, ny: int) -> np.ndarray:
    """(m,) row-continuous raster coordinate of 2D observations on an
    ny-row mesh: the observation keeps its continuous x within the mesh
    row its y falls in, so column ``row * nx + floor(x * nx)`` is the
    nearest mesh point.  The result is clamped strictly below the next
    row's start: an ``x == 1.0`` boundary observation used to alias to
    ``(row + 1) / ny`` — the *next* raster row's first column — and even
    a clamped in-row coordinate can round up across the row seam in
    float arithmetic (``(2 + (1 - eps)) / 4 == 0.75``).  With ``ny == 1``
    this is exactly the identity on in-range x (the 1D convention)."""
    obs = np.asarray(obs, np.float64)
    rows = np.clip((obs[:, 1] * ny).astype(np.int64), 0, ny - 1)
    pos = (rows + np.clip(obs[:, 0], 0.0, 1.0)) / ny
    return np.minimum(pos, np.nextafter((rows + 1.0) / ny, 0.0))


@dataclasses.dataclass(frozen=True)
class RebalanceInfo:
    """What a DyDD run moved: observation migration volume and rounds."""

    migrated: int
    rounds: int


@runtime_checkable
class Domain(Protocol):
    """Protocol of a (re)decomposable assimilation domain.

    ``ndim``/``n``/``p`` are static; ``counts``/``rebalance``/
    ``decomposition`` read (and, for ``rebalance``, advance) the mutable
    boundary state.  ``obs`` arrays are ``(m,)`` for ``ndim == 1`` and
    ``(m, ndim)`` otherwise.
    """

    ndim: int

    @property
    def n(self) -> int:
        """State mesh size (number of columns of A)."""
        ...

    @property
    def p(self) -> int:
        """Number of subdomains (= processors)."""
        ...

    def counts(self, obs: np.ndarray) -> np.ndarray:
        """(p,) observation loads against the current boundaries."""
        ...

    def rebalance(self, obs: np.ndarray,
                  cost_offsets: np.ndarray | None = None) -> RebalanceInfo:
        """Run DyDD on ``obs``; mutates the boundary state.

        ``cost_offsets`` (p,) is the overlap-aware weighting: fixed
        per-subdomain work (halo-column count x weight) added to the
        loads the diffusion schedule balances, so wide halos don't skew
        the migration toward already-loaded subdomains."""
        ...

    def decomposition(self, overlap: int = 0) -> dd_mod.Decomposition:
        """Decompose the raster-ordered state mesh on current boundaries."""
        ...

    def graph_edges(self) -> list:
        """Processor graph edges the diffusion schedule runs on."""
        ...

    def mesh_axes(self) -> tuple:
        """(names, shape) of the device mesh the processor graph maps
        onto: (("sub",), (p,)) for a chain, (("row", "col"), (pr, pc))
        for a grid — subdomain i lives on mesh coordinate
        ``np.unravel_index(i, shape)``."""
        ...

    def obs_positions(self, obs: np.ndarray) -> np.ndarray:
        """(m,) raster-ordered positions in [0, 1) for the observation
        operator (identity in 1D; row-continuous raster coordinate in 2D)."""
        ...

    @property
    def row_size(self) -> int | None:
        """Stencil confinement for ``cls.observation_operator``: the
        raster-row width (nx) on a 2D mesh, None on a 1D mesh (an
        interpolation window may span the whole state vector)."""
        ...

    def load_table(self, loads) -> np.ndarray:
        """Loads shaped for display ((p,) in 1D, (pr, pc) in 2D)."""
        ...

    def describe(self) -> dict:
        """JSON-serializable domain metadata for journals/benchmarks."""
        ...


# ---------------------------------------------------------------------------
# 1D interval domain (PR 1 semantics).
# ---------------------------------------------------------------------------

class Interval1D:
    """p contiguous intervals of [0, 1] with migrating interior edges."""

    ndim = 1

    def __init__(self, n: int, p: int,
                 boundaries: np.ndarray | None = None,
                 tie_ranks: np.ndarray | None = None):
        self._n = int(n)
        self._p = int(p)
        self.boundaries = (np.linspace(0.0, 1.0, p + 1)
                           if boundaries is None
                           else np.asarray(boundaries, np.float64).copy())
        assert self.boundaries.shape == (p + 1,)
        # Rank split of observations tied with an interior boundary (see
        # dydd._counts) — zero means the historic all-right tie rule.
        self.tie_ranks = (np.zeros((max(p - 1, 0),), np.int64)
                          if tie_ranks is None
                          else np.asarray(tie_ranks, np.int64).copy())
        assert self.tie_ranks.shape == (max(p - 1, 0),)

    @property
    def n(self) -> int:
        return self._n

    @property
    def p(self) -> int:
        return self._p

    def counts(self, obs: np.ndarray) -> np.ndarray:
        return dydd_mod._counts(np.asarray(obs, np.float64),
                                self.boundaries, self.tie_ranks)

    def rebalance(self, obs: np.ndarray,
                  cost_offsets: np.ndarray | None = None) -> RebalanceInfo:
        res = dydd_mod.dydd_1d(np.asarray(obs, np.float64), self._p,
                               boundaries=self.boundaries.copy(),
                               cost_offsets=cost_offsets,
                               tie_ranks=self.tie_ranks.copy())
        self.boundaries = res.boundaries
        self.tie_ranks = res.tie_ranks
        return RebalanceInfo(migrated=res.total_movement, rounds=res.rounds)

    def decomposition(self, overlap: int = 0) -> dd_mod.Decomposition:
        return dd_mod.decompose_1d(self._n, self.boundaries,
                                   overlap=overlap)

    def graph_edges(self) -> list:
        return dydd_mod.chain_edges(self._p)

    def mesh_axes(self) -> tuple:
        return ("sub",), (self._p,)

    def obs_positions(self, obs: np.ndarray) -> np.ndarray:
        return np.asarray(obs, np.float64)

    @property
    def row_size(self) -> int | None:
        return None

    def load_table(self, loads) -> np.ndarray:
        return np.asarray(loads, np.int64)

    def describe(self) -> dict:
        return {"ndim": 1, "kind": "interval1d", "n": self._n,
                "p": self._p}

    def state_dict(self) -> dict:
        """The mutable boundary state, as arrays (checkpoint leaves)."""
        return {"boundaries": self.boundaries.copy(),
                "tie_ranks": self.tie_ranks.copy()}

    def load_state(self, state: dict) -> None:
        b = np.asarray(state["boundaries"], np.float64)
        t = np.asarray(state["tie_ranks"], np.int64)
        assert b.shape == (self._p + 1,)
        assert t.shape == (max(self._p - 1, 0),)
        self.boundaries = b.copy()
        self.tie_ranks = t.copy()


# ---------------------------------------------------------------------------
# 2D shelf tiling (the paper's Ω ⊂ R²).
# ---------------------------------------------------------------------------

class ShelfTiling2D:
    """pr horizontal strips x pc cells per strip over an nx x ny mesh.

    State columns are raster-ordered: global column ``iy * nx + ix`` is the
    mesh point at ``((ix + 0.5) / nx, (iy + 0.5) / ny)``.  Subdomain
    ``r * pc + c`` is cell (r, c) of the shelf tiling; the processor graph
    is the pr x pc grid.  ``decomposition(overlap=s)`` gives each cell a
    cross-shaped halo of ``s`` mesh columns/rows absorbed from its
    grid-graph neighbours (``dydd2d.cell_col_sets``), with the
    multiplicity-weighted Schwarz assembly falling out of the general
    :class:`~repro.core.dd.Decomposition` fields.
    """

    ndim = 2

    def __init__(self, nx: int, ny: int, pr: int, pc: int,
                 y_edges: np.ndarray | None = None,
                 x_edges: np.ndarray | None = None,
                 max_rounds: int = 8,
                 y_tie_ranks: np.ndarray | None = None,
                 x_tie_ranks: np.ndarray | None = None):
        self.nx, self.ny = int(nx), int(ny)
        self.pr, self.pc = int(pr), int(pc)
        self.max_rounds = int(max_rounds)
        self.y_edges = (np.linspace(0.0, 1.0, pr + 1)
                        if y_edges is None
                        else np.asarray(y_edges, np.float64).copy())
        self.x_edges = (np.tile(np.linspace(0.0, 1.0, pc + 1), (pr, 1))
                        if x_edges is None
                        else np.asarray(x_edges, np.float64).copy())
        assert self.y_edges.shape == (pr + 1,)
        assert self.x_edges.shape == (pr, pc + 1)
        # Rank splits of observations tied with a shelf edge (see
        # dydd2d._counts_2d) — zero means the historic all-right tie rule.
        self.y_tie_ranks = (np.zeros((max(pr - 1, 0),), np.int64)
                            if y_tie_ranks is None
                            else np.asarray(y_tie_ranks, np.int64).copy())
        self.x_tie_ranks = (np.zeros((pr, max(pc - 1, 0)), np.int64)
                            if x_tie_ranks is None
                            else np.asarray(x_tie_ranks, np.int64).copy())
        assert self.y_tie_ranks.shape == (max(pr - 1, 0),)
        assert self.x_tie_ranks.shape == (pr, max(pc - 1, 0))

    @property
    def n(self) -> int:
        return self.nx * self.ny

    @property
    def p(self) -> int:
        return self.pr * self.pc

    def counts(self, obs: np.ndarray) -> np.ndarray:
        return dydd2d_mod._counts_2d(np.asarray(obs, np.float64),
                                     self.y_edges, self.x_edges,
                                     self.y_tie_ranks,
                                     self.x_tie_ranks).reshape(-1)

    def rebalance(self, obs: np.ndarray,
                  cost_offsets: np.ndarray | None = None) -> RebalanceInfo:
        if cost_offsets is not None:
            cost_offsets = np.asarray(cost_offsets).reshape(self.pr,
                                                            self.pc)
        res = dydd2d_mod.dydd_2d(np.asarray(obs, np.float64),
                                 self.pr, self.pc,
                                 y_edges=self.y_edges.copy(),
                                 x_edges=self.x_edges.copy(),
                                 max_rounds=self.max_rounds,
                                 cost_offsets=cost_offsets,
                                 y_tie_ranks=self.y_tie_ranks.copy(),
                                 x_tie_ranks=self.x_tie_ranks.copy())
        self.y_edges = res.y_edges
        self.x_edges = res.x_edges
        self.y_tie_ranks = res.y_tie_ranks
        self.x_tie_ranks = res.x_tie_ranks
        return RebalanceInfo(migrated=res.total_movement, rounds=res.rounds)

    def decomposition(self, overlap: int = 0) -> dd_mod.Decomposition:
        if overlap < 0:
            raise ValueError(f"overlap must be >= 0 (got {overlap})")
        col_sets = dydd2d_mod.cell_col_sets(self.nx, self.ny, self.y_edges,
                                            self.x_edges, overlap=overlap)
        # boundaries is 1D-interval metadata; a tiling has none (and the
        # solver/packing layer reads only col_sets + multiplicity).
        return dd_mod.Decomposition(n=self.n, col_sets=tuple(col_sets),
                                    overlap=overlap, boundaries=None)

    def graph_edges(self) -> list:
        return dydd_mod.grid_edges(self.pr, self.pc, torus=False)

    def mesh_axes(self) -> tuple:
        return ("row", "col"), (self.pr, self.pc)

    def obs_positions(self, obs: np.ndarray) -> np.ndarray:
        return raster_positions(obs, self.ny)

    @property
    def row_size(self) -> int | None:
        return self.nx

    def load_table(self, loads) -> np.ndarray:
        return np.asarray(loads, np.int64).reshape(self.pr, self.pc)

    def describe(self) -> dict:
        return {"ndim": 2, "kind": "shelf2d", "n": self.n,
                "p": self.p, "nx": self.nx, "ny": self.ny,
                "pr": self.pr, "pc": self.pc}

    def state_dict(self) -> dict:
        """The mutable shelf state, as arrays (checkpoint leaves)."""
        return {"y_edges": self.y_edges.copy(),
                "x_edges": self.x_edges.copy(),
                "y_tie_ranks": self.y_tie_ranks.copy(),
                "x_tie_ranks": self.x_tie_ranks.copy()}

    def load_state(self, state: dict) -> None:
        ye = np.asarray(state["y_edges"], np.float64)
        xe = np.asarray(state["x_edges"], np.float64)
        yt = np.asarray(state["y_tie_ranks"], np.int64)
        xt = np.asarray(state["x_tie_ranks"], np.int64)
        assert ye.shape == (self.pr + 1,)
        assert xe.shape == (self.pr, self.pc + 1)
        assert yt.shape == (max(self.pr - 1, 0),)
        assert xt.shape == (self.pr, max(self.pc - 1, 0))
        self.y_edges = ye.copy()
        self.x_edges = xe.copy()
        self.y_tie_ranks = yt.copy()
        self.x_tie_ranks = xt.copy()


def factor_mesh(n: int) -> tuple:
    """Split n into (nx, ny) with ny = the largest factor <= sqrt(n) —
    the default 2D mesh shape when only a state size is given."""
    ny = max(int(np.sqrt(n)), 1)
    while n % ny:
        ny -= 1
    return n // ny, ny
