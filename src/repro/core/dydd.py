"""DyDD — Dynamic Domain Decomposition load balancing (paper §5, Table 13).

The four steps of procedure DyDD:

  1. DD step        — if a subdomain is empty, split the adjacent subdomain
                      with maximum load in two (geometrically, at its
                      midpoint) and re-assign.
  2. Scheduling     — on the processor graph G (vertex i = subdomain i,
                      value l_i = #observations), solve the graph-Laplacian
                      system  L lambda = b,  b_i = l_i - lbar, and set the
                      per-edge migration delta_ij = round(lambda_i-lambda_j).
                      This is the Hu-Blake-Emerson diffusion schedule that
                      minimizes ||delta||_2 and keeps all movement between
                      *adjacent* subdomains.
  3. Migration      — shift the geometric boundaries of adjacent subdomains
                      so that exactly |delta_ij| observations change side.
  4. Update         — re-map subdomains to processors / recompute loads.

Two implementations are provided:
  * a host-side numpy one (`schedule`, `dydd_1d`) used by the data pipeline
    and the paper-reproduction benchmarks (the p x p solve is microseconds —
    cheaper than any collective, see DESIGN.md §3), and
  * a jittable jnp one (`schedule_jnp`) used on-device by the MoE balancer,
    where the graph is fixed at trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs import meters as meters_mod


Edge = tuple  # (i, j) with i < j


# ---------------------------------------------------------------------------
# Graphs.
# ---------------------------------------------------------------------------

def chain_edges(p: int) -> list:
    """Path graph 0-1-...-(p-1) — Example 4's configuration (deg(i)<=2),
    and the natural graph of a 1D geometric decomposition."""
    return [(i, i + 1) for i in range(p - 1)]


def star_edges(p: int) -> list:
    """Star graph centred at 0 — Example 3's configuration (deg(0)=p-1)."""
    return [(0, i) for i in range(1, p)]


def ring_edges(p: int) -> list:
    """Ring — the graph of a TPU mesh axis (ICI torus dimension)."""
    if p == 1:
        return []
    if p == 2:
        return [(0, 1)]
    return [(i, (i + 1) % p) for i in range(p)]


def grid_edges(rows: int, cols: int, torus: bool = True) -> list:
    """2D grid/torus — the graph of a TPU (data, model) mesh slice."""
    edges = set()
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((0, 1), (1, 0)):
                rr, cc = r + dr, c + dc
                if torus:
                    rr, cc = rr % rows, cc % cols
                elif rr >= rows or cc >= cols:
                    continue
                j = rr * cols + cc
                if i != j:
                    edges.add((min(i, j), max(i, j)))
    return sorted(edges)


def laplacian(p: int, edges: Sequence[Edge]) -> np.ndarray:
    """Graph Laplacian L (eq. 29): L_ii = deg(i), L_ij = -1 on edges."""
    L = np.zeros((p, p), dtype=np.float64)
    for i, j in edges:
        L[i, j] -= 1.0
        L[j, i] -= 1.0
        L[i, i] += 1.0
        L[j, j] += 1.0
    return L


def degrees(p: int, edges: Sequence[Edge]) -> np.ndarray:
    d = np.zeros((p,), dtype=np.int64)
    for i, j in edges:
        d[i] += 1
        d[j] += 1
    return d


# ---------------------------------------------------------------------------
# Scheduling step.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule:
    """A diffusion schedule: per-edge signed integer migrations.

    deltas[k] > 0 means move that many observations from edges[k][0] to
    edges[k][1]; < 0 the other way.  Conservation holds exactly:
    sum(new_loads) == sum(loads).
    """

    edges: tuple
    deltas: np.ndarray   # (E,) int
    lam: np.ndarray      # (p,) the potential lambda (diagnostic)

    def apply(self, loads: np.ndarray) -> np.ndarray:
        new = np.asarray(loads, dtype=np.int64).copy()
        for (i, j), d in zip(self.edges, self.deltas):
            new[i] -= d
            new[j] += d
        return new

    @property
    def total_movement(self) -> int:
        return int(np.abs(self.deltas).sum())


def _solve_laplacian_cg(edges_arr: np.ndarray, deg: np.ndarray,
                        b: np.ndarray, tol: float = 1e-10,
                        maxiter: int | None = None) -> np.ndarray:
    """Matrix-free CG for L lam = b on the span{1}-orthogonal complement.

    O(|E|) per iteration and ~O(graph diameter) iterations — this is what
    keeps the scheduling step microseconds at p = 4096 (64x64 torus) and
    beyond, the 1000+-node requirement (DESIGN.md §3)."""
    p = deg.shape[0]
    src, dst = edges_arr[:, 0], edges_arr[:, 1]

    def apply_L(x):
        out = deg * x
        np.subtract.at(out, src, x[dst])
        np.subtract.at(out, dst, x[src])
        return out

    b = b - b.mean()
    x = np.zeros(p)
    r = b.copy()
    q = r.copy()
    rs = r @ r
    maxiter = maxiter or 4 * p
    cg_hist = meters_mod.get_meters().series["dydd.cg_residual"]
    for _ in range(maxiter):
        cg_hist.append(float(np.sqrt(rs)))
        if rs < tol * tol * max(b @ b, 1e-30):
            break
        Lq = apply_L(q)
        alpha = rs / max(q @ Lq, 1e-300)
        x += alpha * q
        r -= alpha * Lq
        rs_new = r @ r
        q = r + (rs_new / max(rs, 1e-300)) * q
        rs = rs_new
    return x - x.mean()


def schedule(loads: np.ndarray, edges: Sequence[Edge]) -> Schedule:
    """One scheduling step: solve L lambda = (l - lbar), delta = round(dlam).

    L is singular with nullspace span{1}; b sums to ~0 (up to the fractional
    part of lbar) so the min-norm lstsq solution is the Hu-Blake-Emerson
    schedule.  Verified against the paper's §5 worked example in tests.
    Small graphs use dense lstsq; large ones (p > 512) the matrix-free CG.
    """
    loads = np.asarray(loads, dtype=np.float64)
    p = loads.shape[0]
    if p == 1 or not edges:
        return Schedule(edges=tuple(edges), deltas=np.zeros((0,), np.int64),
                        lam=np.zeros((p,)))
    b = loads - loads.mean()
    if p <= 512:
        L = laplacian(p, edges)
        lam, *_ = np.linalg.lstsq(L, b, rcond=None)
    else:
        edges_arr = np.asarray(edges, dtype=np.int64)
        lam = _solve_laplacian_cg(edges_arr, degrees(p, edges).astype(
            np.float64), b)
    edges_arr = np.asarray(edges, dtype=np.int64)
    deltas = np.rint(lam[edges_arr[:, 0]]
                     - lam[edges_arr[:, 1]]).astype(np.int64)
    return Schedule(edges=tuple(edges), deltas=deltas, lam=lam)


def balance(loads: np.ndarray, edges: Sequence[Edge],
            max_rounds: int = 64):
    """Iterate scheduling until the max deviation from the average load is
    within the rounding floor (Table 13 'repeat ... until' loop).

    Returns (final_loads, list_of_schedules).  Each round only moves data
    between graph neighbours; loads never go negative (moves are clamped by
    re-solving on the residual graph if a vertex would overdraw —
    in practice the lstsq schedule never overdraws on connected graphs
    with non-negative loads, but we guard anyway).
    """
    loads = np.asarray(loads, dtype=np.int64).copy()
    total = int(loads.sum())
    p = loads.shape[0]
    schedules = []
    for _ in range(max_rounds):
        lbar = total / p
        dev = np.abs(loads - lbar).max()
        # Keep scheduling until within integer rounding of the average
        # (the worked example of §5 reaches the exact average); the
        # total_movement == 0 break below is the paper's deg/2 floor in
        # practice — once the lstsq potentials round to zero everywhere,
        # no further neighbour move can help.
        if dev < 1.0:
            break
        sch = schedule(loads, edges)
        if sch.total_movement == 0:
            break
        new = sch.apply(loads)
        if new.min() < 0:
            # Clamp: scale this round's deltas down to keep feasibility.
            scale = 0.5
            sch = Schedule(edges=sch.edges,
                           deltas=(sch.deltas * scale).astype(np.int64),
                           lam=sch.lam)
            new = sch.apply(loads)
            if new.min() < 0 or sch.total_movement == 0:
                break
        loads = new
        schedules.append(sch)
    assert int(loads.sum()) == total, "conservation violated"
    m = meters_mod.get_meters()
    m.inc("dydd.schedule_rounds", len(schedules))
    m.inc("dydd.scheduled_movement",
          sum(s.total_movement for s in schedules))
    return loads, schedules


def balance_ratio(loads: np.ndarray) -> float:
    """E = min(l)/max(l) (paper §6) — 1.0 is perfectly balanced."""
    loads = np.asarray(loads, dtype=np.float64)
    mx = loads.max()
    return float(loads.min() / mx) if mx > 0 else 1.0


# ---------------------------------------------------------------------------
# jnp scheduling (fixed graph, on-device) — used by the MoE balancer.
# ---------------------------------------------------------------------------

def schedule_jnp(loads: jax.Array, pinvL: jax.Array,
                 incidence: jax.Array) -> jax.Array:
    """Differentiable-friendly on-device schedule.

    Args:
      loads: (p,) float loads.
      pinvL: (p, p) pseudo-inverse of the graph Laplacian (precomputed at
        trace time from the static mesh topology).
      incidence: (E, p) signed incidence matrix: row k has +1 at edge[k][0],
        -1 at edge[k][1].

    Returns:
      (E,) rounded per-edge migration counts.
    """
    b = loads - jnp.mean(loads)
    lam = pinvL @ b
    return jnp.rint(incidence @ lam)


def incidence_matrix(p: int, edges: Sequence[Edge]) -> np.ndarray:
    E = len(edges)
    M = np.zeros((E, p), dtype=np.float64)
    for k, (i, j) in enumerate(edges):
        M[k, i] = 1.0
        M[k, j] = -1.0
    return M


# ---------------------------------------------------------------------------
# Geometric DyDD in 1D: DD step + migration + update on interval boundaries.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DyDDResult:
    boundaries: np.ndarray          # (p+1,) final interval edges
    loads_initial: np.ndarray       # l_in
    loads_repartitioned: np.ndarray  # l_r (after DD step; = l_in if no empty)
    loads_final: np.ndarray         # l_fin
    rounds: int
    total_movement: int             # observations whose owner changed
    repartitioned: bool
    tie_ranks: np.ndarray | None = None  # (p-1,) rank split of boundary ties
    scheduled_movement: int = 0     # sum |delta| over scheduling rounds

    @property
    def efficiency(self) -> float:
        return balance_ratio(self.loads_final)


def _counts(obs: np.ndarray, boundaries: np.ndarray,
            tie_ranks: np.ndarray | None = None,
            assume_sorted: bool = False) -> np.ndarray:
    """Per-subdomain observation counts under a rank-split tie rule.

    ``tie_ranks[k]`` is the number of observations *exactly equal to*
    interior boundary ``boundaries[k+1]`` that count to its left side;
    ``None`` means all-zero ranks, which reproduces the historic
    ``searchsorted(side="right")`` counting bit for bit (every tied
    observation on the right side).  Counting is cumulative — the number
    of observations in subdomains ``0..k`` is the number strictly below
    boundary k+1 plus that boundary's tie rank — so equal-valued interior
    boundaries (collapsed by the DD step) and out-of-order guards need no
    special casing.  ``assume_sorted`` skips the sort for hot-loop
    callers that hold ``obs`` ascending already."""
    p = len(boundaries) - 1
    obs_sorted = np.asarray(obs, np.float64)
    if not assume_sorted:
        obs_sorted = np.sort(obs_sorted)
    interior = np.asarray(boundaries[1:p], np.float64)
    cum = np.searchsorted(obs_sorted, interior, side="left")
    if tie_ranks is not None:
        eq = np.searchsorted(obs_sorted, interior, side="right") - cum
        cum = cum + np.clip(np.asarray(tie_ranks, np.int64), 0, eq)
    cum = np.concatenate([[0], np.maximum.accumulate(cum),
                          [obs_sorted.size]])
    return np.diff(cum).astype(np.int64)


def _rank_owners(obs: np.ndarray, boundaries: np.ndarray,
                 tie_ranks: np.ndarray | None = None,
                 assume_sorted: bool = False) -> np.ndarray:
    """(m,) owner of each *sorted-rank* observation slot — tied
    observations are interchangeable, so the per-rank assignment is the
    minimal-movement matching between two decompositions."""
    counts = _counts(obs, boundaries, tie_ranks,
                     assume_sorted=assume_sorted)
    return np.repeat(np.arange(counts.shape[0]), counts)


def _repartition_empty(obs: np.ndarray, boundaries: np.ndarray,
                       tie_ranks: np.ndarray | None):
    """DD step (paper Fig. 1): while some subdomain is empty, split the
    *adjacent* subdomain with maximum load at its geometric midpoint and
    give the empty subdomain the half adjacent to it.  Boundaries that
    move reset their tie rank (a fresh geometric cut owns no tie split);
    unmoved boundaries keep theirs.  Returns (boundaries, tie_ranks)."""
    obs = np.sort(np.asarray(obs, np.float64))
    boundaries = boundaries.copy()
    p = len(boundaries) - 1
    ranks = (np.zeros((max(p - 1, 0),), np.int64) if tie_ranks is None
             else np.asarray(tie_ranks, np.int64).copy())
    for _ in range(4 * p):  # termination guard
        counts = _counts(obs, boundaries, ranks, assume_sorted=True)
        empties = np.where(counts == 0)[0]
        if empties.size == 0:
            break
        i = int(empties[0])
        nbrs = [j for j in (i - 1, i + 1) if 0 <= j < p and counts[j] > 0]
        if not nbrs:
            break  # isolated empty region with empty neighbours: next round
        m = max(nbrs, key=lambda j: counts[j])
        lo, hi = boundaries[m], boundaries[m + 1]
        mid = 0.5 * (lo + hi)
        if m < i:       # donate the right half of the neighbour
            boundaries[i] = mid     # i's left edge moves down to mid
            # intermediate boundaries between m+1..i collapse onto mid
            boundaries[m + 1:i] = mid
            ranks[m:i] = 0
        else:           # donate the left half of the neighbour
            boundaries[i + 1] = mid
            boundaries[i + 2:m + 1] = mid
            ranks[i:m] = 0
    return boundaries, ranks


def repartition_empty_1d(obs: np.ndarray,
                         boundaries: np.ndarray) -> np.ndarray:
    """Historic DD-step entry point: boundaries only, all-right tie rule."""
    return _repartition_empty(obs, boundaries, None)[0]


def migrate_1d(obs: np.ndarray, boundaries: np.ndarray,
               target_counts: np.ndarray, assume_sorted: bool = False):
    """Migration step: shift interior boundaries left-to-right so subdomain i
    contains exactly target_counts[i] observations (paper Fig. 3).

    Works for chain-adjacent (1D) decompositions: boundary k is placed
    between the cumsum(target)[k]-th and +1-th order statistic of obs.
    When those order statistics tie, no geometric boundary can realize
    the cut — the boundary sits *on* the tied value and the returned
    ``tie_ranks[k]`` records how many of the tied observations belong to
    its left (an index-based rank split; see :func:`_counts`), so the
    scheduled targets are realized exactly instead of dumping the whole
    tie group on one side.

    Returns ``(boundaries, tie_ranks)``.
    """
    obs_sorted = np.asarray(obs, np.float64)
    if not assume_sorted:
        obs_sorted = np.sort(obs_sorted)
    m = obs_sorted.shape[0]
    p = len(boundaries) - 1
    csum = np.clip(np.cumsum(target_counts)[:-1], 0, m).astype(np.int64)
    new = boundaries.copy()
    for k, c in enumerate(csum):
        c = int(c)
        if c == 0:
            new[k + 1] = boundaries[0]
        elif c == m:
            new[k + 1] = boundaries[-1]
        elif obs_sorted[c - 1] < obs_sorted[c]:
            new[k + 1] = 0.5 * (obs_sorted[c - 1] + obs_sorted[c])
        else:
            new[k + 1] = obs_sorted[c]   # tied cut: boundary on the value
    # Keep edges monotone.
    for k in range(1, len(new)):
        new[k] = max(new[k], new[k - 1])
    new[-1] = boundaries[-1]
    # Rank split: place c - #(obs < boundary) of the boundary-tied
    # observations on the left so the cumulative count at boundary k+1 is
    # exactly csum[k].  (The midpoint of two *distinct* order statistics
    # can still round onto one of them in float arithmetic — the uniform
    # formula covers that too.)
    lt = np.searchsorted(obs_sorted, new[1:p], side="left")
    eq = np.searchsorted(obs_sorted, new[1:p], side="right") - lt
    ranks = np.clip(csum - lt, 0, eq).astype(np.int64)
    return new, ranks


def _offset_targets(work_fin: np.ndarray, offsets: np.ndarray,
                    total: int) -> np.ndarray:
    """Convert balanced *work* loads back to observation targets.

    work_i = obs_i + offset_i, so the migration target is
    work_fin - offsets — clipped at zero (a subdomain whose fixed halo
    cost already exceeds its balanced work share can hold no fewer than
    zero observations) and renormalized to conserve the observation
    count, shaving the deficit off the largest targets."""
    t = np.maximum(np.asarray(work_fin, np.int64) - offsets, 0)
    # balance() conserves totals, so sum(work_fin) = total + sum(offsets)
    # and the clip can only push sum(t) *above* total — never below.
    diff = int(t.sum()) - int(total)
    assert diff >= 0, "balance() under-conserved the weighted loads"
    while diff > 0:
        # diff > 0 implies t.sum() > total >= 0, so max(t) >= 1.
        i = int(np.argmax(t))
        take = min(diff, int(t[i]))
        t[i] -= take
        diff -= take
    return t


def dydd_1d(obs: np.ndarray, p: int,
            boundaries: np.ndarray | None = None,
            max_rounds: int = 64,
            cost_offsets: np.ndarray | None = None,
            tie_ranks: np.ndarray | None = None) -> DyDDResult:
    """Full DyDD on a 1D domain [0,1] with observation locations ``obs``.

    The processor graph of a 1D chain decomposition is the path graph.
    Returns the balanced boundaries and the before/after loads, mirroring
    the quantities the paper reports (l_in, l_r, l_fin, E).

    ``cost_offsets`` (p,) is the overlap-aware weighting: a fixed
    per-subdomain work term (e.g. halo-column count x weight) added to
    the observation loads *for the scheduling step only*, so subdomains
    that carry wide Schwarz halos are scheduled as busier and receive
    fewer observations.  ``None`` (default) reproduces the unweighted
    behaviour bit-for-bit.

    ``tie_ranks`` (p-1,) carries the incoming boundaries' tie split (see
    :func:`_counts`) for streams with quantized/tied coordinates; the
    result's ``tie_ranks`` must be carried alongside ``boundaries`` by
    stateful callers (``domain.Interval1D`` does).  ``total_movement`` is
    the *true* migration volume — the number of observations whose owner
    changed between the incoming and final decomposition — while the
    diffusion schedule's summed |delta| is in ``scheduled_movement``.
    """
    # Everything below is order-invariant, so sort once up front (the
    # counting/migration/ownership helpers would each re-sort otherwise
    # — ~4p redundant O(m log m) sorts per rebalance in the streaming
    # hot path).
    obs = np.sort(np.asarray(obs, dtype=np.float64))
    if boundaries is None:
        boundaries = np.linspace(0.0, 1.0, p + 1)
    l_in = _counts(obs, boundaries, tie_ranks, assume_sorted=True)

    # 1) DD step.
    b1, t1 = _repartition_empty(obs, boundaries, tie_ranks)
    l_r = _counts(obs, b1, t1, assume_sorted=True)
    repartitioned = not np.array_equal(b1, boundaries)

    # 2) Scheduling (iterated) — on obs + halo-cost work when weighted.
    edges = chain_edges(p)
    if cost_offsets is None:
        l_fin, schedules = balance(l_r, edges, max_rounds=max_rounds)
    else:
        off = np.maximum(np.rint(np.asarray(cost_offsets)), 0).astype(
            np.int64)
        if off.shape != (p,):
            raise ValueError(f"cost_offsets must be shape ({p},), got "
                             f"{off.shape}")
        work_fin, schedules = balance(l_r + off, edges,
                                      max_rounds=max_rounds)
        l_fin = _offset_targets(work_fin, off, int(l_r.sum()))

    # 3) Migration: realize l_fin geometrically + rank-split boundary ties.
    b2, t2 = migrate_1d(obs, b1, l_fin, assume_sorted=True)

    # 4) Update: recount.  Exact by construction of migrate_1d — the rank
    # split realizes every scheduled cut even inside a tie group —
    # *provided* every observation lies within the boundary span.  An
    # out-of-span observation is pinned to an end subdomain by counting
    # but invisible to the cut placement, so a zero end target cannot be
    # realized; those callers get the honest recount (the pre-fix
    # behaviour) instead of a crash.
    l_check = _counts(obs, b2, t2, assume_sorted=True)
    if obs.size == 0 or (obs[0] >= boundaries[0]
                         and obs[-1] <= boundaries[-1]):
        assert np.array_equal(l_check, l_fin), \
            f"migration failed to realize the scheduled targets: " \
            f"{l_check.tolist()} != {l_fin.tolist()}"

    # True migration volume: observations whose owner changed between the
    # incoming and final decomposition (tied observations matched by rank
    # — the minimal reassignment).
    moved = int((_rank_owners(obs, boundaries, tie_ranks,
                              assume_sorted=True)
                 != _rank_owners(obs, b2, t2, assume_sorted=True)).sum())
    return DyDDResult(boundaries=b2, loads_initial=l_in,
                      loads_repartitioned=l_r, loads_final=l_check,
                      rounds=len(schedules),
                      total_movement=moved,
                      repartitioned=repartitioned,
                      tie_ranks=t2,
                      scheduled_movement=sum(s.total_movement
                                             for s in schedules))


def dydd_graph(loads: np.ndarray, edges: Sequence[Edge],
               max_rounds: int = 64):
    """DyDD scheduling on an arbitrary processor graph (star for Example 3,
    grids/tori for the TPU mesh).  Returns (final_loads, schedules)."""
    return balance(loads, edges, max_rounds=max_rounds)
