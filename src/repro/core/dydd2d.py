"""DyDD on a 2D domain — the paper's actual setting (Ω ⊂ R², Figures 1-4).

Decomposition is a *shelf* tiling: `pr` horizontal strips whose y-edges can
shift, each strip split into `pc` cells whose x-edges shift independently
per strip.  This is exactly the boundary-shifting migration the paper
draws: Figure 3 moves vertical edges between adjacent subdomains, Figure 1
splits an overloaded neighbour of an empty cell — both are 1D migrations
applied per axis.

Balancing is nested applications of the full 1D DyDD machinery
(:func:`repro.core.dydd.dydd_1d`: DD-step for empty subdomains →
Hu–Blake–Emerson diffusion scheduling → geometric migration):

  1. y-pass: DyDD on the y coordinates over the chain of strips,
  2. x-pass: within each strip, DyDD on that strip's x coordinates over
     the chain of cells.

The pass pair is iterated until the cell loads stop improving (the y-pass
re-targets strip totals, which can shuffle strip membership and leave a
residual the next pass removes), capped at ``max_rounds``; the actual
round count is returned in :class:`DyDD2DResult`.  Both passes move
observations only between *adjacent* subdomains (the diffusion
restriction), and the processor graph of the tiling is the pr × pc grid —
``dydd.grid_edges`` — on which the scheduling step is also validated
(tests assert the geometric result matches the graph schedule's balance
floor).

With ``pr == 1`` the y-pass is a no-op and one round is exactly
``dydd_1d`` on the x coordinates — the degenerate-dimension parity the
domain layer (``repro.core.domain.ShelfTiling2D``) relies on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import dydd


@dataclasses.dataclass
class DyDD2DResult:
    y_edges: np.ndarray          # (pr+1,)
    x_edges: np.ndarray          # (pr, pc+1)
    loads_initial: np.ndarray    # (pr, pc)
    loads_final: np.ndarray     # (pr, pc)
    total_movement: int
    rounds: int = 1              # y-pass/x-pass rounds actually run
    y_tie_ranks: np.ndarray | None = None   # (pr-1,) strip-boundary ties
    x_tie_ranks: np.ndarray | None = None   # (pr, pc-1) per-strip cell ties

    @property
    def efficiency(self) -> float:
        return dydd.balance_ratio(self.loads_final.reshape(-1))


def _split_owners(vals: np.ndarray, edges: np.ndarray,
                  tie_ranks: np.ndarray | None) -> np.ndarray:
    """(m,) subdomain owner of each observation along one axis under the
    rank-split tie rule of :func:`repro.core.dydd._counts`.

    ``tie_ranks=None`` (all-zero ranks) reproduces the historic
    ``searchsorted(side="right")`` clip assignment bit for bit — every
    boundary-tied observation on the right side; with ranks, the first
    ``tie_ranks[k]`` of the observations tied with interior edge ``k+1``
    (in sorted-rank order) count to its left — the same split the 1D
    migration realizes, so the 2D recount sees the loads the schedule
    actually produced instead of dumping whole tie groups on one side.
    """
    vals = np.asarray(vals, np.float64)
    order = np.argsort(vals, kind="stable")
    owners_sorted = dydd._rank_owners(vals[order], edges, tie_ranks,
                                      assume_sorted=True)
    owners = np.empty(vals.shape[0], np.int64)
    owners[order] = owners_sorted
    return owners


def _counts_2d(obs: np.ndarray, y_edges: np.ndarray,
               x_edges: np.ndarray,
               y_tie_ranks: np.ndarray | None = None,
               x_tie_ranks: np.ndarray | None = None) -> np.ndarray:
    """(pr, pc) cell loads under the tie-aware rank-split counting rule
    (``None`` ranks = the historic all-right tie rule, bit for bit)."""
    pr = len(y_edges) - 1
    pc = x_edges.shape[1] - 1
    counts = np.zeros((pr, pc), np.int64)
    rows = _split_owners(obs[:, 1], y_edges, y_tie_ranks)
    for r in range(pr):
        xs = obs[rows == r, 0]
        cols = _split_owners(
            xs, x_edges[r],
            None if x_tie_ranks is None else x_tie_ranks[r])
        counts[r] = np.bincount(cols, minlength=pc)
    return counts


def _pass_2d(obs: np.ndarray, pr: int, pc: int, y_edges: np.ndarray,
             x_edges: np.ndarray,
             cost_offsets: np.ndarray | None = None,
             y_tie_ranks: np.ndarray | None = None,
             x_tie_ranks: np.ndarray | None = None):
    """One y-pass + x-pass round of nested 1D DyDD.  Returns the moved
    edges, the tie ranks realizing them, and the observation migration
    volume of the round.

    ``cost_offsets`` (pr, pc) is the overlap-aware halo-cost table: the
    y-pass sees per-strip row sums, the x-pass each strip's row."""
    moved = 0
    y_tie_ranks = (np.zeros((max(pr - 1, 0),), np.int64)
                   if y_tie_ranks is None
                   else np.asarray(y_tie_ranks, np.int64).copy())
    x_tie_ranks = (np.zeros((pr, max(pc - 1, 0)), np.int64)
                   if x_tie_ranks is None
                   else np.asarray(x_tie_ranks, np.int64).copy())
    # --- y-pass: full 1D DyDD on strip loads (chain of strips) -----------
    if pr > 1:
        res_y = dydd.dydd_1d(
            obs[:, 1], pr, boundaries=y_edges.copy(),
            cost_offsets=(None if cost_offsets is None
                          else cost_offsets.sum(axis=1)),
            tie_ranks=y_tie_ranks)
        y_edges = res_y.boundaries
        y_tie_ranks = res_y.tie_ranks
        moved += res_y.total_movement
    # --- x-pass: per strip, full 1D DyDD on cell loads --------------------
    # Strip membership under the *new* y edges and their rank split: an
    # observation tied with a moved strip boundary lands in the strip the
    # y-pass scheduled it to, not blanket-right.
    x_edges = x_edges.copy()
    rows = _split_owners(obs[:, 1], y_edges, y_tie_ranks)
    for r in range(pr):
        xs = obs[rows == r, 0]
        if xs.size == 0:
            continue  # empty strip: nothing to place, keep its edges
        res_x = dydd.dydd_1d(
            xs, pc, boundaries=x_edges[r].copy(),
            cost_offsets=(None if cost_offsets is None
                          else cost_offsets[r]),
            tie_ranks=x_tie_ranks[r])
        x_edges[r] = res_x.boundaries
        x_tie_ranks[r] = res_x.tie_ranks
        moved += res_x.total_movement
    return y_edges, x_edges, y_tie_ranks, x_tie_ranks, moved


def dydd_2d(obs: np.ndarray, pr: int, pc: int,
            y_edges: np.ndarray | None = None,
            x_edges: np.ndarray | None = None,
            max_rounds: int = 8,
            cost_offsets: np.ndarray | None = None,
            y_tie_ranks: np.ndarray | None = None,
            x_tie_ranks: np.ndarray | None = None) -> DyDD2DResult:
    """Balance m observations (m, 2) in [0,1)² over a pr x pc shelf tiling.

    Starts from the given shelf boundaries (uniform if omitted — pass the
    current edges to warm-start an online rebalance) and iterates the
    y-pass/x-pass pair until every cell's load is within integer rounding
    of m/(pr·pc) or the max deviation stops improving, at most
    ``max_rounds`` times.

    ``cost_offsets`` (pr, pc) adds a fixed per-cell work term (the
    overlap-aware halo weighting — see :func:`repro.core.dydd.dydd_1d`)
    to the loads the nested scheduling passes balance; the convergence
    check then measures deviation of the *weighted* loads.  ``None``
    reproduces the unweighted behaviour bit-for-bit.

    ``y_tie_ranks`` (pr-1,) / ``x_tie_ranks`` (pr, pc-1) carry the
    boundary-tie split state between online rebalances (the 2D analogue
    of ``dydd_1d``'s ``tie_ranks``): when observations sit exactly on a
    shelf edge — quantized coordinates — the recount splits each tie
    group by rank instead of assigning it wholesale rightward, so the
    loads the result reports are the loads the migration realized.  The
    updated ranks come back in the result; thread them into the next
    call together with the edges.
    """
    obs = np.asarray(obs, dtype=np.float64)
    assert obs.ndim == 2 and obs.shape[1] == 2
    m = obs.shape[0]
    if cost_offsets is not None:
        cost_offsets = np.maximum(
            np.rint(np.asarray(cost_offsets)), 0).astype(np.int64)
        if cost_offsets.shape != (pr, pc):
            raise ValueError(f"cost_offsets must be shape ({pr}, {pc}), "
                             f"got {cost_offsets.shape}")

    y_edges = (np.linspace(0.0, 1.0, pr + 1) if y_edges is None
               else np.asarray(y_edges, np.float64).copy())
    x_edges = (np.tile(np.linspace(0.0, 1.0, pc + 1), (pr, 1))
               if x_edges is None
               else np.asarray(x_edges, np.float64).copy())
    y_ranks = (np.zeros((max(pr - 1, 0),), np.int64) if y_tie_ranks is None
               else np.asarray(y_tie_ranks, np.int64).copy())
    x_ranks = (np.zeros((pr, max(pc - 1, 0)), np.int64)
               if x_tie_ranks is None
               else np.asarray(x_tie_ranks, np.int64).copy())
    l_in = _counts_2d(obs, y_edges, x_edges, y_ranks, x_ranks)

    # With halo-cost offsets the target is a balanced *weighted* load:
    # counts + offsets vs the weighted mean.
    off = (np.zeros((pr, pc), np.int64) if cost_offsets is None
           else cost_offsets)
    lbar = (m + off.sum()) / (pr * pc)
    total_moved = 0
    rounds = 0
    best_dev = np.inf
    for _ in range(max(1, max_rounds)):
        y_new, x_new, yr_new, xr_new, moved = _pass_2d(
            obs, pr, pc, y_edges, x_edges, cost_offsets=cost_offsets,
            y_tie_ranks=y_ranks, x_tie_ranks=x_ranks)
        dev = np.abs(_counts_2d(obs, y_new, x_new, yr_new, xr_new)
                     + off - lbar).max()
        if dev >= best_dev:
            break  # no improvement: keep the previous round's edges
        y_edges, x_edges = y_new, x_new
        y_ranks, x_ranks = yr_new, xr_new
        total_moved += moved
        best_dev = dev
        rounds += 1
        if dev < 1.0:
            break

    l_fin = _counts_2d(obs, y_edges, x_edges, y_ranks, x_ranks)
    return DyDD2DResult(y_edges=y_edges, x_edges=x_edges,
                        loads_initial=l_in, loads_final=l_fin,
                        total_movement=total_moved, rounds=rounds,
                        y_tie_ranks=y_ranks, x_tie_ranks=x_ranks)


def make_observations_2d(m: int, kind: str = "clustered",
                         seed: int = 0) -> np.ndarray:
    """2D observation locations: uniform / beta-skewed / clustered."""
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.uniform(0, 1, (m, 2))
    if kind == "beta":
        return np.stack([rng.beta(2, 5, m), rng.beta(5, 2, m)], axis=1)
    centers = rng.uniform(0.15, 0.85, (3, 2))
    c = rng.integers(0, 3, m)
    pts = centers[c] + 0.06 * rng.normal(size=(m, 2))
    return np.clip(pts, 0, 0.999999)


def _owner_ranges(owner: np.ndarray, k: int):
    """Contiguous index range [lo, hi) owned by each of k owners (owner is
    monotone, from searchsorted on monotone edges)."""
    out = []
    for i in range(k):
        idx = np.where(owner == i)[0]
        if idx.size:
            out.append((int(idx[0]), int(idx[-1]) + 1))
        else:
            out.append((0, 0))
    return out


def cell_col_sets(nx: int, ny: int, y_edges: np.ndarray,
                  x_edges: np.ndarray, overlap: int = 0):
    """Map a raster-ordered nx x ny mesh onto the tiling: the 2D analogue
    of ``dd.decompose_1d`` (Remark 4's I x J decomposition).  Returns a
    list of pr*pc int arrays of global column indices (cell (r, c) is
    entry ``r * pc + c``).

    With ``overlap = s > 0`` each cell's set is core ∪ halo (eq. 21-22
    applied per axis of the grid graph): the cell absorbs ``s`` mesh
    columns from its left/right neighbour cells *within its own strip
    rows*, and ``s`` mesh rows from the strips above/below *within its
    own x-window* — a cross-shaped (grid-graph-neighbour) halo, clipped
    at the shelf seams and the domain boundary.  Diagonal (non-neighbour)
    corners are not absorbed; assembly weights follow from the resulting
    column multiplicity, nothing here needs to be conforming across
    strips.  A cell with an empty core stays empty.
    """
    assert overlap >= 0
    xs = (np.arange(nx) + 0.5) / nx
    ys = (np.arange(ny) + 0.5) / ny
    pr = len(y_edges) - 1
    pc = x_edges.shape[1] - 1
    row_owner = np.clip(np.searchsorted(y_edges, ys, side="right") - 1,
                        0, pr - 1)
    row_rng = _owner_ranges(row_owner, pr)
    out = []
    for r in range(pr):
        ry0, ry1 = row_rng[r]
        col_owner = np.clip(np.searchsorted(x_edges[r], xs,
                                            side="right") - 1, 0, pc - 1)
        col_rng = _owner_ranges(col_owner, pc)
        for c in range(pc):
            rx0, rx1 = col_rng[c]
            if ry1 <= ry0 or rx1 <= rx0:      # empty core: no halo either
                out.append(np.empty((0,), dtype=np.int64))
                continue
            mask = np.zeros((ny, nx), dtype=bool)
            # core + left/right halo along this strip's own rows
            lx0 = max(0, rx0 - (overlap if c > 0 else 0))
            lx1 = min(nx, rx1 + (overlap if c < pc - 1 else 0))
            mask[ry0:ry1, lx0:lx1] = True
            # up/down halo rows from the neighbour strips, kept inside the
            # cell's own x-window (clipped at the shelf seam)
            if overlap > 0:
                if r > 0:
                    mask[max(0, ry0 - overlap):ry0, rx0:rx1] = True
                if r < pr - 1:
                    mask[ry1:min(ny, ry1 + overlap), rx0:rx1] = True
            out.append(np.where(mask.reshape(-1))[0].astype(np.int64))
    return out
