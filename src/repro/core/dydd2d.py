"""DyDD on a 2D domain — the paper's actual setting (Ω ⊂ R², Figures 1-4).

Decomposition is a *shelf* tiling: `pr` horizontal strips whose y-edges can
shift, each strip split into `pc` cells whose x-edges shift independently
per strip.  This is exactly the boundary-shifting migration the paper
draws: Figure 3 moves vertical edges between adjacent subdomains, Figure 1
splits an overloaded neighbour of an empty cell — both are 1D migrations
applied per axis.

Balancing is two nested applications of the 1D machinery:
  1. y-pass: strip loads → ``migrate_1d`` on the y-edges (chain graph of
     strips),
  2. x-pass: within each strip, cell loads → ``migrate_1d`` on that
     strip's x-edges.
Both passes move observations only between *adjacent* subdomains (the
diffusion restriction), and the processor graph of the tiling is the
pr × pc grid — ``dydd.grid_edges`` — on which the scheduling step is also
validated (tests assert the geometric result matches the graph schedule's
balance floor).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import dydd


@dataclasses.dataclass
class DyDD2DResult:
    y_edges: np.ndarray          # (pr+1,)
    x_edges: np.ndarray          # (pr, pc+1)
    loads_initial: np.ndarray    # (pr, pc)
    loads_final: np.ndarray     # (pr, pc)
    total_movement: int

    @property
    def efficiency(self) -> float:
        return dydd.balance_ratio(self.loads_final.reshape(-1))


def _counts_2d(obs: np.ndarray, y_edges: np.ndarray,
               x_edges: np.ndarray) -> np.ndarray:
    pr = len(y_edges) - 1
    pc = x_edges.shape[1] - 1
    counts = np.zeros((pr, pc), np.int64)
    rows = np.clip(np.searchsorted(y_edges, obs[:, 1], side="right") - 1,
                   0, pr - 1)
    for r in range(pr):
        xs = obs[rows == r, 0]
        cols = np.clip(np.searchsorted(x_edges[r], xs, side="right") - 1,
                       0, pc - 1)
        counts[r] = np.bincount(cols, minlength=pc)
    return counts


def dydd_2d(obs: np.ndarray, pr: int, pc: int,
            max_rounds: int = 64) -> DyDD2DResult:
    """Balance m observations (m, 2) in [0,1)² over a pr x pc tiling.

    Returns shifted shelf boundaries with every cell's load within integer
    rounding of m/(pr·pc).
    """
    obs = np.asarray(obs, dtype=np.float64)
    assert obs.ndim == 2 and obs.shape[1] == 2
    m = obs.shape[0]

    y_edges0 = np.linspace(0.0, 1.0, pr + 1)
    x_edges0 = np.tile(np.linspace(0.0, 1.0, pc + 1), (pr, 1))
    l_in = _counts_2d(obs, y_edges0, x_edges0)

    # --- y-pass: balance strip loads via 1D migration on y ---------------
    strip_target = np.array([m // pr + (1 if i < m % pr else 0)
                             for i in range(pr)], np.int64)
    y_edges = dydd.migrate_1d(obs[:, 1], y_edges0.copy(), strip_target)

    # --- x-pass: per strip, balance cell loads on x -----------------------
    x_edges = np.empty((pr, pc + 1))
    rows = np.clip(np.searchsorted(y_edges, obs[:, 1], side="right") - 1,
                   0, pr - 1)
    for r in range(pr):
        xs = np.sort(obs[rows == r, 0])
        k = xs.shape[0]
        cell_target = np.array([k // pc + (1 if j < k % pc else 0)
                                for j in range(pc)], np.int64)
        x_edges[r] = dydd.migrate_1d(xs, np.linspace(0, 1, pc + 1),
                                     cell_target)

    l_fin = _counts_2d(obs, y_edges, x_edges)
    moved = int(np.abs(l_fin - l_in).sum() // 2)
    return DyDD2DResult(y_edges=y_edges, x_edges=x_edges,
                        loads_initial=l_in, loads_final=l_fin,
                        total_movement=moved)


def make_observations_2d(m: int, kind: str = "clustered",
                         seed: int = 0) -> np.ndarray:
    """2D observation locations: uniform / beta-skewed / clustered."""
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        return rng.uniform(0, 1, (m, 2))
    if kind == "beta":
        return np.stack([rng.beta(2, 5, m), rng.beta(5, 2, m)], axis=1)
    centers = rng.uniform(0.15, 0.85, (3, 2))
    c = rng.integers(0, 3, m)
    pts = centers[c] + 0.06 * rng.normal(size=(m, 2))
    return np.clip(pts, 0, 0.999999)


def cell_col_sets(nx: int, ny: int, y_edges: np.ndarray,
                  x_edges: np.ndarray):
    """Map a raster-ordered nx x ny mesh onto the tiling: the 2D analogue
    of ``dd.decompose_1d`` (Remark 4's I x J decomposition).  Returns a
    list of pr*pc int arrays of global column indices."""
    xs = (np.arange(nx) + 0.5) / nx
    ys = (np.arange(ny) + 0.5) / ny
    pr = len(y_edges) - 1
    pc = x_edges.shape[1] - 1
    out = []
    gx, gy = np.meshgrid(xs, ys)              # (ny, nx)
    flat_x, flat_y = gx.reshape(-1), gy.reshape(-1)
    rows = np.clip(np.searchsorted(y_edges, flat_y, side="right") - 1, 0,
                   pr - 1)
    for r in range(pr):
        cols = np.clip(np.searchsorted(x_edges[r], flat_x,
                                       side="right") - 1, 0, pc - 1)
        for cidx in range(pc):
            sel = np.where((rows == r) & (cols == cidx))[0]
            out.append(sel.astype(np.int64))
    return out
