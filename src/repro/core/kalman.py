"""Kalman Filter and its variational (VAR-KF) form — paper §2.

Implements the textbook KF (eqs. 5-8) plus the sequential VAR-KF solver for
CLS problems used as the reference ("KF solving CLS problem", paper §6): the
observation rows of H1 are assimilated one block at a time starting from the
state system H0 x = y0, so the final estimate equals the CLS solution.
This is the sequential baseline that DD-KF is validated against
(error_DD-DA ~ 1e-11 in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import cls as cls_mod


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KFState:
    """Filter state: estimate and covariance (information is kept dense —
    the paper's CLS case study has Q = 0 and diagonal R, §3 remark)."""

    x: jax.Array  # (n,) state estimate
    P: jax.Array  # (n, n) error covariance


def predict(state: KFState, M: jax.Array, Q: jax.Array) -> KFState:
    """Predictor phase (eqs. 5-6): x <- M x, P <- M P M^T + Q."""
    x = M @ state.x
    P = M @ state.P @ M.T + Q
    return KFState(x=x, P=P)


def correct(state: KFState, H: jax.Array, y: jax.Array,
            R: jax.Array) -> KFState:
    """Corrector phase (eqs. 7-8).

    K = P H^T (H P H^T + R)^-1 ; x <- x + K (y - H x) ; P <- (I - K H) P.
    R is the (m,) diagonal of the observation covariance.
    """
    HP = H @ state.P                                  # (m, n)
    S = HP @ H.T + jnp.diag(R)
    # Solve instead of explicit inverse: K = P H^T S^-1 = (S^-1 H P)^T.
    K = jax.scipy.linalg.solve(S, HP, assume_a="pos").T
    x = state.x + K @ (y - H @ state.x)
    # (I - K H) P = P - K (H P): O(n^2 m) instead of O(n^3).
    P = state.P - K @ HP
    return KFState(x=x, P=P)


def run(x0: jax.Array, P0: jax.Array,
        Ms: jax.Array, Qs: jax.Array,
        Hs: jax.Array, ys: jax.Array, Rs: jax.Array) -> KFState:
    """Run r KF steps with jax.lax.scan.

    Ms: (r, n, n), Qs: (r, n, n), Hs: (r, m, n), ys: (r, m), Rs: (r, m).
    """
    def step(state: KFState, inp):
        M, Q, H, y, R = inp
        state = predict(state, M, Q)
        state = correct(state, H, y, R)
        return state, state.x

    init = KFState(x=x0, P=P0)
    final, xs = jax.lax.scan(step, init, (Ms, Qs, Hs, ys, Rs))
    return final, xs


# ---------------------------------------------------------------------------
# VAR-KF on a CLS problem: the paper's sequential reference method.
# ---------------------------------------------------------------------------

def _info_init(prob: cls_mod.CLSProblem):
    """Initialize from the state system H0 x = y0 (information form).

    Since rank(H0) = n, the GLS solution of the state system alone is
    x = (H0^T R0 H0)^-1 H0^T R0 y0 with covariance P = (H0^T R0 H0)^-1.
    """
    N = (prob.H0.T * prob.R0) @ prob.H0
    P = jnp.linalg.inv(N)
    x = P @ (prob.H0.T @ (prob.R0 * prob.y0))
    return KFState(x=x, P=P)


def solve_cls_sequential(prob: cls_mod.CLSProblem,
                         block: int = 1) -> jax.Array:
    """Assimilate the m1 observation rows sequentially (KF corrector steps,
    M = I, Q = 0) — 'KF procedure on CLS problem' of paper §6.

    The result equals the direct CLS solve up to roundoff; tests assert this.
    ``block`` rows are assimilated per corrector step (m1 % block == 0).
    """
    m1 = prob.H1.shape[0]
    assert m1 % block == 0, (m1, block)
    state = _info_init(prob)
    H_blocks = prob.H1.reshape(m1 // block, block, prob.n)
    y_blocks = prob.y1.reshape(m1 // block, block)
    R_blocks = prob.R1.reshape(m1 // block, block)

    def step(st: KFState, inp):
        H, y, R = inp
        return correct(st, H, y, R), None

    final, _ = jax.lax.scan(step, state, (H_blocks, y_blocks, R_blocks))
    return final.x
