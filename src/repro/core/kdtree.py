"""k-d tree adaptive domain — space-recursive decomposition of Ω ⊂ R².

The shelf tiling (:class:`repro.core.domain.ShelfTiling2D`) constrains
its cells to pr strips x pc columns, so a strongly anisotropic
observation network — mass on a thin diagonal or curved band — wastes
whole cells on empty strips (the ROADMAP's open quadtree/k-d item).
:class:`KDTreeDomain` drops the shelf constraint: the domain is split by
a k-d tree whose leaves are axis-aligned rectangles and whose cut planes
sit at observation *medians* (the space-recursive decomposition line of
D'Amore & Cacciapuoti, arXiv:2312.00007, applied to the DD-DA framework
of arXiv:2203.16535).  Each recursion level halves the leaf budget
(``ceil(k/2)`` / ``floor(k/2)``, so any p >= 1 works) and splits the
rectangle along the axis with the most mesh cells, at the quantile that
balances the two leaf budgets.

DyDD on this domain is the rebuild itself: ``rebalance`` re-derives the
cut planes from the current stream — warm-started in the sense that the
tree *structure* (recursion order, leaf identity) is stable, so the
migration volume is counted rank-by-rank against the previous leaf
assignment, exactly like the 1D/2D DyDD movement accounting.

Cut planes are placed at the midpoint of *distinct* consecutive order
statistics nearest the target quantile, then snapped to the nearest
mesh line (``k / nx`` or ``k / ny``) so leaf rectangles tile whole
raster cells and col_sets align exactly with raster columns.  Ties on a
cut (possible when observation coordinates are themselves quantized to
mesh lines) are kept whole on one side by consistent half-open
semantics, so the tie-dumping failure of the pre-fix ``dydd.migrate_1d``
cannot occur (the realized loads deviate from the targets by at most the
tie-group mass plus the snap quantization).

The processor graph is the leaf face-adjacency graph — irregular, not a
grid — which is precisely what exercises the graph-general
``Decomposition`` machinery: ``decomposition(overlap=s)`` builds per-leaf
*rectangular* col_sets (core cells ∪ s-cell face halos clipped at the
domain boundary), and ``Decomposition.halo_exchange`` discovers the
resulting edge schedule from col_set intersections, so
``ddkf.solve_shardmap(comm="neighbour")`` runs unchanged on a flat
``(p,)`` device mesh with ``ppermute`` rounds between arbitrary leaf
pairs.
"""
from __future__ import annotations

import numpy as np

from repro.core import dd as dd_mod
from repro.core import domain as domain_mod
from repro.core import dydd as dydd_mod


def _clip_unit(x: np.ndarray) -> np.ndarray:
    """Clamp coordinates into [0, 1) — boundary observations (x == 1.0)
    stay in the last cell instead of falling off the half-open grid."""
    return np.clip(x, 0.0, np.nextafter(1.0, 0.0))


class KDTreeDomain:
    """p axis-aligned rectangular leaves of [0,1]² split at obs medians.

    State columns are raster-ordered exactly like the shelf tiling:
    global column ``iy * nx + ix`` is the mesh point at
    ``((ix + 0.5) / nx, (iy + 0.5) / ny)``.  Leaf i's core is the set of
    mesh cells whose centre lies in its rectangle; cores partition the
    mesh because the leaves partition [0,1)² with half-open cuts.
    """

    ndim = 2

    def __init__(self, nx: int, ny: int, p: int,
                 rects: np.ndarray | None = None):
        self.nx, self.ny = int(nx), int(ny)
        self._p = int(p)
        if self._p < 1:
            raise ValueError(f"p must be >= 1 (got {p})")
        self._depth = int(np.ceil(np.log2(self._p))) if self._p > 1 else 0
        self._cx = (np.arange(self.nx) + 0.5) / self.nx
        self._cy = (np.arange(self.ny) + 0.5) / self.ny
        if rects is None:
            # No stream yet: geometric splits (cuts at the budget-weighted
            # rectangle fraction) give a deterministic near-even tiling.
            rects = self._build(np.empty((0, 2)), self._even_targets(0))
        self.rects = np.asarray(rects, np.float64)
        assert self.rects.shape == (self._p, 4)

    # -- Domain protocol statics -------------------------------------------

    @property
    def n(self) -> int:
        return self.nx * self.ny

    @property
    def p(self) -> int:
        return self._p

    # -- tree construction --------------------------------------------------

    def _even_targets(self, m: int) -> np.ndarray:
        t = np.full((self._p,), m // self._p, np.int64)
        t[:m % self._p] += 1
        return t

    def _cells_in(self, lo: float, hi: float, axis: int) -> np.ndarray:
        centers = self._cx if axis == 0 else self._cy
        return centers[(centers >= lo) & (centers < hi)]

    def _choose_cut(self, rect, pts: np.ndarray, axis: int,
                    q: float) -> tuple:
        """(cut plane, split error) along ``axis`` near the q-quantile of
        ``pts``, **snapped to a mesh line** ``k / nx`` (or ``k / ny``) so
        every leaf rectangle tiles whole raster cells and the col_sets
        align exactly with raster columns.  Among the valid lines (each
        side keeps >= 1 mesh cell) the one whose half-open point split
        lands closest to the target quantile wins (NOT the line nearest
        the unsnapped median: on a dense band that can shed a whole
        column's mass to one side), ties to the leftmost line.  The
        returned error ``|#left - q·#pts|`` is what :meth:`_build` uses
        to pick the split *axis*.  A snapped cut can coincide with a
        grid-quantized observation coordinate; ownership and the build's
        split mask are both half-open (``pts < cut`` goes left), so a tie
        group on the line is kept whole on the right side — consistent
        between counting and building, no tie dumping.  Rectangles with a
        single cell along ``axis`` fall back to an unsnapped cut at the
        order-statistics midpoint (kept off observation coordinates)."""
        lo, hi = (rect[0], rect[1]) if axis == 0 else (rect[2], rect[3])
        v = np.sort(pts[:, axis])
        cut = lo + q * (hi - lo)            # geometric fallback
        if v.size >= 2:
            c = int(np.clip(round(q * v.size), 1, v.size - 1))
            gaps = np.where(v[1:] > v[:-1])[0] + 1   # cut positions
            if gaps.size:
                g = int(gaps[np.argmin(np.abs(gaps - c))])
                cut = 0.5 * (v[g - 1] + v[g])
        nmesh = self.nx if axis == 0 else self.ny
        cells = self._cells_in(lo, hi, axis)
        if cells.size >= 2:
            # Valid snap lines: the first cell has index
            # round(cells[0] * nmesh - 0.5), and a cut at line k leaves
            # cells [..k-1] left, [k..] right — k spans (first+1) .. last.
            first = int(round(cells[0] * nmesh - 0.5))
            last = int(round(cells[-1] * nmesh - 0.5))
            ks = np.arange(first + 1, last + 1, dtype=np.int64)
            if v.size >= 2:
                lefts = np.searchsorted(v, ks / nmesh, side="left")
                errs = np.abs(lefts - q * v.size)
                i = int(np.argmin(errs))
                return ks[i] / nmesh, float(errs[i])
            k = int(np.clip(round(cut * nmesh), first + 1, last))
            return k / nmesh, 0.0
        cut = float(np.clip(cut, np.nextafter(lo, 1.0),
                            np.nextafter(hi, 0.0)))
        err = (float(abs(np.searchsorted(v, cut, side="left")
                         - q * v.size)) if v.size else 0.0)
        return cut, err

    def _build(self, pts: np.ndarray, targets: np.ndarray) -> np.ndarray:
        """Leaf rectangles from recursive median splits, leaf-id order.

        ``targets`` (p,) is the per-leaf observation budget (equal split,
        or the halo-cost-adjusted targets of the overlap-aware DyDD);
        each internal node cuts at the quantile that hands the left
        subtree exactly its share of the budget."""
        pts = np.asarray(pts, np.float64).reshape(-1, 2)
        out: list = []

        def rec(rect, pts, targets):
            k = targets.shape[0]
            if k == 1:
                out.append(rect)
                return
            kl = (k + 1) // 2
            tot = int(targets.sum())
            q = (float(targets[:kl].sum()) / tot) if tot > 0 else kl / k
            # Candidate axes: any with >= 2 mesh cells (a snappable cut);
            # if neither qualifies, fall back to the historic cell-count
            # heuristic on whichever axis has more.  Among candidates the
            # split with the smaller quantile error wins — on a snapped
            # mesh the nominally "longer" axis can only offer coarse
            # splits (a dense diagonal band sheds a whole column's mass),
            # while the other axis may land nearly exactly on target.
            # Ties: more cells, then the x axis (deterministic).
            ncx = self._cells_in(rect[0], rect[1], 0).size
            ncy = self._cells_in(rect[2], rect[3], 1).size
            axes = [a for a, nc in ((0, ncx), (1, ncy)) if nc >= 2]
            if not axes:
                if ncx != ncy:
                    axes = [0 if ncx > ncy else 1]
                else:
                    axes = [0 if (rect[1] - rect[0])
                            >= (rect[3] - rect[2]) else 1]
            best = None
            for a in axes:
                cut_a, err_a = self._choose_cut(rect, pts, a, q)
                key = (err_a, -(ncx if a == 0 else ncy), a)
                if best is None or key < best[0]:
                    best = (key, a, cut_a)
            _, axis, cut = best
            if axis == 0:
                left = (rect[0], cut, rect[2], rect[3])
                right = (cut, rect[1], rect[2], rect[3])
                mask = pts[:, 0] < cut
            else:
                left = (rect[0], rect[1], rect[2], cut)
                right = (rect[0], rect[1], cut, rect[3])
                mask = pts[:, 1] < cut
            rec(left, pts[mask], targets[:kl])
            rec(right, pts[~mask], targets[kl:])

        rec((0.0, 1.0, 0.0, 1.0), pts,
            np.asarray(targets, np.int64))
        return np.asarray(out, np.float64)

    # -- Domain protocol ----------------------------------------------------

    def _owners(self, obs: np.ndarray) -> np.ndarray:
        x = _clip_unit(obs[:, 0])
        y = _clip_unit(obs[:, 1])
        owner = np.full((obs.shape[0],), -1, np.int64)
        for i, (x0, x1, y0, y1) in enumerate(self.rects):
            inside = (x >= x0) & (y >= y0)
            if x1 < 1.0:
                inside &= x < x1
            if y1 < 1.0:
                inside &= y < y1
            owner[inside & (owner < 0)] = i
        return owner

    def counts(self, obs: np.ndarray) -> np.ndarray:
        owner = self._owners(np.asarray(obs, np.float64))
        return np.bincount(owner, minlength=self._p).astype(np.int64)

    def rebalance(self, obs: np.ndarray,
                  cost_offsets: np.ndarray | None = None
                  ) -> domain_mod.RebalanceInfo:
        obs = np.asarray(obs, np.float64).reshape(-1, 2)
        m = obs.shape[0]
        if cost_offsets is None:
            targets = self._even_targets(m)
        else:
            off = np.maximum(np.rint(np.asarray(cost_offsets)
                                     ).reshape(-1), 0).astype(np.int64)
            if off.shape != (self._p,):
                raise ValueError(f"cost_offsets must have {self._p} "
                                 f"entries, got {off.shape}")
            # Balanced *work* (obs + halo cost) per leaf, converted back
            # to observation budgets exactly like the 1D weighted DyDD.
            work = self._even_targets(m + int(off.sum()))
            targets = dydd_mod._offset_targets(work, off, m)
        owner_before = self._owners(obs)
        self.rects = self._build(obs, targets)
        migrated = int((self._owners(obs) != owner_before).sum())
        return domain_mod.RebalanceInfo(migrated=migrated,
                                        rounds=self._depth)

    def _cell_ranges(self, rect) -> tuple:
        """Half-open (ix0, ix1, iy0, iy1) mesh-cell index window of the
        cells whose centre lies in ``rect``."""
        x0, x1, y0, y1 = rect
        ix0 = int(np.searchsorted(self._cx, x0, side="left"))
        ix1 = int(np.searchsorted(self._cx, x1, side="left"))
        iy0 = int(np.searchsorted(self._cy, y0, side="left"))
        iy1 = int(np.searchsorted(self._cy, y1, side="left"))
        return ix0, ix1, iy0, iy1

    def decomposition(self, overlap: int = 0) -> dd_mod.Decomposition:
        if overlap < 0:
            raise ValueError(f"overlap must be >= 0 (got {overlap})")
        col_sets = []
        for rect in self.rects:
            ix0, ix1, iy0, iy1 = self._cell_ranges(rect)
            if ix1 <= ix0 or iy1 <= iy0:   # empty core: no halo either
                col_sets.append(np.empty((0,), np.int64))
                continue
            x0, x1, y0, y1 = rect
            # Face halos: absorb `overlap` mesh columns/rows across every
            # *interior* face (the domain boundary has no neighbour to
            # absorb from), clipped at the mesh edge.  The expanded
            # window stays rectangular — corners between two interior
            # faces are included, which is what keeps the col_set a
            # contiguous raster rectangle per row.
            hx0 = max(0, ix0 - overlap) if x0 > 0.0 else ix0
            hx1 = min(self.nx, ix1 + overlap) if x1 < 1.0 else ix1
            hy0 = max(0, iy0 - overlap) if y0 > 0.0 else iy0
            hy1 = min(self.ny, iy1 + overlap) if y1 < 1.0 else iy1
            ixs = np.arange(hx0, hx1, dtype=np.int64)
            iys = np.arange(hy0, hy1, dtype=np.int64)
            col_sets.append((iys[:, None] * self.nx
                             + ixs[None, :]).reshape(-1))
        return dd_mod.Decomposition(n=self.n, col_sets=tuple(col_sets),
                                    overlap=overlap, boundaries=None)

    def graph_edges(self) -> list:
        """Leaf face-adjacency graph: (i, j) iff the rectangles share a
        face segment of positive length.  Cut values are shared exactly
        between siblings' descendants, so face matching is exact."""
        edges = set()
        r = self.rects
        for i in range(self._p):
            for j in range(i + 1, self._p):
                xi, xj = r[i], r[j]
                touch_x = (xi[1] == xj[0] or xj[1] == xi[0])
                touch_y = (xi[3] == xj[2] or xj[3] == xi[2])
                span_y = min(xi[3], xj[3]) - max(xi[2], xj[2])
                span_x = min(xi[1], xj[1]) - max(xi[0], xj[0])
                if (touch_x and span_y > 0.0) or (touch_y and span_x > 0.0):
                    edges.add((i, j))
        return sorted(edges)

    def mesh_axes(self) -> tuple:
        # The leaf graph is irregular — no torus axis to map onto — so
        # the device mesh is a flat (p,) chain; ppermute handles the
        # arbitrary leaf-pair edges of the coloured exchange schedule.
        return ("sub",), (self._p,)

    def obs_positions(self, obs: np.ndarray) -> np.ndarray:
        return domain_mod.raster_positions(obs, self.ny)

    @property
    def row_size(self) -> int | None:
        return self.nx

    def load_table(self, loads) -> np.ndarray:
        # Leaves have no grid layout; display them flat in leaf-id order
        # (which is recursion order, i.e. roughly space-filling).
        return np.asarray(loads, np.int64)

    def describe(self) -> dict:
        return {"ndim": 2, "kind": "kdtree", "n": self.n, "p": self._p,
                "nx": self.nx, "ny": self.ny, "depth": self._depth}

    def state_dict(self) -> dict:
        """The mutable cut state, as arrays (checkpoint leaves)."""
        return {"rects": self.rects.copy()}

    def load_state(self, state: dict) -> None:
        r = np.asarray(state["rects"], np.float64)
        assert r.shape == (self._p, 4)
        self.rects = r.copy()
