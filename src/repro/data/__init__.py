"""Data pipeline: synthetic corpora, packing, DyDD-balanced sharding."""
from repro.data.pipeline import (  # noqa: F401
    Document, synthetic_corpus, pack_documents, BalancedLoader)
from repro.data.observations import make_observations  # noqa: F401
