"""Observation-location generators for the DA experiments (paper §6).

The paper's scenarios need observations that are "non uniformly distributed
and general sparse"; we provide the distributions used by the benchmark
tables, including configurations where entire subdomains start empty.

Multi-cycle *streams* of observations (drifting swarms, bursty clusters,
sensor dropout, ...) live in :mod:`repro.assim.streams`, which builds on
the single-snapshot generators here.
"""
from __future__ import annotations

import numpy as np

KINDS = ("uniform", "beta", "clustered")


def squeeze_out_of_subdomains(obs: np.ndarray, empty_subdomains,
                              p: int, rng: np.random.Generator) -> np.ndarray:
    """Remap observations so the listed p-way uniform intervals are empty.

    Each observation keeps its within-interval offset and is assigned
    (seeded-uniformly) to one of the allowed intervals — reproduces the
    paper's Example 1 Case 2 / Example 2 Cases 2-4 setups, and the
    streaming sensor-dropout scenario.
    """
    empty = set(int(i) for i in empty_subdomains)
    bad = [i for i in empty if not 0 <= i < p]
    if bad:
        raise ValueError(
            f"empty_subdomains {sorted(bad)} out of range for p={p}")
    allowed = [i for i in range(p) if i not in empty]
    if not allowed:
        raise ValueError(
            f"cannot empty every subdomain: p={p}, "
            f"empty_subdomains={sorted(empty)} leaves no interval for the "
            f"observations (did you forget to pass p?)")
    w = 1.0 / p
    frac = np.asarray(obs, dtype=np.float64) % 1.0
    idx = rng.integers(0, len(allowed), len(frac))
    return (np.asarray(allowed, dtype=np.float64)[idx] + frac) * w


def squeeze_out_of_rect(pts: np.ndarray, x_hi: float, y_hi: float,
                        rng: np.random.Generator) -> np.ndarray:
    """Re-draw 2D points inside [0, x_hi) x [0, y_hi) uniformly into the
    complementary L-shaped region — the 2D analogue of
    :func:`squeeze_out_of_subdomains` (a rectangle of tiling cells goes
    dark; Figure 1's empty-subdomain configuration).
    """
    if not (0.0 < x_hi <= 1.0 and 0.0 < y_hi <= 1.0):
        raise ValueError(f"dead rectangle [0,{x_hi})x[0,{y_hi}) must lie "
                         f"inside the unit square with positive extent")
    if x_hi >= 1.0 and y_hi >= 1.0:
        raise ValueError("cannot empty the whole domain: the dead "
                         "rectangle covers [0,1)² and leaves nowhere for "
                         "the observations")
    pts = np.asarray(pts, dtype=np.float64).copy()
    inside = (pts[:, 0] < x_hi) & (pts[:, 1] < y_hi)
    k = int(inside.sum())
    if k == 0:
        return pts
    # Exact area-weighted sampling over the two strips of the L:
    # right strip [x_hi,1) x [0,1), top-left strip [0,x_hi) x [y_hi,1).
    a_right = (1.0 - x_hi)
    a_top = x_hi * (1.0 - y_hi)
    right = rng.uniform(0, 1, k) < a_right / (a_right + a_top)
    u, v = rng.uniform(0, 1, k), rng.uniform(0, 1, k)
    xs = np.where(right, x_hi + (1.0 - x_hi) * u, x_hi * u)
    ys = np.where(right, v, y_hi + (1.0 - y_hi) * v)
    pts[inside] = np.stack([xs, ys], axis=1)
    return pts


def make_observations(m: int, kind: str = "beta", seed: int = 0,
                      empty_subdomains: tuple = (), p: int = 1) -> np.ndarray:
    """m observation locations in [0, 1).

    kind: "uniform" | "beta" (skewed) | "clustered" (Gaussian bumps).
    empty_subdomains: indices (of a p-way uniform split) that must contain
    no observations — reproduces the paper's Example 1 Case 2 / Example 2
    Cases 2-4 setups.  Requires ``p > len(empty_subdomains)``; the default
    p=1 admits no empty subdomains.
    """
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        obs = rng.uniform(0, 1, m)
    elif kind == "beta":
        obs = rng.beta(2.0, 5.0, m)
    elif kind == "clustered":
        centers = rng.uniform(0.1, 0.9, 3)
        c = rng.integers(0, len(centers), m)
        obs = np.clip(centers[c] + 0.05 * rng.normal(size=m), 0, 0.999999)
    else:
        raise ValueError(
            f"unknown observation kind {kind!r}; expected one of {KINDS}")

    if empty_subdomains:
        obs = squeeze_out_of_subdomains(obs, empty_subdomains, p, rng)
    return np.sort(obs)
