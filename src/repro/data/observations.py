"""Observation-location generators for the DA experiments (paper §6).

The paper's scenarios need observations that are "non uniformly distributed
and general sparse"; we provide the distributions used by the benchmark
tables, including configurations where entire subdomains start empty.
"""
from __future__ import annotations

import numpy as np


def make_observations(m: int, kind: str = "beta", seed: int = 0,
                      empty_subdomains: tuple = (), p: int = 1) -> np.ndarray:
    """m observation locations in [0, 1).

    kind: "uniform" | "beta" (skewed) | "clustered" (Gaussian bumps).
    empty_subdomains: indices (of a p-way uniform split) that must contain
    no observations — reproduces the paper's Example 1 Case 2 / Example 2
    Cases 2-4 setups.
    """
    rng = np.random.default_rng(seed)
    if kind == "uniform":
        obs = rng.uniform(0, 1, m)
    elif kind == "beta":
        obs = rng.beta(2.0, 5.0, m)
    elif kind == "clustered":
        centers = rng.uniform(0.1, 0.9, 3)
        c = rng.integers(0, len(centers), m)
        obs = np.clip(centers[c] + 0.05 * rng.normal(size=m), 0, 0.999999)
    else:
        raise ValueError(kind)

    if empty_subdomains:
        # squeeze all mass out of the forbidden uniform intervals
        allowed = [i for i in range(p) if i not in empty_subdomains]
        assert allowed, "cannot empty every subdomain"
        w = 1.0 / p
        # map each obs into one of the allowed intervals, preserving its
        # within-interval offset
        frac = obs % 1.0
        idx = rng.integers(0, len(allowed), m)
        obs = np.array([(allowed[i] + f) * w for i, f in zip(idx, frac)])
    return np.sort(obs)
