"""Token data pipeline with DyDD-balanced data-parallel sharding.

Documents have heavy-tailed lengths (real corpora do), so naive round-robin
assignment leaves data-parallel shards with unequal token counts — the LM
incarnation of the paper's "observations non uniformly distributed"
problem.  ``BalancedLoader`` treats per-shard token counts as DyDD loads on
the DP-axis ring graph and migrates whole documents between *neighbouring*
shards per the diffusion schedule before packing (DESIGN.md §4.1), so the
padding waste (= straggler work) is levelled every window.

Everything is deterministic given the seed (restart-safe: the loader state
is (seed, step) and is stored in checkpoints).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from repro.core import balance as balance_mod


@dataclasses.dataclass(frozen=True)
class Document:
    doc_id: int
    tokens: np.ndarray      # (len,) int32


def synthetic_corpus(num_docs: int, vocab_size: int, seed: int = 0,
                     mean_len: int = 512, max_len: int = 4096):
    """Heavy-tailed (lognormal) document lengths; deterministic tokens."""
    rng = np.random.default_rng(seed)
    lengths = np.clip(rng.lognormal(np.log(mean_len), 0.8,
                                    num_docs).astype(np.int64),
                      16, max_len)
    docs = []
    for i, L in enumerate(lengths):
        toks = rng.integers(1, vocab_size, size=int(L), dtype=np.int64)
        docs.append(Document(doc_id=i, tokens=toks.astype(np.int32)))
    return docs


def pack_documents(docs: Sequence[Document], batch: int, seq: int,
                   bos: int = 0):
    """Greedy first-fit packing into (batch, seq) with BOS separators.

    Returns (tokens, labels, mask) int32/float32 arrays; mask zeroes the
    padding and each document's final position.
    """
    tokens = np.zeros((batch, seq), np.int32)
    mask = np.zeros((batch, seq), np.float32)
    fill = np.zeros(batch, np.int64)
    for doc in docs:
        L = min(len(doc.tokens), seq - 1)
        row = int(np.argmin(fill))
        if fill[row] + L + 1 > seq:
            continue        # window full: drop remainder (counted by caller)
        o = fill[row]
        tokens[row, o] = bos
        tokens[row, o + 1:o + 1 + L] = doc.tokens[:L]
        mask[row, o:o + L] = 1.0
        fill[row] += L + 1
    labels = np.zeros_like(tokens)
    labels[:, :-1] = tokens[:, 1:]
    return tokens, labels, mask


@dataclasses.dataclass
class LoaderStats:
    loads_before: np.ndarray
    loads_after: np.ndarray
    docs_moved: int
    efficiency_before: float
    efficiency_after: float


class BalancedLoader:
    """Deterministic, restart-safe loader with DyDD shard balancing.

    Each step window: draw ``window_docs`` fresh documents, hash-assign them
    to the ``dp`` shards (location-based initial DD), run the DyDD plan on
    the ring topology, migrate whole documents between adjacent shards, and
    pack per shard.
    """

    def __init__(self, vocab_size: int, dp: int, batch_per_shard: int,
                 seq: int, seed: int = 0, window_docs: int | None = None,
                 balance: bool = True, mean_len: int = 512):
        self.vocab_size = vocab_size
        self.dp = dp
        self.batch_per_shard = batch_per_shard
        self.seq = seq
        self.seed = seed
        self.balance = balance
        self.mean_len = mean_len
        self.window_docs = window_docs or dp * batch_per_shard * 4
        self.topo = balance_mod.Topology.ring(dp)
        self.step = 0
        self.last_stats: LoaderStats | None = None

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, st):
        self.seed = int(st["seed"])
        self.step = int(st["step"])

    def next_batch(self):
        """Returns (tokens, labels, mask) of shape (dp*batch_per_shard, seq)
        with rows grouped by shard (row r belongs to shard r // bps)."""
        docs = synthetic_corpus(self.window_docs, self.vocab_size,
                                seed=hash((self.seed, self.step)) % 2**31,
                                mean_len=self.mean_len,
                                max_len=self.seq - 1)
        self.step += 1

        # initial DD: documents land on shards by id hash (data location)
        shard_of = np.array([d.doc_id % self.dp for d in docs])
        loads = np.bincount(
            shard_of, weights=[len(d.tokens) for d in docs],
            minlength=self.dp).astype(np.int64)

        moved = 0
        if self.balance and self.dp > 1:
            plan = balance_mod.plan(loads, self.topo)
            # realize the plan with whole documents (greedy nearest-size)
            by_shard = {i: [d for d, s in zip(docs, shard_of) if s == i]
                        for i in range(self.dp)}
            for src, dst, amount in plan.moves:
                pool = sorted(by_shard[src], key=lambda d: len(d.tokens))
                sent = 0
                while pool and sent < amount:
                    # send the doc that best fits the remaining amount; stop
                    # if even the best choice overshoots by more than it
                    # helps (whole-document granularity).
                    rem = amount - sent
                    d = min(pool, key=lambda dd: abs(len(dd.tokens) - rem))
                    if len(d.tokens) > 2 * rem:
                        break
                    pool.remove(d)
                    by_shard[src].remove(d)
                    by_shard[dst].append(d)
                    sent += len(d.tokens)
                    moved += 1
            new_loads = np.array(
                [sum(len(d.tokens) for d in by_shard[i])
                 for i in range(self.dp)], np.int64)
        else:
            by_shard = {i: [d for d, s in zip(docs, shard_of) if s == i]
                        for i in range(self.dp)}
            new_loads = loads

        from repro.core import dydd as dydd_mod
        self.last_stats = LoaderStats(
            loads_before=loads, loads_after=new_loads, docs_moved=moved,
            efficiency_before=dydd_mod.balance_ratio(loads),
            efficiency_after=dydd_mod.balance_ratio(new_loads))

        toks, labs, masks = [], [], []
        for i in range(self.dp):
            t, l, m = pack_documents(by_shard[i], self.batch_per_shard,
                                     self.seq)
            toks.append(t)
            labs.append(l)
            masks.append(m)
        return (np.concatenate(toks), np.concatenate(labs),
                np.concatenate(masks))
