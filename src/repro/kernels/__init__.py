"""Pallas TPU kernels + jnp oracles.  See EXAMPLE.md for the layout."""
from repro.kernels import ops, ref  # noqa: F401
