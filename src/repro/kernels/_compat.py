"""Version compatibility shims for the Pallas TPU API.

``pltpu.CompilerParams`` was named ``TPUCompilerParams`` in older jax
releases; resolve whichever this runtime ships so the kernels (and their
interpret-mode CPU tests) work on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
