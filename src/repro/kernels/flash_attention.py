"""Flash attention Pallas TPU kernel (causal + sliding-window).

TPU adaptation (DESIGN.md §3): blocks are sized for VMEM and MXU alignment
(q/k tiles 128-multiple, head_dim padded to 128/256); the kv-block grid
dimension is the *sequential* (arbitrary) TPU grid axis, so the online
softmax accumulators (m, l, acc) live in VMEM scratch across kv steps —
the HBM->VMEM streaming analogue of the CUDA shared-memory algorithm.

Layout: q, k, v are (BH, S, D) with heads pre-folded into batch and GQA
pre-expanded (the ops.py wrapper does both).  Sliding window w > 0 masks
kv positions <= q - w; causal masks kv > q.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, seq_len: int):
    qi = pl.program_id(1)          # q block index
    ki = pl.program_id(2)          # kv block index (sequential axis)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Skip fully-masked blocks (causal: kv block entirely after q block;
    # window: kv block entirely before the window opening).
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        run = jnp.logical_and(
            run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        in_bounds = kpos < seq_len
        # zero out padded k/v rows: out-of-bounds block slack is undefined
        # (NaN in interpret mode) and 0 * NaN = NaN otherwise
        row_ok = (k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < seq_len
        k = jnp.where(row_ok, k, 0.0)
        v = jnp.where(row_ok, v, 0.0)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                              # (bq, bk)
        ok = in_bounds
        if causal:
            ok &= kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                        # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        # guard fully-masked rows (m_cur == NEG_INF): exp(-inf - -inf)
        # must contribute 0, not 1
        p = jnp.where(s > 0.5 * NEG_INF,
                      jnp.exp(s - m_cur[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        m_ref[...] = m_cur
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q, k, v: (BH, S, D) -> (BH, S, D).  window <= 0 means unbounded."""
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq = pl.cdiv(s, block_q)
    nk = pl.cdiv(s, block_k)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=s)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            # acc, m, l accumulators persist across the sequential kv axis
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
