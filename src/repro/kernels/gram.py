"""Weighted Gram-matrix Pallas TPU kernel — the DD-KF compute hot spot.

Every DyDD re-partition re-factorizes each subdomain's local normal matrix
N_i = A_i^T diag(r) A_i (paper eq. 27); with p subdomains this is a batch
of (m x w)^T (m x w) products, m ~ 5000+, w ~ n/p — the dominant FLOPs of
the setup phase.

TPU mapping: grid (p, m/bm) with the reduction (m) axis as the sequential
dimension; the (w x w) accumulator lives in VMEM scratch; each step loads
one (bm x w) tile of A_i, scales rows by r, and issues a single MXU
matmul-accumulate.  w is padded to 128 lanes by the wrapper in ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _gram_kernel(a_ref, r_ref, o_ref, acc_ref, *, block_m: int,
                 m_total: int):
    mi = pl.program_id(1)
    nm = pl.num_programs(1)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0].astype(jnp.float32)              # (bm, w)
    r = r_ref[0].astype(jnp.float32)              # (bm,)
    # mask padded rows of the final block
    row = mi * block_m + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_m, 1), 0)
    valid = row < m_total
    ar = jnp.where(valid, a * r[:, None], 0.0)
    a = jnp.where(valid, a, 0.0)
    acc_ref[...] += jax.lax.dot_general(
        ar, a, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(mi == nm - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def gram(A, r, *, block_m: int = 256, interpret: bool = False):
    """A: (p, m, w), r: (p, m)  ->  N: (p, w, w) with N = A^T diag(r) A."""
    p, m, w = A.shape
    block_m = min(block_m, m)
    nm = pl.cdiv(m, block_m)
    kernel = functools.partial(_gram_kernel, block_m=block_m, m_total=m)
    return pl.pallas_call(
        kernel,
        grid=(p, nm),
        in_specs=[
            pl.BlockSpec((1, block_m, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_m), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, w, w), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((p, w, w), A.dtype),
        scratch_shapes=[pltpu.VMEM((w, w), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(A, r)
