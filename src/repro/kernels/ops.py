"""jit'd public wrappers for the Pallas kernels.

On TPU backends the Pallas kernels run natively; elsewhere (this CPU
container, and any backend without Mosaic) the wrappers either run the
kernels in interpret mode (tests) or fall back to the jnp references —
selected by ``mode``:

  "auto"      — kernel on TPU, reference otherwise (production default)
  "kernel"    — force the Pallas kernel (native)
  "interpret" — force the Pallas kernel in interpret mode (CPU validation)
  "ref"       — force the jnp oracle
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import gram as _gram
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str) -> str:
    if mode == "auto":
        return "kernel" if _on_tpu() else "ref"
    return mode


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    mode: str = "auto", block_q: int = 128,
                    block_k: int = 128):
    """q, k, v: (BH, S, D) -> (BH, S, D)."""
    m = _resolve(mode)
    if m == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=(m == "interpret"))


def rglru_scan(a, b, *, mode: str = "auto", block_s: int = 256,
               block_w: int = 128):
    """h_t = a_t h_{t-1} + b_t; a, b: (B, S, W)."""
    m = _resolve(mode)
    if m == "ref":
        return _ref.rglru_scan_ref(a, b)
    return _rg.rglru_scan(a, b, block_s=block_s, block_w=block_w,
                          interpret=(m == "interpret"))


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, mode: str = "auto"):
    """Head-folded SSD: x (BH,S,P), dt (BH,S), A (BH,), B/C (BH,S,N)."""
    m = _resolve(mode)
    if m == "ref":
        return _ref.ssd_heads_ref(x, dt, A, B, C, chunk)
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk,
                         interpret=(m == "interpret"))


def gram(A, r, *, mode: str = "auto", block_m: int = 256):
    """Batched weighted Gram N = A^T diag(r) A — the DD-KF normal-matrix
    assembly hot spot (paper eq. 27).  A: (p, m, w), r: (p, m).

    float64 inputs always take the jnp reference under mode="auto" (the
    MXU has no f64 path); for the native kernel the lane (w) axis is
    zero-padded to the 128-lane tile and the result sliced back.
    """
    m = _resolve(mode)
    if m == "ref" or (mode == "auto" and A.dtype == jnp.float64):
        return _ref.gram_ref(A, r)
    w = A.shape[-1]
    wpad = -w % 128
    if m == "kernel" and wpad:
        A = jnp.pad(A, ((0, 0), (0, 0), (0, wpad)))
        out = _gram.gram(A, r, block_m=block_m, interpret=False)
        return out[:, :w, :w]
    return _gram.gram(A, r, block_m=block_m, interpret=(m == "interpret"))
