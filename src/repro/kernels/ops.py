"""jit'd public wrappers for the Pallas kernels.

On TPU backends the Pallas kernels run natively; elsewhere (this CPU
container, and any backend without Mosaic) the wrappers either run the
kernels in interpret mode (tests) or fall back to the jnp references —
selected by ``mode``:

  "auto"      — kernel on TPU, reference otherwise (production default)
  "kernel"    — force the Pallas kernel (native)
  "interpret" — force the Pallas kernel in interpret mode (CPU validation)
  "ref"       — force the jnp oracle
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import gram as _gram
from repro.kernels import rglru_scan as _rg
from repro.kernels import schwarz_step as _sch
from repro.kernels import ssd_scan as _ssd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(mode: str) -> str:
    if mode == "auto":
        return "kernel" if _on_tpu() else "ref"
    return mode


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    mode: str = "auto", block_q: int = 128,
                    block_k: int = 128):
    """q, k, v: (BH, S, D) -> (BH, S, D)."""
    m = _resolve(mode)
    if m == "ref":
        return _ref.attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=(m == "interpret"))


def rglru_scan(a, b, *, mode: str = "auto", block_s: int = 256,
               block_w: int = 128):
    """h_t = a_t h_{t-1} + b_t; a, b: (B, S, W)."""
    m = _resolve(mode)
    if m == "ref":
        return _ref.rglru_scan_ref(a, b)
    return _rg.rglru_scan(a, b, block_s=block_s, block_w=block_w,
                          interpret=(m == "interpret"))


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, mode: str = "auto"):
    """Head-folded SSD: x (BH,S,P), dt (BH,S), A (BH,), B/C (BH,S,N)."""
    m = _resolve(mode)
    if m == "ref":
        return _ref.ssd_heads_ref(x, dt, A, B, C, chunk)
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk,
                         interpret=(m == "interpret"))


# -- gram block_m autotuning -------------------------------------------------
#
# The best reduction tile depends on (p, m, w) — a tall skinny A wants a
# bigger m-tile to amortize the accumulator writeback, a wide one is
# VMEM-bound earlier.  First call per (shape, dtype, path) runs a tiny
# timed sweep; every later call (and every jit retrace with the same
# shape) hits the cache.

GRAM_BLOCK_CANDIDATES = (64, 128, 256, 512, 1024)

# Conservative VMEM budget for one grid step's working set: half of the
# ~16 MiB a TPU core has, leaving headroom for double buffering and the
# Mosaic scheduler's own allocations.  Candidates whose tile footprint
# exceeds this are rejected without being timed (a sweep that OOMs the
# kernel is worse than a slightly narrower candidate set) and recorded
# in the tuning report.
GRAM_VMEM_BUDGET_BYTES = 8 * 1024 * 1024
_GRAM_TUNE_CACHE: dict = {}


def gram_tile_bytes(block_m: int, w: int) -> int:
    """f32 VMEM working set of one gram grid step: the (block_m, w) A
    tile, its row-scaled copy, the (block_m,) r tile and the (w, w)
    accumulator scratch."""
    return 4 * (2 * block_m * w + block_m + w * w)


def autotune_gram_block(p: int, m: int, w: int, dtype,
                        interpret: bool = False) -> int:
    """Pick block_m for a (p, m, w) gram by timing the candidates once.

    Cached per (shape, dtype, path); the sweep costs two kernel launches
    per candidate (one compile+warmup, one timed).  Candidates whose
    VMEM tile footprint exceeds :data:`GRAM_VMEM_BUDGET_BYTES` are
    skipped (and recorded) rather than timed; the smallest candidate is
    always kept so the sweep cannot come up empty.
    """
    # Time exactly the shape the production path runs: the native kernel
    # sees the lane (w) axis zero-padded to the 128-lane tile (ops.gram
    # pads before calling it); interpret mode runs the raw width.
    if not interpret:
        w = w + (-w % 128)
    key = (int(p), int(m), int(w), jnp.dtype(dtype).name, bool(interpret))
    hit = _GRAM_TUNE_CACHE.get(key)
    if hit is not None:
        return hit["block_m"]
    candidates = sorted({min(c, m) for c in GRAM_BLOCK_CANDIDATES})
    rejected = {bm: gram_tile_bytes(bm, w) for bm in candidates
                if gram_tile_bytes(bm, w) > GRAM_VMEM_BUDGET_BYTES}
    kept = [bm for bm in candidates if bm not in rejected]
    if not kept:  # every candidate over budget: keep the narrowest
        kept = candidates[:1]
        rejected.pop(kept[0])
    A = jnp.ones((p, m, w), dtype)
    r = jnp.ones((p, m), dtype)
    sweep = {}
    for bm in kept:
        jax.block_until_ready(
            _gram.gram(A, r, block_m=bm, interpret=interpret))
        t0 = time.perf_counter()
        jax.block_until_ready(
            _gram.gram(A, r, block_m=bm, interpret=interpret))
        sweep[bm] = time.perf_counter() - t0
    best = min(sweep, key=sweep.get)
    _GRAM_TUNE_CACHE[key] = {"block_m": best, "time_s": sweep[best],
                             "sweep_s": sweep,
                             "rejected_vmem": {str(bm): int(fb) for bm, fb
                                               in rejected.items()}}
    # Journal the decision into the observability registry (first call
    # per shape only — the cache short-circuits repeats).
    from repro.obs import meters as meters_mod
    meters_mod.get_meters().event(
        "gram.autotune", shape=[int(p), int(m), int(w)],
        dtype=str(jnp.dtype(dtype)), block_m=int(best),
        candidates=sorted(int(b) for b in sweep),
        rejected_vmem=sorted(int(b) for b in rejected))
    return best


def gram_block_for(shape, dtype, mode: str = "auto"):
    """The block_m the gram path will use for this (p, m, w) shape —
    autotuned for the kernel paths, ``None`` when the shape resolves to
    the jnp reference (which has no blocking).  Call this *outside* jit
    (e.g. at operator-packing time) and pass the result through as a
    static argument."""
    m = _resolve(mode)
    if m == "ref" or (mode == "auto" and jnp.dtype(dtype) == jnp.float64):
        return None
    p, mm, w = shape
    return autotune_gram_block(p, mm, w, dtype, interpret=(m == "interpret"))


def gram_tuning_report() -> dict:
    """JSON-serializable snapshot of the autotune cache: per shape, the
    chosen block and the timed sweep (what the streaming benchmark
    records next to its pack times)."""
    return {
        f"p{p}_m{m}_w{w}_{dt}" + ("_interpret" if it else ""): dict(v)
        for (p, m, w, dt, it), v in _GRAM_TUNE_CACHE.items()
    }


# -- schwarz step block_m autotuning ----------------------------------------
#
# Same harness as gram, generalized to the fused solve kernel: first call
# per (p, m_loc, w, dtype, path) times the candidates once (fwd + bwd
# together — that is exactly what one solver iteration launches), with
# the same conservative VMEM budget.  ``pack_operator`` resolves the
# block host-side and threads it statically through the jitted solves.

SCHWARZ_BLOCK_CANDIDATES = GRAM_BLOCK_CANDIDATES
_SCHWARZ_TUNE_CACHE: dict = {}


def schwarz_tile_bytes(block_m: int, w: int) -> int:
    """f32 VMEM working set of one fused-step grid slot, priced at the
    union of both passes: the (block_m, w) A tile (+ its masked copy in
    the bwd pass), the four (block_m,) m-vectors (r, b, Ax, u), the
    stacked (2, w) xs operand, the (2, block_m) fwd output tile, and the
    (1, w) accumulator plus the four (w,) local vectors."""
    return 4 * (2 * block_m * w + 4 * block_m + 2 * w
                + 2 * block_m + 5 * w)


def autotune_schwarz_block(p: int, m: int, w: int, dtype,
                           interpret: bool = False) -> int:
    """Pick block_m for a (p, m, w) fused Schwarz step by timing the
    candidates once (one warmup + one timed launch of fwd+bwd each).
    Cached per (shape, dtype, path); over-VMEM candidates are rejected
    without being timed, keeping at least the narrowest."""
    if not interpret:
        w = w + (-w % 128)
    key = (int(p), int(m), int(w), jnp.dtype(dtype).name, bool(interpret))
    hit = _SCHWARZ_TUNE_CACHE.get(key)
    if hit is not None:
        return hit["block_m"]
    candidates = sorted({min(c, m) for c in SCHWARZ_BLOCK_CANDIDATES})
    rejected = {bm: schwarz_tile_bytes(bm, w) for bm in candidates
                if schwarz_tile_bytes(bm, w) > GRAM_VMEM_BUDGET_BYTES}
    kept = [bm for bm in candidates if bm not in rejected]
    if not kept:
        kept = candidates[:1]
        rejected.pop(kept[0])
    A = jnp.ones((p, m, w), dtype)
    xv = jnp.ones((p, w), dtype)
    mv = jnp.ones((m,), dtype)

    def run(bm):
        y, u = _sch.schwarz_fwd(A, xv, xv, block_m=bm, interpret=interpret)
        return _sch.schwarz_bwd(A, mv, mv, jnp.sum(y, 0), u, xv, xv, xv,
                                block_m=bm, interpret=interpret)

    sweep = {}
    for bm in kept:
        jax.block_until_ready(run(bm))
        t0 = time.perf_counter()
        jax.block_until_ready(run(bm))
        sweep[bm] = time.perf_counter() - t0
    best = min(sweep, key=sweep.get)
    _SCHWARZ_TUNE_CACHE[key] = {"block_m": best, "time_s": sweep[best],
                                "sweep_s": sweep,
                                "rejected_vmem": {str(bm): int(fb) for bm, fb
                                                  in rejected.items()}}
    from repro.obs import meters as meters_mod
    meters_mod.get_meters().event(
        "schwarz.autotune", shape=[int(p), int(m), int(w)],
        dtype=str(jnp.dtype(dtype)), block_m=int(best),
        candidates=sorted(int(b) for b in sweep),
        rejected_vmem=sorted(int(b) for b in rejected))
    return best


def schwarz_block_for(shape, dtype, mode: str = "auto"):
    """The block_m the fused solve path will use for this (p, m, w) —
    autotuned for the kernel paths, ``None`` when the shape resolves to
    the jnp reference.  Call outside jit (at operator-packing time) and
    pass through as a static argument."""
    m = _resolve(mode)
    if m == "ref" or (mode == "auto" and jnp.dtype(dtype) == jnp.float64):
        return None
    p, mm, w = shape
    return autotune_schwarz_block(p, mm, w, dtype,
                                  interpret=(m == "interpret"))


def schwarz_tuning_report() -> dict:
    """JSON-serializable snapshot of the schwarz autotune cache (same
    keying as :func:`gram_tuning_report`)."""
    return {
        f"p{p}_m{m}_w{w}_{dt}" + ("_interpret" if it else ""): dict(v)
        for (p, m, w, dt, it), v in _SCHWARZ_TUNE_CACHE.items()
    }


def export_tune_caches() -> dict:
    """Both autotune caches as one JSON-ready dict (checkpoint payload):
    a resumed engine re-imports them so the first post-restore solve
    doesn't re-run the candidate sweeps."""
    def dump(cache):
        return [{"key": list(k),
                 "block_m": int(v["block_m"]),
                 "time_s": float(v["time_s"]),
                 "sweep_s": {str(bm): float(t)
                             for bm, t in v["sweep_s"].items()},
                 "rejected_vmem": dict(v["rejected_vmem"])}
                for k, v in cache.items()]
    return {"gram": dump(_GRAM_TUNE_CACHE),
            "schwarz": dump(_SCHWARZ_TUNE_CACHE)}


def import_tune_caches(payload: dict) -> int:
    """Merge a previously exported cache payload in (existing entries
    win — they were timed on *this* host).  Returns entries added."""
    added = 0
    for name, cache in (("gram", _GRAM_TUNE_CACHE),
                        ("schwarz", _SCHWARZ_TUNE_CACHE)):
        for row in (payload or {}).get(name, []):
            p, m, w, dt, it = row["key"]
            key = (int(p), int(m), int(w), str(dt), bool(it))
            if key in cache:
                continue
            cache[key] = {"block_m": int(row["block_m"]),
                          "time_s": float(row["time_s"]),
                          "sweep_s": {int(bm): float(t) for bm, t
                                      in row["sweep_s"].items()},
                          "rejected_vmem": dict(row["rejected_vmem"])}
            added += 1
    return added


def schwarz_fwd(A, x, wdiv, *, mode: str = "auto",
                block_m: int | None = None):
    """Fused forward Schwarz half: (y, u) = (A @ (x * wdiv), A @ x) in
    one pass over A.  A: (p, m, w), x/wdiv: (p, w).

    float64 takes the jnp reference under mode="auto" (still single-pass
    — the reference uses the same stacked matmat); the native kernel
    pads the lane (w) axis to 128 with zero columns (extra columns
    contribute nothing to either product)."""
    m = _resolve(mode)
    if m == "ref" or (mode == "auto" and A.dtype == jnp.float64):
        return _ref.schwarz_fwd_ref(A, x, wdiv)
    if block_m is None:
        if isinstance(A, jax.core.Tracer):
            block_m = 256
        else:
            p, mm, w_ = A.shape
            block_m = autotune_schwarz_block(p, mm, w_, A.dtype,
                                             interpret=(m == "interpret"))
    w = A.shape[-1]
    wpad = -w % 128
    if m == "kernel" and wpad:
        A = jnp.pad(A, ((0, 0), (0, 0), (0, wpad)))
        x = jnp.pad(x, ((0, 0), (0, wpad)))
        wdiv = jnp.pad(wdiv, ((0, 0), (0, wpad)))
        return _sch.schwarz_fwd(A, x, wdiv, block_m=block_m,
                                interpret=False)
    return _sch.schwarz_fwd(A, x, wdiv, block_m=block_m,
                            interpret=(m == "interpret"))


def schwarz_bwd(A, r, b, Ax, u, x, muov, mask, *, mode: str = "auto",
                block_m: int | None = None):
    """Fused backward Schwarz half: rhs = (A^T @ (r * (b - Ax + u)) +
    muov * x) * mask in one pass over A with VMEM-resident residual
    tiles.  A: (p, m, w), r/b/Ax: (m,), u: (p, m), rest (p, w)."""
    m = _resolve(mode)
    if m == "ref" or (mode == "auto" and A.dtype == jnp.float64):
        return _ref.schwarz_bwd_ref(A, r, b, Ax, u, x, muov, mask)
    if block_m is None:
        if isinstance(A, jax.core.Tracer):
            block_m = 256
        else:
            p, mm, w_ = A.shape
            block_m = autotune_schwarz_block(p, mm, w_, A.dtype,
                                             interpret=(m == "interpret"))
    w = A.shape[-1]
    wpad = -w % 128
    if m == "kernel" and wpad:
        pad2 = ((0, 0), (0, wpad))
        out = _sch.schwarz_bwd(
            jnp.pad(A, ((0, 0), (0, 0), (0, wpad))), r, b, Ax, u,
            jnp.pad(x, pad2), jnp.pad(muov, pad2), jnp.pad(mask, pad2),
            block_m=block_m, interpret=False)
        return out[:, :w]
    return _sch.schwarz_bwd(A, r, b, Ax, u, x, muov, mask,
                            block_m=block_m, interpret=(m == "interpret"))


def gram(A, r, *, mode: str = "auto", block_m: int | None = None):
    """Batched weighted Gram N = A^T diag(r) A — the DD-KF normal-matrix
    assembly hot spot (paper eq. 27).  A: (p, m, w), r: (p, m).

    float64 inputs always take the jnp reference under mode="auto" (the
    MXU has no f64 path); for the native kernel the lane (w) axis is
    zero-padded to the 128-lane tile and the result sliced back.

    ``block_m=None`` autotunes the reduction tile on first call per shape
    (cached; see :func:`autotune_gram_block`) when the inputs are
    concrete, and falls back to 256 under tracing — jitted callers should
    resolve the block with :func:`gram_block_for` and pass it statically.
    """
    m = _resolve(mode)
    if m == "ref" or (mode == "auto" and A.dtype == jnp.float64):
        return _ref.gram_ref(A, r)
    if block_m is None:
        if isinstance(A, jax.core.Tracer):
            block_m = 256
        else:
            p, mm, w_ = A.shape
            block_m = autotune_gram_block(p, mm, w_, A.dtype,
                                          interpret=(m == "interpret"))
    w = A.shape[-1]
    wpad = -w % 128
    if m == "kernel" and wpad:
        A = jnp.pad(A, ((0, 0), (0, 0), (0, wpad)))
        out = _gram.gram(A, r, block_m=block_m, interpret=False)
        return out[:, :w, :w]
    return _gram.gram(A, r, block_m=block_m, interpret=(m == "interpret"))
