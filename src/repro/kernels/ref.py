"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q, k, v: (BH, S, D) -> (BH, S, D).  Naive softmax attention."""
    bh, s, d = q.shape
    scale = 1.0 / math.sqrt(d)
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    scores = jnp.where(ok[None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def rglru_scan_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t via associative scan.  a, b: (B, S, W)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def ssd_ref(x, dt, A, B, C, chunk: int):
    """Mamba-2 SSD oracle — defers to the model's reference implementation
    (one source of truth)."""
    from repro.models.ssd import ssd_ref as _ref
    return _ref(x, dt, A, B, C, chunk)


def ssd_heads_ref(x, dt, A, B, C, chunk: int):
    """Head-folded layout oracle matching the kernel's (BH, S, ...) layout.

    x: (BH, S, P), dt: (BH, S), A: (BH,), B, C: (BH, S, N).
    Sequential recurrence (exact):  S_t = exp(dt_t A) S_{t-1}
    + dt_t B_t x_t^T ;  y_t = C_t S_t.
    """
    bh, s, p = x.shape
    n = B.shape[-1]

    def per_bh(xb, dtb, Ab, Bb, Cb):
        def step(state, inp):
            xt, dtt, Bt, Ct = inp
            decay = jnp.exp(dtt * Ab)
            state = decay * state + dtt * Bt[:, None] * xt[None, :]
            return state, Ct @ state

        init = jnp.zeros((n, p), jnp.float32)
        _, y = jax.lax.scan(step, init, (xb, dtb, Bb, Cb))
        return y

    return jax.vmap(per_bh)(x, dt, A, B, C)


def gram_ref(A, r):
    """N = A^T diag(r) A, batched.  A: (p, m, w), r: (p, m)."""
    return jnp.einsum("pmw,pm,pmv->pwv", A, r, A)


def schwarz_fwd_ref(A, x, wdiv):
    """Fused forward half of the Schwarz step: (y, u) = (A @ (x * wdiv),
    A @ x) as ONE stacked matmat over A — same two-column single-pass
    structure as the kernel, so even the reference reads A once.
    A: (p, m, w), x/wdiv: (p, w) -> two (p, m) arrays."""
    xs = jnp.stack([x * wdiv, x], axis=1)          # (p, 2, w)
    yu = jnp.einsum("pmw,pkw->pkm", A, xs)
    return yu[:, 0], yu[:, 1]


def schwarz_bwd_ref(A, r, b, Ax, u, x, muov, mask):
    """Fused backward half: rhs = (A^T @ (r * (b - Ax + u)) + muov * x)
    * mask.  A: (p, m, w), r/b/Ax: (m,), u: (p, m), rest (p, w)."""
    resid = (b - Ax)[None] + u                     # (p, m)
    t = r[None] * resid
    return (jnp.einsum("pmw,pm->pw", A, t) + muov * x) * mask
