"""RG-LRU linear-recurrence Pallas TPU kernel.

Computes h_t = a_t * h_{t-1} + b_t over the sequence axis, blocked over
(batch, width): the grid is (B, W/bw, S/bs) with the *sequence* axis as the
sequential TPU grid dimension; the running state h (bw lanes) persists in a
VMEM scratch across sequence blocks, and each block's scan is a short
unrolled/fori loop over bs steps entirely in VMEM.

TPU adaptation: lanes (width) are the vector dimension — blocks are
(bs, bw) with bw a multiple of 128 so the per-step multiply-add maps to
full VPU lanes; HBM traffic is exactly 2 reads + 1 write per element
(streaming), the roofline optimum for a memory-bound scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)   # (bs, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("block_s", "block_w",
                                             "interpret"))
def rglru_scan(a, b, *, block_s: int = 256, block_w: int = 128,
               interpret: bool = False):
    """a, b: (B, S, W) -> h: (B, S, W) with h_t = a_t h_{t-1} + b_t."""
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    ns = pl.cdiv(S, block_s)
    nw = pl.cdiv(W, block_w)

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si:
                         (bi, si, wi)),
            pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si:
                         (bi, si, wi)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda bi, wi, si:
                               (bi, si, wi)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
