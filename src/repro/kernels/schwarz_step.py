"""Fused additive-Schwarz iteration step — the DD-KF solve hot loop.

Each solver iteration applies, per subdomain i (paper eqs. 23-26):

    y_i    = A_i @ (x_i * wdiv_i)          # overlap-weighted local matvec
    Ax     = allreduce_p(y_i)              # the one unavoidable collective
    resid  = b - Ax + A_i @ x_i            # local residual correction
    rhs_i  = (A_i^T @ (r * resid) + muov_i * x_i) * mask_i

The jnp composition reads the (m x w) local operator A_i from HBM three
times per iteration (two forward matvecs + one transposed reduction) and
materializes ``resid`` as an (m,) HBM round-trip.  The fused kernels cut
that to one double-pass with no resid materialization:

* :func:`schwarz_fwd` — ONE pass over A_i tiles computes both forward
  products as a single stacked (2, w) x (w, bm) MXU matmul per tile
  (``xs = [x * wdiv, x]``), emitting ``(y_i, u_i = A_i @ x_i)``.  The
  cross-subdomain ``Ax = psum(y)`` stays outside the kernel — it is the
  collective the decomposition exists to expose.
* :func:`schwarz_bwd` — the SECOND pass re-reads each (bm x w) A-tile,
  forms the matching resid tile ``b - Ax + u`` directly in VMEM
  (registers, never written back), and accumulates the transposed
  product ``A_tile^T @ (r * resid)`` into a (1, w) VMEM scratch; the
  ``+ muov * x`` / ``* mask`` epilogue runs once at the last m-block.

TPU mapping mirrors ``gram.py``: grid (p, m/bm) with the m axis
sequential, accumulator in VMEM scratch, lane (w) axis padded to 128 by
the wrapper in ops.py.  Unlike gram, f64 inputs keep an f64 accumulator
(interpret mode must stay ULP-comparable to the jnp path; the f32
accumulator is only used for f32/bf16 inputs where the MXU accumulates
in f32 anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _acc_dtype(dtype):
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def _fwd_kernel(a_ref, xs_ref, o_ref, *, acc_t):
    # One tile: (2, w) @ (bm, w)^T -> (2, bm) = [y_tile; u_tile].  Rows of
    # the output are independent dots over w, so padded m-rows need no
    # masking — out-of-range rows are dropped on writeback.
    a = a_ref[0]                                   # (bm, w)
    xs = xs_ref[0]                                 # (2, w)
    o_ref[0] = jax.lax.dot_general(
        xs, a, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_t).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def schwarz_fwd(A, x, wdiv, *, block_m: int = 256, interpret: bool = False):
    """A: (p, m, w), x/wdiv: (p, w) -> (y, u) both (p, m) with
    y = A @ (x * wdiv) and u = A @ x, one HBM pass over A."""
    p, m, w = A.shape
    block_m = min(block_m, m)
    nm = pl.cdiv(m, block_m)
    xs = jnp.stack([x * wdiv, x], axis=1)          # (p, 2, w)
    kernel = functools.partial(_fwd_kernel, acc_t=_acc_dtype(A.dtype))
    out = pl.pallas_call(
        kernel,
        grid=(p, nm),
        in_specs=[
            pl.BlockSpec((1, block_m, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 2, w), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2, block_m), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((p, 2, m), A.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(A, xs)
    return out[:, 0], out[:, 1]


def _bwd_kernel(a_ref, r_ref, b_ref, ax_ref, u_ref, x_ref, muov_ref,
                mask_ref, o_ref, acc_ref, *, block_m: int, m_total: int):
    mi = pl.program_id(1)
    nm = pl.num_programs(1)

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]                                   # (bm, w)
    # resid tile lives entirely in VMEM/registers — never written to HBM.
    resid = b_ref[0] - ax_ref[0] + u_ref[0]        # (bm,)
    t = (r_ref[0] * resid).astype(acc_ref.dtype)
    # mask padded rows of the final block (and the A tile, so garbage
    # padding can't poison the product via 0 * inf)
    row = mi * block_m + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_m, 1), 0)
    valid = row < m_total
    t = jnp.where(valid[:, 0], t, 0.0)
    a = jnp.where(valid, a, 0.0).astype(acc_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        t[None, :], a, (((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(mi == nm - 1)
    def _done():
        acc = acc_ref[0] + muov_ref[0].astype(acc_ref.dtype) * \
            x_ref[0].astype(acc_ref.dtype)
        o_ref[0] = (acc * mask_ref[0].astype(acc_ref.dtype)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def schwarz_bwd(A, r, b, Ax, u, x, muov, mask, *, block_m: int = 256,
                interpret: bool = False):
    """A: (p, m, w), r/b/Ax: (m,), u: (p, m), x/muov/mask: (p, w) ->
    rhs: (p, w) = (A^T @ (r * (b - Ax + u)) + muov * x) * mask, one HBM
    pass over A with the resid tiles formed in VMEM."""
    p, m, w = A.shape
    block_m = min(block_m, m)
    nm = pl.cdiv(m, block_m)
    r2, b2, ax2 = r[None], b[None], Ax[None]       # (1, m)
    kernel = functools.partial(_bwd_kernel, block_m=block_m, m_total=m)
    vec_spec = pl.BlockSpec((1, block_m), lambda i, j: (0, j))
    loc_spec = pl.BlockSpec((1, w), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(p, nm),
        in_specs=[
            pl.BlockSpec((1, block_m, w), lambda i, j: (i, j, 0)),
            vec_spec,                              # r
            vec_spec,                              # b
            vec_spec,                              # Ax
            pl.BlockSpec((1, block_m), lambda i, j: (i, j)),  # u
            loc_spec,                              # x
            loc_spec,                              # muov
            loc_spec,                              # mask
        ],
        out_specs=loc_spec,
        out_shape=jax.ShapeDtypeStruct((p, w), A.dtype),
        scratch_shapes=[pltpu.VMEM((1, w), _acc_dtype(A.dtype))],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(A, r2, b2, ax2, u, x, muov, mask)
