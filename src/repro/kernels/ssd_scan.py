"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Head-folded layout: x (BH, S, P), dt (BH, S), A (BH,), B/C (BH, S, N).
Grid is (BH, S/chunk) with the chunk axis sequential; the inter-chunk
ssm state (N, P) lives in VMEM scratch.  Per chunk (all in VMEM):

  intra:  y  = ((C B^T) .* L) (dt .* x)        two MXU matmuls + mask
  inter:  y += (C .* exp(cum)) S_prev          one MXU matmul
  state:  S  = exp(cum_last) S_prev + B^T (dt .* exp(cum_last - cum) .* x)

which is the state-space-duality algorithm with the quadratic part
confined to a (chunk x chunk) tile — sized so chunk, N, P are multiples
of the 128 MXU dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, s_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)          # (C, P)
    dt = dt_ref[0].astype(jnp.float32)        # (C,)
    A = a_ref[0].astype(jnp.float32)          # scalar (per head)
    Bm = b_ref[0].astype(jnp.float32)         # (C, N)
    Cm = c_ref[0].astype(jnp.float32)         # (C, N)

    dA = dt * A                                # (C,)
    cum = jnp.cumsum(dA)                       # (C,)
    last = cum[-1]

    # intra-chunk (dual form)
    diff = cum[:, None] - cum[None, :]         # (C, C)
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(mask, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = CB * L * dt[None, :]              # (C, C)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk from carried state
    s_prev = s_ref[...]                        # (N, P)
    y += jax.lax.dot_general(Cm * jnp.exp(cum)[:, None], s_prev,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update
    w = dt * jnp.exp(last - cum)               # (C,)
    s_new = jnp.exp(last) * s_prev + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = s_new
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256,
             interpret: bool = False):
    """x: (BH,S,P), dt: (BH,S), A: (BH,), B/C: (BH,S,N) -> y: (BH,S,P)."""
    BH, S, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C)
