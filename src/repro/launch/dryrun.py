import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds ShapeDtypeStruct inputs (configs.shapes.input_specs — no
     allocation),
  2. jits the right step (train/prefill/serve) with the production
     in/out_shardings,
  3. ``.lower().compile()`` under the mesh — proving the sharding is
     coherent for 256- and 512-chip topologies,
  4. prints ``compiled.memory_analysis()`` (fits-in-HBM proof) and
     ``cost_analysis()`` (FLOPs/bytes), parses collective bytes from the
     partitioned HLO, and appends the roofline row to
     ``results/dryrun_<mesh>.json``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--multi-pod] \
      [--arch yi-6b] [--shape train_4k] [--skip-done]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs import shapes as shapes_mod
from repro.launch import hlo_analysis, mesh as mesh_mod
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import sharding, steps as steps_mod

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")


def _result_path(multi_pod: bool) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = "dryrun_multipod.json" if multi_pod else "dryrun_singlepod.json"
    return os.path.join(RESULTS_DIR, name)


def _load_results(path):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def _save_results(path, results):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def lower_cell(cfg, shape_name: str, mesh):
    """Lower + compile one cell.  Returns (compiled, lowered, model_flops)."""
    case = shapes_mod.SHAPES[shape_name]
    chips = mesh.devices.size

    with jax.sharding.set_mesh(mesh):
        bshapes = shapes_mod.input_specs(cfg, shape_name)
        if case.kind == "train":
            opt_cfg = adamw.AdamWConfig(accum_steps=cfg.train_accum)
            step = steps_mod.make_train_step(cfg, opt_cfg, mesh=mesh,
                                             donate=True,
                                             batch_shapes=bshapes)
            pshapes = transformer.param_shapes(cfg)
            oshapes = {
                "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                    s.shape, jnp.float32), pshapes),
                "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                    s.shape, jnp.float32), pshapes),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            lowered = step.lower(pshapes, oshapes, bshapes)
            mf = hlo_analysis.model_flops_train(cfg, case.seq_len,
                                                case.global_batch)
        elif case.kind == "prefill":
            step = steps_mod.make_prefill_step(cfg, mesh=mesh,
                                               max_seq=case.seq_len,
                                               batch_shapes=bshapes)
            pshapes = transformer.param_shapes(cfg)
            lowered = step.lower(pshapes, bshapes)
            mf = hlo_analysis.model_flops_train(cfg, case.seq_len,
                                                case.global_batch) / 3.0
        else:  # decode
            cache_shapes = shapes_mod.decode_cache_specs(cfg, shape_name)
            step = steps_mod.make_serve_step(cfg, mesh=mesh,
                                             cache_shapes=cache_shapes)
            pshapes = transformer.param_shapes(cfg)
            lowered = step.lower(pshapes, cache_shapes, bshapes["tokens"],
                                 jax.ShapeDtypeStruct((), jnp.int32))
            mf = hlo_analysis.model_flops_decode(cfg, case.seq_len,
                                                 case.global_batch)
        compiled = lowered.compile()
    return compiled, lowered, mf


def _layer_counts(cfg, n_periods: int):
    """Scale layer counts to n_periods pattern periods (whisper scales the
    encoder in proportion)."""
    period = len(cfg.attn_pattern)
    # train_accum forced to 1: the microbatch scan is a while loop and
    # would re-introduce the body-counted-once undercount; per-step cost
    # terms are accum-invariant (same global batch) — only the phase-1
    # fits-proof keeps the accum.
    over = {"num_layers": n_periods * period, "scan_layers": False,
            "train_accum": 1}
    if cfg.is_encoder_decoder:
        over["encoder_layers"] = n_periods * period
    return cfg.scaled(**over)


def analyze_cell(cfg, shape_name: str, mesh, model_flops: float):
    """Accurate roofline terms via per-layer extrapolation.

    XLA's cost_analysis counts while-loop bodies once (verified — see
    EXPERIMENTS.md §Dry-run methodology), so we compile *unrolled* lowerings
    at 1x and 2x pattern periods and extrapolate each metric linearly in
    the layer count: metric(L) = m(L1) + (m(L2)-m(L1))/(L2-L1) * (L-L1).
    Layers are homogeneous per period, so this is exact up to compiler
    noise; the embedding/loss ends live in the intercept.
    """
    period = len(cfg.attn_pattern)
    L = cfg.num_layers
    chips = mesh.devices.size

    metrics = []
    for n_p in (1, 2):
        c_small = _layer_counts(cfg, n_p)
        compiled, _, _ = lower_cell(c_small, shape_name, mesh)
        hlo = compiled.as_text()
        cost = compiled.cost_analysis()
        coll = hlo_analysis.collective_bytes(hlo)
        metrics.append({
            "L": c_small.num_layers,
            # cost_analysis is per-device on SPMD modules -> scale global
            "flops": float(cost.get("flops", 0.0)) * chips,
            "bytes": float(cost.get("bytes accessed", 0.0)) * chips,
            "coll": coll.per_device_bytes,
            "counts": coll.counts,
        })
    m1, m2 = metrics
    dL = m2["L"] - m1["L"]

    def extrap(key):
        slope = (m2[key] - m1[key]) / dL
        return max(m1[key] + slope * (L - m1["L"]), 0.0)

    flops = extrap("flops")
    hbm = extrap("bytes")
    coll_b = extrap("coll")
    roof = hlo_analysis.Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes_per_device=coll_b,
        chips=chips,
        compute_s=flops / (chips * hlo_analysis.PEAK_FLOPS),
        memory_s=hbm / (chips * hlo_analysis.HBM_BW),
        collective_s=coll_b / hlo_analysis.LINK_BW,
        model_flops=model_flops, counts=m2["counts"])
    return roof


def run_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
             verbose: bool = True, analysis: bool = True):
    cfg = configs.get_config(arch)
    ok, reason = shapes_mod.cell_supported(cfg, shape_name)
    if not ok:
        return {"status": "skipped", "reason": reason}
    t0 = time.time()
    # Phase 1: full-depth scan-mode compile — the fits-in-HBM proof and the
    # proof that the sharding config is coherent at this topology.
    compiled, lowered, model_flops = lower_cell(cfg, shape_name, mesh)
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    chips = mesh.devices.size
    # Phase 2: unrolled per-layer extrapolation for accurate FLOPs/bytes/
    # collective terms (single-pod only; multi-pod reuses phase-1 HLO for
    # the collective schedule proof).
    if analysis:
        roof = analyze_cell(cfg, shape_name, mesh, model_flops)
    else:
        roof = hlo_analysis.analyze(compiled, hlo, chips, model_flops)

    row = {
        "status": "ok",
        "arch": arch, "shape": shape_name, "chips": chips,
        "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes),
        },
        **roof.to_dict(),
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {chips} chips "
              f"(compile {compile_s:.0f}s)")
        print(f"   memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB "
              f"alias={mem.alias_size_in_bytes/2**30:.2f}GiB")
        print(f"   cost_analysis: flops={roof.flops:.3e} "
              f"bytes={roof.hbm_bytes:.3e} "
              f"coll/dev={roof.coll_bytes_per_device:.3e} {roof.counts}")
        print(f"   terms: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"-> {roof.dominant}-bound; useful={roof.useful_flops_frac:.2f} "
              f"roofline={roof.roofline_frac:.2f}")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default=None, help="single arch id")
    ap.add_argument("--shape", default=None, help="single shape name")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--no-analysis", action="store_true",
                    help="skip the unrolled per-layer cost extrapolation "
                         "(multi-pod pass: compile proof only)")
    args = ap.parse_args()

    mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} devices, backend="
          f"{jax.default_backend()})")

    path = _result_path(args.multi_pod)
    results = _load_results(path)

    if args.arch:
        archs = [args.arch]
    else:
        # one canonical dash-form id per architecture (no alias dupes):
        # prefer dotted ids, break ties by length (most specific)
        seen = {}
        for aid, mod in sorted(configs.ARCH_IDS.items()):
            if "-" not in aid:
                continue
            cur = seen.get(mod)
            if cur is None or ("." in aid, len(aid)) > ("." in cur,
                                                        len(cur)):
                seen[mod] = aid
        archs = sorted(seen.values())
    shapes = [args.shape] if args.shape else list(shapes_mod.SHAPES)

    failures = []
    for arch in archs:
        for shape_name in shapes:
            key = f"{arch}|{shape_name}"
            if args.skip_done and key in results and \
                    results[key].get("status") in ("ok", "skipped"):
                continue
            try:
                row = run_cell(arch, shape_name, mesh, args.multi_pod,
                               analysis=not args.no_analysis)
            except Exception as e:
                traceback.print_exc()
                row = {"status": "fail", "error": f"{type(e).__name__}: {e}"}
                failures.append(key)
            results[key] = row
            _save_results(path, results)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, "
          f"{len(failures)} failed -> {path}")
    if failures:
        print("FAILED:", failures)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
