"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOPs)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

cost_analysis() reports *global* FLOPs/bytes (summed over partitions) for a
SPMD module; collective bytes are NOT in cost_analysis, so we parse the
partitioned HLO: after GSPMD, shapes are per-device, so summing the result
bytes of every collective op gives per-device wire bytes
(collective_bytes := per_device_bytes * chips, making the term
per_device_bytes / link_bw).  all-reduce is counted twice (ring =
reduce-scatter + all-gather at full payload); reduce-scatter at group_size x
result (the payload that transits); all-gather/all-to-all/collective-permute
at result size.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_REPLICA_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _REPLICA_GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    per_device_bytes: float
    counts: dict
    bytes_by_kind: dict


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device wire bytes from a partitioned HLO module."""
    counts: dict = {}
    by_kind: dict = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        g = _group_size(line)
        if op == "all-reduce":
            wire = 2.0 * b * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = b * (g - 1)           # input = b*g, transits (g-1)/g of it
        elif op == "all-gather":
            wire = b * (g - 1) / max(g, 1)
        else:                            # all-to-all, collective-permute
            wire = b
        counts[op] = counts.get(op, 0) + 1
        by_kind[op] = by_kind.get(op, 0.0) + wire
        total += wire
    return CollectiveStats(per_device_bytes=total, counts=counts,
                           bytes_by_kind=by_kind)


@dataclasses.dataclass
class Roofline:
    flops: float                  # global HLO FLOPs
    hbm_bytes: float              # global HLO bytes accessed
    coll_bytes_per_device: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float            # 6*N*D (or 6*N_active*D)
    counts: dict

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the compute roofline realized if the dominant term
        were fully overlapped: ideal_compute_time / bound_time."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_s if self.bound_s else 0.0

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "chips": self.chips, "compute_s": self.compute_s,
            "memory_s": self.memory_s, "collective_s": self.collective_s,
            "model_flops": self.model_flops, "dominant": self.dominant,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac, "counts": self.counts,
        }


def analyze(compiled, hlo_text: str, chips: int,
            model_flops: float) -> Roofline:
    """NOTE: XLA cost_analysis on a GSPMD-partitioned module reports
    PER-DEVICE flops/bytes (verified against a hand-counted sharded matmul
    — EXPERIMENTS.md §Dry-run methodology); we scale to global here."""
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0)) * chips
    hbm = float(cost.get("bytes accessed", 0.0)) * chips
    coll = collective_bytes(hlo_text)
    return Roofline(
        flops=flops, hbm_bytes=hbm,
        coll_bytes_per_device=coll.per_device_bytes, chips=chips,
        compute_s=flops / (chips * PEAK_FLOPS),
        memory_s=hbm / (chips * HBM_BW),
        collective_s=coll.per_device_bytes / LINK_BW,
        model_flops=model_flops, counts=coll.counts)


def model_flops_train(cfg, seq: int, global_batch: int) -> float:
    """6*N*D with N = active params (MoE: routed experts only)."""
    n_active = cfg.param_count(active_only=True)
    return 6.0 * n_active * seq * global_batch


def model_flops_decode(cfg, cache_len: int, global_batch: int) -> float:
    """One token: 2*N_active matmul FLOPs + attention reads over the cache."""
    n_active = cfg.param_count(active_only=True)
    flops = 2.0 * n_active * global_batch
    # attention over the cache (per global/local layer)
    for i in range(cfg.num_layers):
        t = cfg.layer_type(i)
        if t in ("global", "local"):
            span = cache_len if t == "global" else min(cfg.window, cache_len)
            flops += (4.0 * global_batch * cfg.num_heads * cfg.head_dim
                      * span)
    return flops
