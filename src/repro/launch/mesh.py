"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
before first jax init, tests and benches see the single real device.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: 'data' (DP + FSDP), 'model' (TP/EP); 'pod' is pure DP across pods
    (gradient all-reduce only crosses pods — DESIGN.md §8).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_test_mesh(shape, axes):
    """Arbitrary mesh over however many devices exist (tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))
