"""Serving driver: batched prefill + decode with static-shape KV caches.

Requests arrive with different prompt lengths; prompts are left-padded
into the prefill batch, decode proceeds lock-step with per-row stop
handling.  On TPU the same loop runs under the production mesh with the
cache shardings from ``runtime.steps.make_serve_step`` (kv-head TP or
cache sequence sharding).

Queueing is delegated to the shared continuous-batching
:class:`~repro.runtime.scheduler.SlotScheduler` (the same table the
assimilation fleet runs on): ``serve_queue`` admits up to ``slots``
requests per wave, runs the wave to completion with ``serve_batch``,
retires every slot and admits the next wave — so an open-ended request
stream runs under a bounded decode batch.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 32 --max-new 16 [--slots 2]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer
from repro.runtime import steps as steps_mod
from repro.runtime.scheduler import SlotScheduler


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (len,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)


def serve_batch(cfg, params, requests, *, max_seq: int, greedy: bool = True,
                seed: int = 0, mesh=None):
    """Run a batch of requests to completion.  Returns the requests with
    ``out`` filled, plus timing stats."""
    B = len(requests)
    S = max(len(r.prompt) for r in requests)
    # right-align prompts (left padding) so decode positions line up
    toks = np.zeros((B, S), np.int32)
    for i, r in enumerate(requests):
        toks[i, S - len(r.prompt):] = r.prompt
    batch = {"tokens": jnp.asarray(toks)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.zeros((B, cfg.num_patches, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    P_off = cfg.num_patches if cfg.frontend == "vision_stub" else 0

    t0 = time.perf_counter()
    prefill = steps_mod.make_prefill_step(cfg, max_seq=max_seq + P_off)
    logits, cache = prefill(params, batch)
    prefill_s = time.perf_counter() - t0

    serve = steps_mod.make_serve_step(cfg, donate=False)
    key = jax.random.PRNGKey(seed)
    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    max_new = max(r.max_new for r in requests)
    t1 = time.perf_counter()
    for step in range(max_new):
        for i, r in enumerate(requests):
            if step < r.max_new:
                r.out.append(int(cur[i, 0]))
        logits, cache = serve(params, cache, cur,
                              jnp.asarray(P_off + S + step, jnp.int32))
        if greedy:
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits[:, 0, :])[:, None]
            cur = cur.astype(jnp.int32)
    decode_s = time.perf_counter() - t1
    stats = {
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "tokens_per_s": B * max_new / decode_s if decode_s else 0.0,
    }
    return requests, stats


def serve_queue(cfg, params, requests, *, slots: int, max_seq: int,
                greedy: bool = True, seed: int = 0, mesh=None):
    """Run an unbounded request list through a bounded decode batch.

    Requests are parked on a :class:`SlotScheduler` of ``slots`` slots
    and served in FIFO waves: admit up to ``slots``, run the wave with
    :func:`serve_batch`, retire, repeat until the queue drains.  Returns
    the completed requests (arrival order) and aggregate stats.
    """
    sched = SlotScheduler(capacity=slots, meters_prefix="serve.")
    for r in requests:
        sched.submit(r)
    done = []
    waves = 0
    agg = {"prefill_s": 0.0, "decode_s": 0.0}
    while not sched.idle():
        wave = sched.admit()
        batch = [r for _, r in wave]
        batch, stats = serve_batch(cfg, params, batch, max_seq=max_seq,
                                   greedy=greedy, seed=seed + waves,
                                   mesh=mesh)
        for slot, _ in wave:
            sched.retire(slot)
        done.extend(batch)
        agg["prefill_s"] += stats["prefill_s"]
        agg["decode_s"] += stats["decode_s"]
        waves += 1
    total_new = sum(len(r.out) for r in done)
    agg["waves"] = waves
    agg["tokens_per_s"] = (total_new / agg["decode_s"]
                           if agg["decode_s"] else 0.0)
    return done, agg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=0,
                    help="decode-batch slot count (0 = one wave of "
                         "--batch requests, no queueing)")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        rng.integers(4, args.prompt_len),
                                        dtype=np.int64).astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.batch)]
    if args.slots > 0:
        reqs, stats = serve_queue(cfg, params, reqs, slots=args.slots,
                                  max_seq=args.prompt_len + args.max_new)
    else:
        reqs, stats = serve_batch(cfg, params, reqs,
                                  max_seq=args.prompt_len + args.max_new)
    for r in reqs:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out[:8]}...")
    print(f"prefill {stats['prefill_s']:.3f}s decode {stats['decode_s']:.3f}s "
          f"({stats['tokens_per_s']:.1f} tok/s)")


if __name__ == "__main__":
    main()
