"""Production training driver.

Wires every substrate together: config -> mesh -> DyDD-balanced data loader
-> pjit train step -> straggler monitor -> async fault-tolerant checkpoints
with auto-resume.  On this CPU container it runs the reduced (smoke)
configs end-to-end (examples/train_lm.py drives it); on a TPU pod the same
entry point runs the full configs (mesh from launch.mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
      --steps 100 --seq 128 --batch 8 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import manager as ckpt_mod
from repro.data import pipeline
from repro.models import transformer
from repro.optim import AdamWConfig, adamw_init, make_schedule
from repro.runtime import steps as steps_mod
from repro.runtime.straggler import StragglerMonitor


def train(cfg, *, steps: int, seq: int, global_batch: int, dp: int,
          ckpt_dir: str | None, ckpt_every: int = 50, lr: float = 3e-4,
          seed: int = 0, log_every: int = 10, mesh=None):
    opt_cfg = AdamWConfig(lr=lr)
    schedule = make_schedule("cosine", lr, warmup_steps=max(steps // 20, 1),
                             total_steps=steps)
    step_fn = steps_mod.make_train_step(cfg, opt_cfg, lr_schedule=schedule,
                                        mesh=mesh, donate=False)

    loader = pipeline.BalancedLoader(
        vocab_size=cfg.vocab_size, dp=dp,
        batch_per_shard=global_batch // dp, seq=seq, seed=seed)

    params = transformer.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params)
    start_step = 0

    mgr = None
    if ckpt_dir:
        mgr = ckpt_mod.CheckpointManager(ckpt_dir, keep=3)
        restored = mgr.restore_latest(
            like={"params": params, "opt": opt})
        if restored is not None:
            tree, manifest = restored
            params, opt = tree["params"], tree["opt"]
            loader.load_state_dict(manifest["metadata"]["loader"])
            start_step = manifest["step"]
            print(f"resumed from step {start_step}")

    monitor = StragglerMonitor()
    losses = []
    for s in range(start_step, steps):
        t, l, m = loader.next_batch()
        batch = {"tokens": jnp.asarray(t), "labels": jnp.asarray(l),
                 "mask": jnp.asarray(m)}
        t0 = time.perf_counter()
        loss, params, opt = step_fn(params, opt, batch)
        loss = float(loss)
        monitor.record(time.perf_counter() - t0)
        losses.append(loss)
        if s % log_every == 0 or s == steps - 1:
            st = loader.last_stats
            print(f"step {s:5d} loss {loss:8.4f} "
                  f"balance E {st.efficiency_before:.3f}->"
                  f"{st.efficiency_after:.3f} moved {st.docs_moved}")
        if mgr and (s + 1) % ckpt_every == 0:
            mgr.save({"params": params, "opt": opt}, step=s + 1,
                     metadata={"loader": loader.state_dict()},
                     blocking=False)
    if mgr:
        mgr.save({"params": params, "opt": opt}, step=steps,
                 metadata={"loader": loader.state_dict()}, blocking=True)
        mgr.wait()
        mgr.close()
    return params, opt, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--dp", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    _, _, losses = train(cfg, steps=args.steps, seq=args.seq,
                         global_batch=args.batch, dp=args.dp,
                         ckpt_dir=args.ckpt_dir, lr=args.lr,
                         seed=args.seed)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
