"""Model stack: configs, layers and the flexible transformer."""
from repro.models import attention, config, moe, nn, rglru, ssd, transformer  # noqa: F401
from repro.models.config import ModelConfig  # noqa: F401
