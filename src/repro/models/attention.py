"""Attention: MHA/GQA/MQA, global + sliding-window, KV caches for decode.

Training/prefill uses a blocked jnp implementation (the Pallas flash kernel
in ``repro.kernels`` is numerically validated against the same reference and
swaps in on real TPU backends via ``repro.kernels.ops``).  Decode uses a
static-shape KV cache; sliding-window layers use a ring buffer of exactly
``window`` slots so long-context decode state stays O(window), which is what
makes the ``long_500k`` shape feasible for local/hybrid archs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ModelConfig
from repro.runtime import sharding

NEG_INF = -1e30


def make_attn_params(b: nn.Builder, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": b.param((d, h, hd), ("embed", "heads", None)),
        "wk": b.param((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": b.param((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": b.param((h, hd, d), ("heads", None, "embed")),
    }


def _expand_kv(k, q_per_kv):
    """(B,S,KV,D) -> (B,S,KV*q_per_kv,D) by repeat (GQA)."""
    if q_per_kv == 1:
        return k
    return jnp.repeat(k, q_per_kv, axis=2)


def _mask(seq_q: int, seq_k: int, window, causal: bool,
          q_offset: int = 0):
    """(Sq, Sk) additive mask.  ``window`` may be a traced int; <= 0 means
    unbounded (global attention)."""
    qpos = jnp.arange(seq_q)[:, None] + q_offset
    kpos = jnp.arange(seq_k)[None, :]
    ok = jnp.ones((seq_q, seq_k), bool)
    if causal:
        ok &= kpos <= qpos
    window = jnp.asarray(window)
    ok &= (kpos > qpos - window) | (window <= 0)
    return jnp.where(ok, 0.0, NEG_INF)


def attention(cfg: ModelConfig, params, x, positions, *, window: int,
              causal: bool = True, rope_theta: float | None = None,
              kv_override=None):
    """Training/prefill attention.  x: (B,S,D) -> (B,S,D).

    kv_override: (k, v) from an encoder (cross-attention); disables rope on
    kv and causal masking.

    When cfg.attn_q_chunk > 0 and the sequence is long, the scores are
    computed in q-chunks (and, for sliding-window layers, against a sliced
    k-band) — the jnp twin of the flash kernel's blocking that keeps the
    temp footprint to O(chunk x S) instead of O(S^2).  Numerics are
    identical (full-precision softmax over all visible keys).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q = sharding.shard(q, "batch", "seq", "heads", None)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
        theta = rope_theta if rope_theta is not None else cfg.rope_theta
        # static 0 disables rope (whisper: learned positions); traced
        # per-layer thetas are always > 0.
        if not (isinstance(theta, (int, float)) and theta <= 0):
            q = nn.rope(q, positions, theta)
            k = nn.rope(k, positions, theta)
    else:
        k, v = kv_override
        causal = False
        window = 0
    k = sharding.shard(k, "batch", "seq", "kv_heads", None)
    v = sharding.shard(v, "batch", "seq", "kv_heads", None)

    k = _expand_kv(k, cfg.q_per_kv)
    v = _expand_kv(v, cfg.q_per_kv)

    cq = cfg.attn_q_chunk
    if cq and S > cq and S % cq == 0 and isinstance(window, int):
        out = _chunked_attention(cfg, q, k, v, window=window, causal=causal,
                                 q_chunk=cq)
    else:
        out = _full_attention(cfg, q, k, v, window=window, causal=causal)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return sharding.shard(out, "batch", "seq", "embed")


def _full_attention(cfg, q, k, v, *, window, causal):
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32) * scale
    if cfg.attn_softcap > 0:
        scores = nn.softcap(scores, cfg.attn_softcap)
    scores = scores + _mask(q.shape[1], k.shape[1], window, causal)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshk->bqhk", probs, v)


def _chunked_attention(cfg, q, k, v, *, window: int, causal: bool,
                       q_chunk: int):
    """Blocked attention over q-chunks; local layers slice a k-band.

    Assumes positions are 0..S-1 (true for every trunk call; decode uses
    ``decode_attention``).  Exact — not an approximation.
    """
    B, S, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(cfg.head_dim)

    band = bool(window and window > 0 and causal and Sk == S)
    if band:
        # PERF-B1 (refuted, EXPERIMENTS.md §Perf): narrowing q-chunks to
        # 128 shrinks the score tile S x (cq + window) but re-reads the
        # overlapping k-band nq times — measured net +10% HBM bytes, so
        # the chunk stays at cfg.attn_q_chunk.
        klen = min(Sk, q_chunk + ((window + 127) // 128) * 128)
    else:
        klen = Sk
    nq = S // q_chunk

    def one_chunk(ci):
        z = jnp.zeros((), jnp.int32)
        qs = jnp.asarray(ci * q_chunk, jnp.int32)
        qc = jax.lax.dynamic_slice(q, (z, qs, z, z), (B, q_chunk, H, hd))
        if band and klen < Sk:
            ks = jnp.clip(qs + q_chunk - klen, 0, Sk - klen)
            ks = jnp.asarray(ks, jnp.int32)
            kc = jax.lax.dynamic_slice(k, (z, ks, z, z), (B, klen, H, hd))
            vc = jax.lax.dynamic_slice(v, (z, ks, z, z), (B, klen, H, hd))
            kpos = ks + jnp.arange(klen)
        else:
            kc, vc = k, v
            kpos = jnp.arange(klen)
        qpos = qs + jnp.arange(q_chunk)
        scores = jnp.einsum("bqhk,bshk->bhqs", qc,
                            kc).astype(jnp.float32) * scale
        if cfg.attn_softcap > 0:
            scores = nn.softcap(scores, cfg.attn_softcap)
        ok = jnp.ones((q_chunk, klen), bool)
        if causal:
            ok &= kpos[None, :] <= qpos[:, None]
        if window and window > 0:
            ok &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(ok[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(vc.dtype)
        return jnp.einsum("bhqs,bshk->bqhk", probs, vc)

    if cfg.scan_layers:
        # checkpoint per chunk: the backward recomputes one chunk's scores
        # at a time, so peak temp is O(chunk x klen) not O(S x S).
        chunks = jax.lax.map(jax.checkpoint(one_chunk),
                             jnp.arange(nq))                # (nq,B,cq,H,hd)
    else:
        chunks = jnp.stack([one_chunk(jnp.asarray(ci))
                            for ci in range(nq)])
    return jnp.moveaxis(chunks, 0, 1).reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# Decode caches.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Static description of one layer's KV cache."""

    kind: str          # "full" | "ring"
    length: int        # cache slots (= seq for full, = window for ring)


def cache_spec(cfg: ModelConfig, layer_type: str, max_seq: int) -> CacheSpec:
    if layer_type == "local":
        return CacheSpec(kind="ring", length=min(cfg.window, max_seq))
    return CacheSpec(kind="full", length=max_seq)


def init_cache(cfg: ModelConfig, spec: CacheSpec, batch: int, dtype):
    L = spec.length
    return {
        "k": jnp.zeros((batch, L, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, L, cfg.num_kv_heads, cfg.head_dim), dtype),
        # absolute position stored in each slot (-1 = empty)
        "pos": jnp.full((L,), -1, jnp.int32),
    }


def decode_attention(cfg: ModelConfig, params, cache, spec: CacheSpec, x,
                     pos, *, window: int, rope_theta: float | None = None):
    """Single-token decode.  x: (B,1,D); pos: scalar int32 absolute position.

    Returns (out (B,1,D), new_cache).  The cache slot is ``pos % length``
    (ring) or ``pos`` (full); masking uses the per-slot absolute positions,
    so RoPE-at-write stays correct after wraparound.
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    positions = pos[None, None] * jnp.ones((B, 1), jnp.int32)
    if not (isinstance(theta, (int, float)) and theta <= 0):
        q = nn.rope(q, positions, theta)
        k = nn.rope(k, positions, theta)

    slot = (pos % spec.length if spec.kind == "ring" else pos)
    slot = jnp.asarray(slot, jnp.int32)
    z = jnp.zeros((), jnp.int32)
    new_k = jax.lax.dynamic_update_slice(cache["k"], k, (z, slot, z, z))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v, (z, slot, z, z))
    new_pos = jax.lax.dynamic_update_slice(
        cache["pos"], pos[None].astype(jnp.int32), (slot,))

    kk = _expand_kv(new_k, cfg.q_per_kv)
    vv = _expand_kv(new_v, cfg.q_per_kv)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    scores = jnp.einsum("bqhk,bshk->bhqs", q, kk).astype(jnp.float32) * scale
    if cfg.attn_softcap > 0:
        scores = nn.softcap(scores, cfg.attn_softcap)
    valid = (new_pos >= 0) & (new_pos <= pos)
    window = jnp.asarray(window)
    valid &= (new_pos > pos - window) | (window <= 0)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", probs, vv)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, {"k": new_k, "v": new_v, "pos": new_pos}


def prefill_cache(cfg: ModelConfig, spec: CacheSpec, k, v, positions):
    """Build a cache from prefill-computed k/v.  k/v: (B,S,KV,D) with rope
    already applied; positions: (S,)."""
    B, S = k.shape[0], k.shape[1]
    L = spec.length
    if S <= L:
        pad = L - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.pad(positions.astype(jnp.int32), (0, pad),
                      constant_values=-1)
    else:  # keep the last L (ring semantics)
        k, v = k[:, -L:], v[:, -L:]
        pos = positions[-L:].astype(jnp.int32)
    return {"k": k, "v": v, "pos": pos}
