"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single flexible decoder / encoder-decoder LM configuration.

    ``attn_pattern`` is cycled over layers; entries:
      "global" — full (causal) attention,
      "local"  — sliding-window attention (``window``),
      "rglru"  — RG-LRU recurrent block (recurrentgemma),
      "ssd"    — Mamba-2 state-space duality block.
    """

    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Activation / MLP.
    act: str = "silu"                # silu | gelu
    gated_mlp: bool = True           # SwiGLU / GeGLU when True

    # Attention pattern.
    attn_pattern: tuple = ("global",)
    window: int = 4096
    rope_theta: float = 10000.0
    logits_softcap: float = 0.0
    attn_softcap: float = 0.0
    scale_embeddings: bool = False   # gemma-style sqrt(d_model) scaling

    # Mixture of Experts.
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_dydd_balance: bool = True    # paper's technique as expert balancer
    moe_ep: bool = False             # expert parallelism (experts sharded
                                     # over 'model'); else d_ff TP
    moe_virtual_experts: int = 1     # split each expert into v half-width
                                     # shards so E*v divides the model axis
                                     # (mixtral: 8 experts x 2 = 16)

    # SSM (mamba2).
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256

    # RG-LRU (recurrentgemma).
    lru_width: int = 0

    # Encoder-decoder (whisper) / modality stubs.
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 0             # fixed frame count (whisper: 1500)
    frontend: str = "none"           # none | audio_stub | vision_stub
    num_patches: int = 0             # vlm stub patch count

    # Norms / embeddings.
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = True

    # Parallelism / memory hints (consumed by runtime/).
    fsdp: bool = True
    remat: str = "block"             # none | block | group
    remat_group: int = 8             # layers-per-residual for remat="group"
    dtype: str = "bfloat16"
    loss_chunk: int = 0              # sequence-chunked loss (0 = off)
    train_accum: int = 1             # gradient-accumulation microbatches
    attn_q_chunk: int = 0            # blocked attention q-chunk (0 = full)
    scan_layers: bool = True         # False: unroll (dry-run cost analysis)
    sharding_profile: str = "tp"     # "tp" (FSDP+TP) | "dp" (pure DP+FSDP)

    def layer_type(self, i: int) -> str:
        return self.attn_pattern[i % len(self.attn_pattern)]

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return all(t in ("rglru", "ssd") for t in self.attn_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer keeps an unbounded full-length KV cache."""
        return all(t != "global" for t in self.attn_pattern)

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy (smoke tests)."""
        return dataclasses.replace(self, **overrides)

    # ---- parameter counting (used for roofline MODEL_FLOPS) -------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        n = 0
        layers = self.num_layers

        def attn_params():
            return d * h * hd + 2 * d * kv * hd + h * hd * d

        def mlp_params(ff):
            return d * ff * (3 if self.gated_mlp else 2)

        for i in range(layers):
            t = self.layer_type(i)
            if t in ("global", "local"):
                n += attn_params()
            elif t == "rglru":
                w = self.lru_width or d
                # in/out proj (x and gate branches) + gates + conv-ish mixing
                n += 2 * d * w + w * d + 3 * w
            elif t == "ssd":
                di = self.ssm_expand * d
                ng, st = self.ssm_ngroups, self.ssm_state
                n += d * (2 * di + 2 * ng * st + di // self.ssm_headdim)
                n += di * d + self.ssm_conv * (di + 2 * ng * st)
            if self.num_experts > 0:
                e = self.num_experts
                k = self.experts_per_token
                per = mlp_params(f)
                n += d * e + (k if active_only else e) * per
            elif f > 0:
                n += mlp_params(f)
            n += 2 * d  # norms
        if self.is_encoder_decoder:
            # encoder blocks (global attn + mlp) + cross-attn in decoder
            n += self.encoder_layers * (attn_params() + mlp_params(f) + 2 * d)
            n += layers * attn_params()  # cross attention
        n += v * d  # embeddings (tied head)
        if not self.tie_embeddings:
            n += v * d
        return n
