"""Mixture-of-Experts with the paper's DyDD balancer as the token router.

Standard top-k MoE with static per-expert capacity drops tokens whenever the
router's load is skewed — exactly the "observations non-uniformly
distributed" problem DyDD solves.  The mapping (DESIGN.md §4):

  * sorted (expert-major) token order  <->  the 1D domain,
  * per-expert chunk boundaries        <->  subdomain boundaries,
  * routed-token counts                <->  observation loads l_i,
  * expert ring (EP placement order)   <->  the processor graph G.

Balancing = DyDD's scheduling step (``schedule_jnp`` with the precomputed
ring-Laplacian pseudo-inverse) computes target counts; the migration step is
realized by re-chunking the expert-major sorted order at the new boundaries
— movement is *adjacent-expert only* by construction, the jnp analogue of
``dydd.migrate_1d``.  Tokens that migrate are re-weighted by their router
probability for the receiving expert, so the estimator stays consistent.

All shapes are static: dispatch uses argsort + capacity-bounded one-hot
scatter; expert FFN weights are TP-sharded on d_ff (see runtime/sharding).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dydd
from repro.models import nn
from repro.models.config import ModelConfig
from repro.runtime import sharding


def make_moe_params(b: nn.Builder, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    v = cfg.moe_virtual_experts if cfg.moe_ep else 1
    ev, fv = e * v, f // v
    if cfg.moe_ep:
        # expert parallelism: whole (virtual) experts sharded over 'model'
        # (PERF-A2/C1).  When e < model-axis width, each expert is split
        # into v half-width shards ("virtual experts") so e*v divides the
        # axis — partial d_ff sums are added at combine time.
        ax_up = ("moe_expert", "embed", None)
        ax_dn = ("moe_expert", None, "embed")
    else:
        # d_ff tensor parallelism (experts replicated over 'model')
        ax_up = ("expert", "embed", "ff")
        ax_dn = ("expert", "ff", "embed")
    return {
        "router": b.param((d, e), ("embed", "expert")),
        "w_up": b.param((ev, d, fv), ax_up),
        "w_gate": b.param((ev, d, fv), ax_up),
        "w_down": b.param((ev, fv, d), ax_dn),
    }


def _ring_operators(e: int):
    """Precomputed (pinvL, incidence) for the expert ring graph."""
    topo_edges = dydd.ring_edges(e)
    L = dydd.laplacian(e, topo_edges)
    pinvL = np.linalg.pinv(L)
    inc = dydd.incidence_matrix(e, topo_edges)
    return jnp.asarray(pinvL), jnp.asarray(inc), topo_edges


def dydd_target_counts(counts, pinvL, incidence, capacity):
    """DyDD scheduling step on the expert ring (paper Table 13, on-device).

    counts: (E,) routed-token counts.  Returns (E,) target counts: loads
    after applying the per-edge migrations delta = round(inc @ pinv(L) @ b),
    clamped to [0, capacity].
    """
    deltas = dydd.schedule_jnp(counts.astype(jnp.float32), pinvL, incidence)
    new = counts.astype(jnp.float32) - incidence.T @ deltas
    new = jnp.clip(new, 0.0, capacity)
    return jnp.round(new).astype(jnp.int32)


def apply_moe(cfg: ModelConfig, params, x):
    """x: (B,S,D) -> (B,S,D).  vmapped over batch rows."""
    e, k = cfg.num_experts, cfg.experts_per_token
    S = x.shape[1]
    capacity = int(np.ceil(S * k / e * cfg.capacity_factor))
    capacity = max(8, min(capacity, S))
    pinvL, inc, _ = _ring_operators(e)

    def one_row(xr):  # xr: (S, D)
        logits = xr @ params["router"]                       # (S, E)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)               # (S, k)
        flat_e = top_e.reshape(-1)                           # (S*k,)
        flat_p = top_p.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(S), k)

        # ----- DyDD scheduling: counts -> balanced target counts --------
        counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=0)
        if cfg.moe_dydd_balance:
            target = dydd_target_counts(counts, pinvL, inc, capacity)
        else:
            target = jnp.minimum(counts, capacity)

        # ----- migration: expert-major sort, re-chunk at new boundaries -
        # sort by (expert asc, prob desc): low-confidence tokens sit at
        # chunk edges and are the ones that migrate to the adjacent expert.
        # stop_gradient: the ORDER is a discrete routing decision; gradients
        # flow through the gate values only (also works around a jaxlib
        # batched-gather-VJP limitation in this container).
        order = jnp.argsort(jax.lax.stop_gradient(
            flat_e.astype(jnp.float32) - flat_p * 0.5))
        sorted_tok = flat_tok[order]
        starts = jnp.cumsum(target) - target                 # (E,)
        ranks = jnp.arange(S * k)
        # assigned expert after migration = which chunk the rank falls in
        new_e = jnp.searchsorted(jnp.cumsum(target), ranks, side="right")
        new_e = jnp.minimum(new_e, e - 1)
        pos_in_e = ranks - starts[new_e]
        valid = pos_in_e < capacity
        # ranks beyond sum(target) are dropped
        valid &= ranks < jnp.sum(target)

        # combine weight = router prob of the *receiving* expert
        gate = probs[sorted_tok, new_e]
        gate = jnp.where(valid, gate, 0.0)

        # ----- dispatch: scatter tokens into (E, C, D) ------------------
        slot = jnp.where(valid, new_e * capacity + pos_in_e, e * capacity)
        disp = jnp.zeros((e * capacity + 1, xr.shape[-1]), xr.dtype)
        disp = disp.at[slot].add(xr[sorted_tok])
        disp = disp[:-1].reshape(e, capacity, xr.shape[-1])
        return disp, (sorted_tok, slot, gate)

    disp, aux = jax.vmap(one_row)(x)
    exp_axis = "moe_expert" if cfg.moe_ep else "expert"
    v = cfg.moe_virtual_experts if cfg.moe_ep else 1
    if v > 1:
        # duplicate dispatch rows onto each expert's v virtual shards
        disp = jnp.repeat(disp, v, axis=1)        # (B, E*v, C, D)
    disp = sharding.shard(disp, "batch", exp_axis, None, "embed")

    # ----- expert FFN (EP: local full-width matmuls; TP: d_ff sharded) --
    act_fn = jax.nn.silu if cfg.act == "silu" else (
        lambda u: jax.nn.gelu(u, approximate=True))
    up = jnp.einsum("becd,edf->becf", disp, params["w_up"])
    gate_h = act_fn(jnp.einsum("becd,edf->becf", disp, params["w_gate"]))
    up = sharding.shard(up, "batch", exp_axis, None,
                        None if cfg.moe_ep else "ff")
    h = gate_h * up
    out_e = jnp.einsum("becf,efd->becd", h, params["w_down"])
    if v > 1:
        # partial d_ff sums from the v virtual shards add up
        B_, EV, C_, D_ = out_e.shape
        out_e = out_e.reshape(B_, EV // v, v, C_, D_).sum(axis=2)
    out_e = sharding.shard(out_e, "batch", exp_axis, None, "embed")

    # ----- combine: gather back with gate weights ------------------------
    def combine_row(out_r, aux_r, S_, D_):
        sorted_tok, slot, gate = aux_r
        flat = jnp.concatenate(
            [out_r.reshape(-1, D_), jnp.zeros((1, D_), out_r.dtype)], axis=0)
        contrib = flat[jnp.minimum(slot, flat.shape[0] - 1)] \
            * gate[:, None].astype(out_r.dtype)
        y = jnp.zeros((S_, D_), out_r.dtype)
        return y.at[sorted_tok].add(contrib)

    S_, D_ = x.shape[1], x.shape[2]
    y = jax.vmap(lambda o, a: combine_row(o, a, S_, D_))(out_e, aux)
    return sharding.shard(y, "batch", "seq", "embed")


def load_balance_stats(cfg: ModelConfig, params, x):
    """Diagnostics: per-expert counts before/after DyDD and the paper's
    balance ratio E = min/max (used by tests and the MoE benchmark)."""
    e, k = cfg.num_experts, cfg.experts_per_token
    S = x.shape[1]
    capacity = int(np.ceil(S * k / e * cfg.capacity_factor))
    capacity = max(8, min(capacity, S))
    pinvL, inc, _ = _ring_operators(e)
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, top_e = jax.lax.top_k(probs, k)
    counts = jnp.sum(jax.nn.one_hot(top_e.reshape(x.shape[0], -1), e,
                                    dtype=jnp.int32), axis=(0, 1))
    per_row = counts.astype(jnp.int32) // x.shape[0]
    if cfg.moe_dydd_balance:
        target = dydd_target_counts(per_row, pinvL, inc, capacity)
    else:
        target = jnp.minimum(per_row, capacity)
    return counts, target
