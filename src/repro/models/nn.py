"""Parameter builder and basic neural-net primitives (pure JAX, no flax).

Every parameter is declared once through ``Builder.param`` with its shape,
initializer and *logical* sharding axes; the same declaration code produces
(i) initialized arrays, (ii) jax.ShapeDtypeStruct skeletons for the dry-run,
and (iii) PartitionSpecs for pjit — guaranteeing the three never drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.runtime import sharding


class Builder:
    """Collects parameter declarations in one of three modes."""

    def __init__(self, mode: str, key: jax.Array | None = None,
                 dtype=jnp.float32):
        assert mode in ("init", "spec", "shape")
        self.mode = mode
        self._key = key
        self.dtype = dtype

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, shape, axes, init="normal", scale: float | None = None):
        if self.mode == "spec":
            return sharding.param_spec(shape, *axes)
        if self.mode == "shape":
            return jax.ShapeDtypeStruct(shape, self.dtype)
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if scale is None:
            # fan-in scaling
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (scale * jax.random.normal(self._next_key(), shape)
                ).astype(self.dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(x, weight, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def make_norm_params(b: Builder, d: int, kind: str):
    if kind == "rmsnorm":
        return {"scale": b.param((d,), (None,), init="zeros")}
    return {"scale": b.param((d,), (None,), init="ones"),
            "bias": b.param((d,), (None,), init="zeros")}


def apply_norm(params, x, kind: str, eps: float):
    if kind == "rmsnorm":
        return rms_norm(x, params["scale"], eps)
    return layer_norm(x, params["scale"], params["bias"], eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings.
# ---------------------------------------------------------------------------

def rope(x, positions, theta):
    """Apply RoPE.  x: (..., S, H, D), positions: (..., S).
    ``theta`` may be a traced scalar (per-layer theta under scan)."""
    d = x.shape[-1]
    half = d // 2
    theta = jnp.asarray(theta, jnp.float32)
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    ang = ang[..., None, :]                                    # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------

def make_mlp_params(b: Builder, d: int, f: int, gated: bool):
    p = {"w_up": b.param((d, f), ("embed", "ff")),
         "w_down": b.param((f, d), ("ff", "embed"))}
    if gated:
        p["w_gate"] = b.param((d, f), ("embed", "ff"))
    return p


def apply_mlp(params, x, act: str, gated: bool):
    act_fn = jax.nn.silu if act == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True))
    up = x @ params["w_up"]
    up = sharding.shard(up, "batch", "seq", "ff")
    if gated:
        gate = act_fn(x @ params["w_gate"])
        h = gate * up
    else:
        h = act_fn(up)
    out = h @ params["w_down"]
    return sharding.shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Loss.
# ---------------------------------------------------------------------------

def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy in f32.  logits: (B,S,V), labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None],
                                 axis=-1)[..., 0]
    nll = lse - picked
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_loss(h_final, embed, labels, chunk: int, softcap_val: float,
                 mask=None, unroll: bool = False):
    """Sequence-chunked cross entropy: never materializes (B,S,V).

    h_final: (B,S,D) final hidden states; embed: (V,D) tied output table.
    This is one of the §Perf memory optimizations (see EXPERIMENTS.md).
    ``unroll`` is the dry-run analysis mode (XLA cost_analysis counts
    while-loop bodies once).
    """
    B, S, D = h_final.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    h = h_final.reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
    y = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)
    if mask is None:
        m = jnp.ones((n_chunks, B, chunk), jnp.float32)
    else:
        m = mask.reshape(B, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, inp):
        hc, yc, mc = inp
        logits = softcap(hc @ embed.T, softcap_val).astype(jnp.float32)
        logits = sharding.shard(logits, "loss_batch", "seq", "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        # label pick via masked sum, NOT take_along_axis: a gather over the
        # vocab-sharded axis would all-gather the full logits; the iota
        # compare keeps the reduction local + one tiny all-reduce.
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        picked = jnp.sum(jnp.where(vocab_iota == yc[..., None], logits,
                                   0.0), axis=-1)
        nll = (lse - picked) * mc
        tot, cnt = carry
        return (tot + jnp.sum(nll), cnt + jnp.sum(mc)), None

    init = (jnp.zeros(()), jnp.zeros(()))
    if unroll:
        carry = init
        for c in range(n_chunks):
            carry, _ = body(carry, (h[c], y[c], m[c]))
        tot, cnt = carry
    else:
        # checkpoint per chunk: backward recomputes one chunk's logits at a
        # time instead of keeping n_chunks x (B, chunk, V) residuals.
        (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body), init, (h, y, m))
    return tot / jnp.maximum(cnt, 1.0)
