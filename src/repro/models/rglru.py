"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

The temporal mixing is the Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)                    (recurrence gate)
    i_t = sigmoid(W_x x_t)                    (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)    (per-channel decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

wrapped in the Griffin recurrent block: linear in-proj to a gated branch
(GeLU) and a recurrent branch (temporal conv1d width 4 -> RG-LRU), merged
by elementwise product and projected out.

Training uses ``jax.lax.associative_scan`` over the (a, b) linear
recurrence; the Pallas kernel in ``repro.kernels.rglru_scan`` implements the
same blocked scan for TPU and is validated against ``rglru_ref`` here.
Decode carries (h, conv_state) — O(1) per step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ModelConfig
from repro.runtime import sharding

_C = 8.0
_MAX_SQRT_GRADIENT = 1000.0


def make_rglru_params(b: nn.Builder, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    conv = 4
    return {
        "w_in_rec": b.param((d, w), ("embed", "lru")),
        "w_in_gate": b.param((d, w), ("embed", "lru")),
        "w_out": b.param((w, d), ("lru", "embed")),
        "conv_w": b.param((conv, w), (None, "lru"),
                          scale=1.0 / math.sqrt(conv)),
        "conv_b": b.param((w,), ("lru",), init="zeros"),
        "gate_a": b.param((w,), ("lru",), init="zeros"),
        "gate_x": b.param((w,), ("lru",), init="zeros"),
        # Lambda parametrized so a in (0.9, 0.999) at init
        "log_lambda": b.param((w,), ("lru",), init="zeros"),
    }


def _decay(params, x_rec):
    """Per-timestep decay a_t and input scale — both like x_rec.

    r_t = sigmoid(x_rec + gate_a) is the recurrence gate; the decay is
    a_t = exp(-c * softplus(Lambda) * r_t) as in the paper, with Lambda
    parametrized so a ~ 0.96..0.999 at init.
    """
    lam = jax.nn.softplus(params["log_lambda"] + 4.0) / _C
    r = jax.nn.sigmoid(x_rec + params["gate_a"])
    a = jnp.exp(-_C * lam * r)
    return a, jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))


def rglru_scan_ref(a, bx):
    """Associative linear recurrence h_t = a_t h_{t-1} + bx_t.

    a, bx: (B, S, W) -> h: (B, S, W).  Pure-jnp oracle, also used in
    training via associative_scan (log-depth).
    """
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    a_s, b_s = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return b_s


def apply_rglru(cfg: ModelConfig, params, x, positions=None):
    """Griffin recurrent block, training/prefill.  x: (B,S,D)."""
    B, S, D = x.shape
    gate = jax.nn.gelu(x @ params["w_in_gate"], approximate=True)
    rec = x @ params["w_in_rec"]
    rec = sharding.shard(rec, "batch", "seq", "lru")

    # temporal conv1d (causal, width 4)
    conv = params["conv_w"]
    width = conv.shape[0]
    rec_pad = jnp.pad(rec, ((0, 0), (width - 1, 0), (0, 0)))
    rec_c = sum(rec_pad[:, i:i + S, :] * conv[i] for i in range(width))
    rec_c = rec_c + params["conv_b"]

    a, b_scale = _decay(params, rec_c)
    h = rglru_scan_ref(a.astype(jnp.float32),
                       (b_scale * jax.nn.sigmoid(params["gate_x"])
                        * rec_c).astype(jnp.float32))
    h = h.astype(x.dtype)
    out = (h * gate) @ params["w_out"]
    return sharding.shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Decode (single step, O(1) state).
# ---------------------------------------------------------------------------

def init_rglru_cache(cfg: ModelConfig, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 4 - 1, w), dtype),
    }


def decode_rglru(cfg: ModelConfig, params, cache, x):
    """x: (B,1,D) -> (out (B,1,D), new_cache)."""
    B = x.shape[0]
    xt = x[:, 0]
    gate = jax.nn.gelu(xt @ params["w_in_gate"], approximate=True)
    rec = xt @ params["w_in_rec"]

    conv_w = params["conv_w"]
    width = conv_w.shape[0]
    hist = jnp.concatenate([cache["conv"], rec[:, None, :]], axis=1)
    rec_c = sum(hist[:, i, :] * conv_w[i] for i in range(width))
    rec_c = rec_c + params["conv_b"]
    new_conv = hist[:, 1:, :]

    a, b_scale = _decay(params, rec_c[:, None, :])
    a, b_scale = a[:, 0], b_scale[:, 0]
    bx = b_scale * jax.nn.sigmoid(params["gate_x"]) * rec_c
    h = a.astype(jnp.float32) * cache["h"] + bx.astype(jnp.float32)
    out = ((h.astype(x.dtype) * gate) @ params["w_out"])[:, None, :]
    return out, {"h": h, "conv": new_conv}
