"""Mamba-2 SSD (state-space duality) block (arXiv:2405.21060).

The selective SSM with scalar-times-identity A is computed with the SSD
chunked algorithm: within a chunk the output is a masked attention-like
matmul (duality), and chunk-to-chunk information flows through the
recurrent state  S_c = (decay) S_{c-1} + B_c^T (decay-weighted X_c).

Shapes follow the Mamba-2 reference: inner dim  di = expand * d_model,
heads nh = di / headdim, state N = ssm_state, groups G (B/C shared
across heads within a group).

``ssd_ref`` below is the pure-jnp oracle; the Pallas kernel in
``repro.kernels.ssd_scan`` computes the same chunked recursion with VMEM
tiling and is validated against it.  Decode carries (conv_state,
ssm_state (B, nh, hd, N)) — O(1) per token.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ModelConfig
from repro.runtime import sharding


def make_ssd_params(b: nn.Builder, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    nh = di // cfg.ssm_headdim
    g, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = di + 2 * g * N
    return {
        "in_proj": b.param((d, 2 * di + 2 * g * N + nh), ("embed",
                                                          "ssm_inner")),
        "conv_w": b.param((cfg.ssm_conv, conv_dim), (None, "ssm_inner"),
                          scale=1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": b.param((conv_dim,), ("ssm_inner",), init="zeros"),
        "A_log": b.param((nh,), (None,), init="zeros"),
        "D": b.param((nh,), (None,), init="ones"),
        "dt_bias": b.param((nh,), (None,), init="zeros"),
        "norm": b.param((di,), ("ssm_inner",), init="zeros"),
        "out_proj": b.param((di, d), ("ssm_inner", "embed")),
    }


def ssd_ref(x, dt, A, B, C, chunk: int, unroll: bool = False):
    """SSD chunked reference.

    x:  (b, s, nh, hd)   inputs per head
    dt: (b, s, nh)       positive step sizes (after softplus)
    A:  (nh,)            negative per-head decay rates
    B:  (b, s, g, N)     input maps (g groups broadcast over heads)
    C:  (b, s, g, N)     output maps
    Returns y: (b, s, nh, hd).
    """
    b, s, nh, hd = x.shape
    g, N = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = nh // g

    xc = x.reshape(b, nc, chunk, nh, hd)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, N), rep, axis=3)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, N), rep, axis=3)

    dA = dtc * A  # (b,nc,l,nh) log-decay per step
    cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum
    # intra-chunk (dual / attention-like) term
    # L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,l,l,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bclhn,bcmhn->bclmh", Cc, Bc)          # (b,nc,l,l,nh)
    y_intra = jnp.einsum("bclmh,bclmh,bcmh,bcmhp->bclhp",
                         CB, L, dtc, xc)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # (b,nc,l,nh)
    S = jnp.einsum("bclh,bclh,bclhn,bclhp->bchnp",
                   decay_to_end, dtc, Bc, xc)              # (b,nc,nh,N,hd)

    # inter-chunk recurrence over c:  S_prev' = exp(cum_last) S_prev + S
    chunk_decay = jnp.exp(cum[:, :, -1, :])                # (b,nc,nh)

    def scan_fn(Sprev, inp):
        Sc, dec = inp
        Snew = dec[:, :, None, None] * Sprev + Sc
        return Snew, Sprev

    S_t = jnp.moveaxis(S, 1, 0)                 # (nc,b,nh,N,hd)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)     # (nc,b,nh)
    init = jnp.zeros_like(S_t[0])
    if unroll:   # dry-run analysis mode: while-loops undercount in XLA cost
        carry, outs = init, []
        for c in range(nc):
            carry, prev = scan_fn(carry, (S_t[c], dec_t[c]))
            outs.append(prev)
        Sprev_t = jnp.stack(outs)
    else:
        _, Sprev_t = jax.lax.scan(scan_fn, init, (S_t, dec_t))
    Sprev = jnp.moveaxis(Sprev_t, 0, 1)         # (b,nc,nh,N,hd) state *before* chunk

    # inter-chunk contribution: y_j += C_j exp(cum_j) S_prev
    decay_from_start = jnp.exp(cum)             # (b,nc,l,nh)
    y_inter = jnp.einsum("bclhn,bclh,bchnp->bclhp",
                         Cc, decay_from_start, Sprev)
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    return y


def apply_ssd(cfg: ModelConfig, params, x, positions=None):
    """Mamba-2 block, training/prefill.  x: (B,S,D)."""
    B_, S, D = x.shape
    di = cfg.ssm_expand * D
    nh = di // cfg.ssm_headdim
    g, N = cfg.ssm_ngroups, cfg.ssm_state

    zxbcdt = x @ params["in_proj"]
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * N, 2 * di + 2 * g * N], axis=-1)

    # causal conv over (xs, B, C)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    width = cfg.ssm_conv
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S, :] * params["conv_w"][i]
               for i in range(width)) + params["conv_b"]
    conv = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(conv, [di, di + g * N], axis=-1)

    dt = jax.nn.softplus(dt + params["dt_bias"])           # (B,S,nh)
    A = -jnp.exp(params["A_log"])                          # (nh,)
    xh = xs.reshape(B_, S, nh, cfg.ssm_headdim)
    chunk = min(cfg.ssm_chunk, S)
    pad = (-S) % chunk                                     # causal: safe
    xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
    Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    y = ssd_ref(xh_p.astype(jnp.float32), dt_p.astype(jnp.float32), A,
                Bm_p.reshape(B_, Sp, g, N).astype(jnp.float32),
                Cm_p.reshape(B_, Sp, g, N).astype(jnp.float32),
                chunk, unroll=not cfg.scan_layers)[:, :S]
    y = y.astype(x.dtype) + xh * params["D"][None, None, :, None]
    y = y.reshape(B_, S, di)
    y = nn.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return sharding.shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Decode.
# ---------------------------------------------------------------------------

def init_ssd_cache(cfg: ModelConfig, batch: int, dtype):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_headdim
    g, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = di + 2 * g * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, nh, N, cfg.ssm_headdim), jnp.float32),
    }


def decode_ssd(cfg: ModelConfig, params, cache, x):
    """x: (B,1,D) -> (out (B,1,D), new_cache).  Exact recurrent step:
    S <- exp(dt*A) S + dt * B x^T ;  y = C S + D x."""
    B_ = x.shape[0]
    D = x.shape[-1]
    di = cfg.ssm_expand * D
    nh = di // cfg.ssm_headdim
    g, N = cfg.ssm_ngroups, cfg.ssm_state

    zxbcdt = x[:, 0] @ params["in_proj"]
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * N, 2 * di + 2 * g * N], axis=-1)

    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    hist = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    conv = sum(hist[:, i, :] * params["conv_w"][i]
               for i in range(cfg.ssm_conv)) + params["conv_b"]
    conv = jax.nn.silu(conv)
    new_conv = hist[:, 1:, :]
    xs, Bm, Cm = jnp.split(conv, [di, di + g * N], axis=-1)

    dt = jax.nn.softplus(dt + params["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B_, nh, cfg.ssm_headdim).astype(jnp.float32)
    rep = nh // g
    Bh = jnp.repeat(Bm.reshape(B_, g, N), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B_, g, N), rep, axis=1).astype(jnp.float32)

    decay = jnp.exp(dt * A)                                # (B,nh)
    upd = jnp.einsum("bh,bhn,bhp->bhnp", dt, Bh, xh)
    state = decay[:, :, None, None] * cache["state"] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    y = y.astype(x.dtype) + xh.astype(x.dtype) * params["D"][None, :, None]
    y = y.reshape(B_, di)
    y = nn.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"conv": new_conv, "state": state}
