"""The full model: init/apply/prefill/decode for every assigned arch.

One flexible decoder (or encoder-decoder) transformer whose per-layer type
comes from ``cfg.attn_pattern``:

  * uniform attention archs (gemma/yi/glm4/phi3v/mixtral/olmoe/gemma3):
    one ``lax.scan`` over stacked blocks; per-layer window & rope-theta ride
    along as scanned arrays, so local:global mixtures share one body;
  * recurrentgemma: scan over (rglru, rglru, local-attn) periods + unrolled
    remainder;
  * mamba2: scan over SSD blocks;
  * whisper: encoder scan + decoder scan with cross-attention.

Parameters, ShapeDtypeStructs and PartitionSpecs all come from the same
declaration code (``models.nn.Builder``), so the dry-run sharding can never
drift from the real initializer.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import attention, moe, nn, rglru, ssd
from repro.models.config import ModelConfig
from repro.runtime import sharding


# ---------------------------------------------------------------------------
# Builders.
# ---------------------------------------------------------------------------

class _Stacked:
    """Prepends a leading layer axis to every declared parameter."""

    def __init__(self, b: nn.Builder, n: int):
        self._b = b
        self._n = n

    def param(self, shape, axes, init="normal", scale=None):
        if scale is None and init == "normal":
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        return self._b.param((self._n,) + tuple(shape),
                             (None,) + tuple(axes), init=init, scale=scale)


def _attn_block(b, cfg: ModelConfig):
    p = {"norm1": nn.make_norm_params(b, cfg.d_model, cfg.norm),
         "attn": attention.make_attn_params(b, cfg),
         "norm2": nn.make_norm_params(b, cfg.d_model, cfg.norm)}
    if cfg.num_experts > 0:
        p["moe"] = moe.make_moe_params(b, cfg)
    elif cfg.d_ff > 0:
        p["mlp"] = nn.make_mlp_params(b, cfg.d_model, cfg.d_ff,
                                      cfg.gated_mlp)
    return p


def _rglru_block(b, cfg: ModelConfig):
    return {"norm1": nn.make_norm_params(b, cfg.d_model, cfg.norm),
            "rglru": rglru.make_rglru_params(b, cfg),
            "norm2": nn.make_norm_params(b, cfg.d_model, cfg.norm),
            "mlp": nn.make_mlp_params(b, cfg.d_model, cfg.d_ff,
                                      cfg.gated_mlp)}


def _ssd_block(b, cfg: ModelConfig):
    return {"norm1": nn.make_norm_params(b, cfg.d_model, cfg.norm),
            "ssd": ssd.make_ssd_params(b, cfg)}


def _cross_block(b, cfg: ModelConfig):
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    return {"norm1": nn.make_norm_params(b, cfg.d_model, cfg.norm),
            "self_attn": attention.make_attn_params(b, cfg),
            "norm_x": nn.make_norm_params(b, cfg.d_model, cfg.norm),
            "cross_attn": attention.make_attn_params(b, cfg),
            "norm2": nn.make_norm_params(b, cfg.d_model, cfg.norm),
            "mlp": nn.make_mlp_params(b, cfg.d_model, cfg.d_ff,
                                      cfg.gated_mlp)}


def _build(cfg: ModelConfig, b: nn.Builder):
    d, v = cfg.d_model, cfg.vocab_size
    params: dict = {
        # 'embed_table' replicates under the dp profile (PERF-B3): the
        # FSDP gathers of the table per loss chunk cost more than the
        # replicated copy.
        "embed": b.param((v, d), ("vocab", "embed_table"), scale=1.0),
        "final_norm": nn.make_norm_params(b, d, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = b.param((v, d), ("vocab", "embed_table"))

    if cfg.name.startswith("recurrentgemma") or (
            "rglru" in cfg.attn_pattern and len(set(cfg.attn_pattern)) > 1):
        period = len(cfg.attn_pattern)          # (rglru, rglru, local)
        n_full = cfg.num_layers // period
        rem = cfg.num_layers % period
        params["periods"] = {
            "r1": _rglru_block(_Stacked(b, n_full), cfg),
            "r2": _rglru_block(_Stacked(b, n_full), cfg),
            "attn": _attn_block(_Stacked(b, n_full), cfg),
        }
        if rem:
            params["tail"] = _rglru_block(_Stacked(b, rem), cfg)
    elif cfg.attn_pattern == ("ssd",):
        params["blocks"] = _ssd_block(_Stacked(b, cfg.num_layers), cfg)
    elif cfg.is_encoder_decoder:
        params["enc_pos"] = b.param((cfg.encoder_seq, d), (None, "embed"),
                                    scale=0.02)
        # learned decoder positions; whisper's real context is 448 — the
        # table is extended to cover the assigned mechanical decode_32k /
        # prefill_32k shapes (DESIGN.md §5)
        params["dec_pos"] = b.param((40960, d), (None, "embed"), scale=0.02)
        params["encoder"] = _attn_block(_Stacked(b, cfg.encoder_layers), cfg)
        params["enc_final_norm"] = nn.make_norm_params(b, d, cfg.norm)
        params["decoder"] = _cross_block(_Stacked(b, cfg.num_layers), cfg)
    else:
        params["blocks"] = _attn_block(_Stacked(b, cfg.num_layers), cfg)
    return params


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return _build(cfg, nn.Builder("init", key=key, dtype=dtype))


def param_shapes(cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    return _build(cfg, nn.Builder("shape", dtype=dtype))


def param_specs(cfg: ModelConfig):
    with sharding.profile(cfg.sharding_profile):
        return _build(cfg, nn.Builder("spec"))


# ---------------------------------------------------------------------------
# Per-layer statics (window / rope theta arrays for the scans).
# ---------------------------------------------------------------------------

def _layer_statics_py(cfg: ModelConfig):
    windows, thetas = [], []
    for i in range(cfg.num_layers):
        t = cfg.layer_type(i)
        if t == "local":
            windows.append(cfg.window)
            thetas.append(10000.0 if len(set(cfg.attn_pattern)) > 1
                          else cfg.rope_theta)
        else:
            windows.append(0)
            thetas.append(cfg.rope_theta)
    return windows, thetas


def _layer_statics(cfg: ModelConfig):
    windows, thetas = _layer_statics_py(cfg)
    return (jnp.asarray(windows, jnp.int32),
            jnp.asarray(thetas, jnp.float32))


# ---------------------------------------------------------------------------
# Forward (training / prefill trunk).
# ---------------------------------------------------------------------------

def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat in ("block", "group"):
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def _scan_or_loop(cfg: ModelConfig, body, carry, xs_tree, length: int):
    """lax.scan when cfg.scan_layers, else an unrolled python loop.

    remat="block": every scan body is checkpointed — residual = one block
    input per layer (O(L) residuals).
    remat="group" (PERF-A3): layers are scanned in groups of
    ``cfg.remat_group`` with the checkpoint at GROUP level — residuals
    drop to O(L / g) block inputs at the cost of one extra in-group
    forward during backprop (sqrt-remat; the fits-fix for mixtral-8x22b
    whose 56 x 800 MB per-layer residuals overflow HBM).

    The unrolled form is used by the dry-run analysis mode: XLA's
    cost_analysis counts a while-loop body ONCE (verified in
    EXPERIMENTS.md §Dry-run), so FLOPs/bytes/collective extraction happens
    on unrolled lowerings while the fits-in-HBM proof uses the scan form.
    Only the carry is returned (no ys).
    """
    if (cfg.remat == "group" and cfg.scan_layers
            and length % cfg.remat_group == 0 and length > cfg.remat_group):
        g = cfg.remat_group
        # NESTED checkpointing: the group recompute must itself run
        # block-checkpointed, otherwise the backward holds all g layers'
        # internals at once (measured 3x WORSE, EXPERIMENTS.md §Perf A3).
        inner = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

        def group_body(c, xs_group):
            for j in range(g):
                sl = jax.tree.map(lambda x: x[j], xs_group)
                c, _ = inner(c, sl)
            return c, None

        grouped = jax.tree.map(
            lambda x: x.reshape((length // g, g) + x.shape[1:]), xs_tree)
        carry, _ = jax.lax.scan(_maybe_remat(cfg, group_body), carry,
                                grouped)
        return carry

    body = _maybe_remat(cfg, body)
    if cfg.scan_layers:
        carry, _ = jax.lax.scan(body, carry, xs_tree)
        return carry
    for i in range(length):
        sl = jax.tree.map(lambda x: x[i], xs_tree)
        carry, _ = body(carry, sl)
    return carry


def _scan_or_loop_ys(cfg: ModelConfig, body, carry, xs_tree, length: int):
    """Like _scan_or_loop but returns (carry, stacked_ys) — used by the
    prefill/serve paths that collect per-layer caches."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs_tree)
    ys = []
    for i in range(length):
        sl = jax.tree.map(lambda x: x[i], xs_tree)
        carry, y = body(carry, sl)
        ys.append(y)
    return carry, jax.tree.map(lambda *xs: jnp.stack(xs), *ys)


def _apply_attn_block(cfg, lp, h, positions, window, theta):
    a_in = nn.apply_norm(lp["norm1"], h, cfg.norm, cfg.norm_eps)
    h = h + attention.attention(cfg, lp["attn"], a_in, positions,
                                window=window, rope_theta=theta)
    f_in = nn.apply_norm(lp["norm2"], h, cfg.norm, cfg.norm_eps)
    if cfg.num_experts > 0:
        h = h + moe.apply_moe(cfg, lp["moe"], f_in)
    elif cfg.d_ff > 0:
        h = h + nn.apply_mlp(lp["mlp"], f_in, cfg.act, cfg.gated_mlp)
    return h


def _apply_rglru_block(cfg, lp, h, positions):
    r_in = nn.apply_norm(lp["norm1"], h, cfg.norm, cfg.norm_eps)
    h = h + rglru.apply_rglru(cfg, lp["rglru"], r_in, positions)
    f_in = nn.apply_norm(lp["norm2"], h, cfg.norm, cfg.norm_eps)
    return h + nn.apply_mlp(lp["mlp"], f_in, cfg.act, cfg.gated_mlp)


def _apply_ssd_block(cfg, lp, h, positions):
    s_in = nn.apply_norm(lp["norm1"], h, cfg.norm, cfg.norm_eps)
    return h + ssd.apply_ssd(cfg, lp["ssd"], s_in, positions)


def _encode(cfg: ModelConfig, params, frames):
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    h = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None]
    positions = jnp.broadcast_to(jnp.arange(h.shape[1]),
                                 (h.shape[0], h.shape[1]))

    def body(carry, lp):
        a_in = nn.apply_norm(lp["norm1"], carry, cfg.norm, cfg.norm_eps)
        carry = carry + attention.attention(
            cfg, lp["attn"], a_in, positions, window=0, causal=False,
            rope_theta=0.0)
        f_in = nn.apply_norm(lp["norm2"], carry, cfg.norm, cfg.norm_eps)
        carry = carry + nn.apply_mlp(lp["mlp"], f_in, cfg.act,
                                     cfg.gated_mlp)
        return carry, None

    h = _scan_or_loop(cfg, body, h, params["encoder"], cfg.encoder_layers)
    return nn.apply_norm(params["enc_final_norm"], h, cfg.norm,
                         cfg.norm_eps)


def _embed_tokens(cfg: ModelConfig, params, tokens):
    h = params["embed"][tokens]
    if cfg.scale_embeddings:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return sharding.shard(h, "batch", "seq", "embed")


def forward(cfg: ModelConfig, params, batch) -> jax.Array:
    """Returns final hidden states (B, S, D).

    batch: {"tokens": (B,S) int32} plus modality extras:
      whisper: {"frames": (B, encoder_seq, D)};
      vlm: {"patches": (B, num_patches, D)} prepended to the sequence.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed_tokens(cfg, params, tokens)

    if cfg.frontend == "vision_stub" and "patches" in batch:
        patches = batch["patches"].astype(h.dtype)
        h = jnp.concatenate([patches, h], axis=1)
    Sh = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Sh), (B, Sh))

    if "periods" in params:                       # recurrentgemma
        def body(carry, lps):
            r1, r2, at = lps
            carry = _apply_rglru_block(cfg, r1, carry, positions)
            carry = _apply_rglru_block(cfg, r2, carry, positions)
            carry = _apply_attn_block(cfg, at, carry, positions,
                                      cfg.window, cfg.rope_theta)
            return carry, None

        n_full = cfg.num_layers // len(cfg.attn_pattern)
        h = _scan_or_loop(cfg, body, h,
                          (params["periods"]["r1"], params["periods"]["r2"],
                           params["periods"]["attn"]), n_full)
        if "tail" in params:
            def tbody(carry, lp):
                return _apply_rglru_block(cfg, lp, carry, positions), None
            h = _scan_or_loop(cfg, tbody, h, params["tail"],
                              cfg.num_layers % len(cfg.attn_pattern))
    elif cfg.attn_pattern == ("ssd",):
        def body(carry, lp):
            return _apply_ssd_block(cfg, lp, carry, positions), None
        h = _scan_or_loop(cfg, body, h, params["blocks"], cfg.num_layers)
    elif cfg.is_encoder_decoder:
        enc = _encode(cfg, params, batch["frames"])
        # per-layer cross kv are computed inside the scan (weights differ)
        h = h + params["dec_pos"][:Sh][None]

        def body(carry, lp):
            kv = (jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wk"]),
                  jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wv"]))
            a_in = nn.apply_norm(lp["norm1"], carry, cfg.norm, cfg.norm_eps)
            carry = carry + attention.attention(
                cfg, lp["self_attn"], a_in, positions, window=0,
                rope_theta=0.0)
            x_in = nn.apply_norm(lp["norm_x"], carry, cfg.norm, cfg.norm_eps)
            carry = carry + attention.attention(
                cfg, lp["cross_attn"], x_in, positions, window=0,
                kv_override=kv)
            f_in = nn.apply_norm(lp["norm2"], carry, cfg.norm, cfg.norm_eps)
            carry = carry + nn.apply_mlp(lp["mlp"], f_in, cfg.act,
                                         cfg.gated_mlp)
            return carry, None

        h = _scan_or_loop(cfg, body, h, params["decoder"], cfg.num_layers)
    else:
        # Uniform attention stack: scan over whole pattern periods so every
        # sub-layer sees a *static* window/theta (required by the k-band
        # slicing in blocked attention); remainder layers unrolled.
        win_py, theta_py = _layer_statics_py(cfg)
        period = len(cfg.attn_pattern)
        n_full = cfg.num_layers // period
        rem = cfg.num_layers % period

        def body(carry, lp_group):
            for j in range(period):
                lp = jax.tree.map(lambda x: x[j], lp_group)
                # per-sub-layer remat: without it the backward of a period
                # body materializes all `period` layers' residuals at once.
                blk = (jax.checkpoint(
                    lambda c, p, jj=j: _apply_attn_block(
                        cfg, p, c, positions, win_py[jj], theta_py[jj]))
                    if cfg.remat == "block" and period > 1 else
                    lambda c, p, jj=j: _apply_attn_block(
                        cfg, p, c, positions, win_py[jj], theta_py[jj]))
                carry = blk(carry, lp)
            return carry, None

        main = jax.tree.map(
            lambda x: x[:n_full * period].reshape(
                (n_full, period) + x.shape[1:]), params["blocks"])
        h = _scan_or_loop(cfg, body, h, main, n_full)
        for r in range(rem):
            lp = jax.tree.map(lambda x: x[n_full * period + r],
                              params["blocks"])
            h = _apply_attn_block(cfg, lp, h, positions, win_py[r],
                                  theta_py[r])

    return nn.apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)


def _out_table(cfg, params):
    return params["embed"] if cfg.tie_embeddings else params["unembed"]


def logits_fn(cfg: ModelConfig, params, h):
    out = h @ _out_table(cfg, params).T
    out = nn.softcap(out, cfg.logits_softcap)
    return sharding.shard(out, "batch", "seq", "vocab")


def loss_fn(cfg: ModelConfig, params, batch) -> jax.Array:
    """Mean next-token cross-entropy.  Uses sequence-chunked loss when
    cfg.loss_chunk > 0 (never materializes (B,S,V))."""
    h = forward(cfg, params, batch)
    tokens = batch["tokens"]
    S = tokens.shape[1]
    if cfg.frontend == "vision_stub" and "patches" in batch:
        h = h[:, -S:]                      # loss only over the text tail
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones(tokens.shape, jnp.float32).at[:, -1].set(0.0)
    table = _out_table(cfg, params)
    if cfg.loss_chunk and S % cfg.loss_chunk == 0:
        return nn.chunked_loss(h, table, labels, cfg.loss_chunk,
                               cfg.logits_softcap, mask,
                               unroll=not cfg.scan_layers)
    logits = logits_fn(cfg, params, h)
    return nn.cross_entropy(logits, labels, mask)


# ---------------------------------------------------------------------------
# Decode caches + serve step.
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int,
                      dtype=None):
    """Build the (stacked) cache pytree for ``serve_step``."""
    dtype = dtype or jnp.dtype(cfg.dtype)

    if "rglru" in cfg.attn_pattern and len(set(cfg.attn_pattern)) > 1:
        period = len(cfg.attn_pattern)
        n_full = cfg.num_layers // period
        rem = cfg.num_layers % period
        spec = attention.CacheSpec("ring", min(cfg.window, max_seq))
        cache = {
            "r1": jax.tree.map(lambda x: jnp.stack([x] * n_full),
                               rglru.init_rglru_cache(cfg, batch, dtype)),
            "r2": jax.tree.map(lambda x: jnp.stack([x] * n_full),
                               rglru.init_rglru_cache(cfg, batch, dtype)),
            "attn": jax.tree.map(
                lambda x: jnp.stack([x] * n_full),
                attention.init_cache(cfg, spec, batch, dtype)),
        }
        if rem:
            cache["tail"] = jax.tree.map(
                lambda x: jnp.stack([x] * rem),
                rglru.init_rglru_cache(cfg, batch, dtype))
        return cache
    if cfg.attn_pattern == ("ssd",):
        return jax.tree.map(lambda x: jnp.stack([x] * cfg.num_layers),
                            ssd.init_ssd_cache(cfg, batch, dtype))
    if cfg.is_encoder_decoder:
        spec = attention.CacheSpec("full", max_seq)
        kvh = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
        return {
            "self": jax.tree.map(
                lambda x: jnp.stack([x] * cfg.num_layers),
                attention.init_cache(cfg, spec, batch, dtype)),
            "cross_k": jnp.zeros((cfg.num_layers,) + kvh, dtype),
            "cross_v": jnp.zeros((cfg.num_layers,) + kvh, dtype),
        }
    # uniform attention stack: per-layer ring/full caches (stacked by kind)
    caches = []
    for i in range(cfg.num_layers):
        spec = attention.cache_spec(cfg, cfg.layer_type(i), max_seq)
        caches.append(attention.init_cache(cfg, spec, batch, dtype))
    # stack homogeneous subsets: represent as dict {"full": ..., "ring": ...}
    # with an index map so the scan can pick per-layer slices.
    return _stack_mixed_caches(cfg, caches, max_seq)


def _cache_layout(cfg: ModelConfig, max_seq: int):
    kinds = []
    for i in range(cfg.num_layers):
        kinds.append(attention.cache_spec(cfg, cfg.layer_type(i),
                                          max_seq).kind)
    return tuple(kinds)


def _stack_mixed_caches(cfg, caches, max_seq):
    kinds = _cache_layout(cfg, max_seq)
    out = {}
    for kind in ("full", "ring"):
        idx = [i for i, k in enumerate(kinds) if k == kind]
        if idx:
            out[kind] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                     *[caches[i] for i in idx])
    return out


def serve_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step.  tokens: (B, 1) int32; pos: scalar int32 absolute
    position.  Returns (logits (B, 1, V), new_cache)."""
    B = tokens.shape[0]
    h = _embed_tokens(cfg, params, tokens)
    windows, thetas = _layer_statics(cfg)

    if "periods" in params:
        spec = attention.CacheSpec("ring",
                                   int(cache["attn"]["k"].shape[2]))

        def body(carry, xs):
            (r1, r2, at), (c1, c2, ca) = xs
            carry, n1 = _decode_rglru_block(cfg, r1, c1, carry)
            carry, n2 = _decode_rglru_block(cfg, r2, c2, carry)
            carry, na = _decode_attn_block(cfg, at, ca, spec, carry, pos,
                                           cfg.window, cfg.rope_theta)
            return carry, (n1, n2, na)

        n_full = cfg.num_layers // len(cfg.attn_pattern)
        h, (nc1, nc2, nca) = _scan_or_loop_ys(
            cfg, body, h, ((params["periods"]["r1"],
                            params["periods"]["r2"],
                            params["periods"]["attn"]),
                           (cache["r1"], cache["r2"], cache["attn"])),
            n_full)
        new_cache = {"r1": nc1, "r2": nc2, "attn": nca}
        if "tail" in params:
            def tbody(carry, xs):
                lp, c = xs
                carry, ncl = _decode_rglru_block(cfg, lp, c, carry)
                return carry, ncl
            h, nct = _scan_or_loop_ys(
                cfg, tbody, h, (params["tail"], cache["tail"]),
                cfg.num_layers % len(cfg.attn_pattern))
            new_cache["tail"] = nct
    elif cfg.attn_pattern == ("ssd",):
        def body(carry, xs):
            lp, c = xs
            s_in = nn.apply_norm(lp["norm1"], carry, cfg.norm, cfg.norm_eps)
            out, ncl = ssd.decode_ssd(cfg, lp["ssd"], c, s_in)
            return carry + out, ncl
        h, new_cache = _scan_or_loop_ys(cfg, body, h,
                                        (params["blocks"], cache),
                                        cfg.num_layers)
    elif cfg.is_encoder_decoder:
        spec = attention.CacheSpec("full", int(cache["self"]["k"].shape[2]))
        h = h + params["dec_pos"][pos][None, None]

        def body(carry, xs):
            lp, cs, ck, cv = xs
            a_in = nn.apply_norm(lp["norm1"], carry, cfg.norm, cfg.norm_eps)
            out, ncs = attention.decode_attention(
                cfg, lp["self_attn"], cs, spec, a_in, pos, window=0,
                rope_theta=0.0)
            carry = carry + out
            x_in = nn.apply_norm(lp["norm_x"], carry, cfg.norm, cfg.norm_eps)
            carry = carry + attention.attention(
                cfg, lp["cross_attn"], x_in, None, window=0,
                kv_override=(ck, cv))
            f_in = nn.apply_norm(lp["norm2"], carry, cfg.norm, cfg.norm_eps)
            carry = carry + nn.apply_mlp(lp["mlp"], f_in, cfg.act,
                                         cfg.gated_mlp)
            return carry, ncs

        h, ncs = _scan_or_loop_ys(
            cfg, body, h, (params["decoder"], cache["self"],
                           cache["cross_k"], cache["cross_v"]),
            cfg.num_layers)
        new_cache = dict(cache, self=ncs)
    else:
        kinds = _cache_layout(cfg, 1 << 30)
        new_cache = dict(cache)
        # scan per cache-kind subset, preserving layer order inside each.
        h, new_cache = _decode_uniform(cfg, params, cache, h, pos, windows,
                                       thetas, kinds)
    h = nn.apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    logits = logits_fn(cfg, params, h)
    return logits, new_cache


def _decode_rglru_block(cfg, lp, c, h):
    r_in = nn.apply_norm(lp["norm1"], h, cfg.norm, cfg.norm_eps)
    out, nc = rglru.decode_rglru(cfg, lp["rglru"], c, r_in)
    h = h + out
    f_in = nn.apply_norm(lp["norm2"], h, cfg.norm, cfg.norm_eps)
    return h + nn.apply_mlp(lp["mlp"], f_in, cfg.act, cfg.gated_mlp), nc


def _decode_attn_block(cfg, lp, c, spec, h, pos, window, theta):
    a_in = nn.apply_norm(lp["norm1"], h, cfg.norm, cfg.norm_eps)
    out, nc = attention.decode_attention(cfg, lp["attn"], c, spec, a_in,
                                         pos, window=window,
                                         rope_theta=theta)
    h = h + out
    f_in = nn.apply_norm(lp["norm2"], h, cfg.norm, cfg.norm_eps)
    if cfg.num_experts > 0:
        h = h + moe.apply_moe(cfg, lp["moe"], f_in)
    elif cfg.d_ff > 0:
        h = h + nn.apply_mlp(lp["mlp"], f_in, cfg.act, cfg.gated_mlp)
    return h, nc


def _decode_uniform(cfg, params, cache, h, pos, windows, thetas, kinds):
    """Decode for the uniform attention stack.  Layers whose caches share a
    kind ("full"/"ring") were stacked together; we scan each subset in turn.
    Layer order is preserved because interleaved kinds only occur for
    local:global mixtures where blocks commute per-kind is NOT true — so we
    instead walk layers grouped but apply them in original order via a
    permutation-aware scan: for mixed patterns we fall back to a python loop
    over period groups (bounded: pattern length <= 8)."""
    if len(set(kinds)) == 1:
        kind = kinds[0]

        def body(carry, xs):
            lp, c, w, th = xs
            spec = attention.CacheSpec(kind, int(cache[kind]["k"].shape[2]))
            carry, nc = _decode_attn_block(cfg, lp, c, spec, carry, pos, w,
                                           th)
            return carry, nc

        h, nc = _scan_or_loop_ys(cfg, body, h,
                                 (params["blocks"], cache[kind], windows,
                                  thetas), cfg.num_layers)
        return h, {kind: nc}

    # Mixed local/global (gemma3): python loop over the pattern period with
    # static per-layer windows/thetas.
    win_py, theta_py = _layer_statics_py(cfg)
    new_cache = {k: jax.tree.map(lambda x: x, v) for k, v in cache.items()}
    kind_idx = {k: 0 for k in new_cache}
    for i in range(cfg.num_layers):
        k = kinds[i]
        j = kind_idx[k]
        kind_idx[k] += 1
        lp = jax.tree.map(lambda x: x[i], params["blocks"])
        c = jax.tree.map(lambda x: x[j], new_cache[k])
        spec = attention.CacheSpec(k, int(cache[k]["k"].shape[2]))
        h, nc = _decode_attn_block(cfg, lp, c, spec, h, pos,
                                   win_py[i], theta_py[i])
        new_cache[k] = jax.tree.map(
            lambda full, upd, jj=j: full.at[jj].set(upd), new_cache[k], nc)
    return h, new_cache


# ---------------------------------------------------------------------------
# Prefill.
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, batch, max_seq: int | None = None):
    """Run the trunk over a prompt and build decode caches.

    Returns (logits_last (B, V), cache).  Implemented for the uniform
    attention stack, mamba2, recurrentgemma and whisper.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = _embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision_stub" and "patches" in batch:
        h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
    S = h.shape[1]                      # includes prepended patches
    max_seq = max_seq or S
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    windows, thetas = _layer_statics(cfg)

    if cfg.attn_pattern == ("ssd",):
        def body(carry, lp):
            s_in = nn.apply_norm(lp["norm1"], carry, cfg.norm, cfg.norm_eps)
            out, st = _ssd_prefill(cfg, lp["ssd"], s_in)
            return carry + out, st
        h, new_cache = _scan_or_loop_ys(cfg, body, h, params["blocks"],
                                        cfg.num_layers)
    elif "periods" in params:
        spec = attention.CacheSpec("ring", min(cfg.window, max_seq))

        def body(carry, lps):
            r1, r2, at = lps
            carry, c1 = _rglru_prefill_block(cfg, r1, carry, positions)
            carry, c2 = _rglru_prefill_block(cfg, r2, carry, positions)
            carry, ca = _attn_prefill_block(cfg, at, carry, positions, spec,
                                            cfg.window, cfg.rope_theta)
            return carry, (c1, c2, ca)

        h, (c1, c2, ca) = _scan_or_loop_ys(
            cfg, body, h, (params["periods"]["r1"], params["periods"]["r2"],
                           params["periods"]["attn"]),
            cfg.num_layers // len(cfg.attn_pattern))
        new_cache = {"r1": c1, "r2": c2, "attn": ca}
        if "tail" in params:
            def tbody(carry, lp):
                carry, c = _rglru_prefill_block(cfg, lp, carry, positions)
                return carry, c
            h, ct = _scan_or_loop_ys(cfg, tbody, h, params["tail"],
                                     cfg.num_layers % len(cfg.attn_pattern))
            new_cache["tail"] = ct
    elif cfg.is_encoder_decoder:
        enc = _encode(cfg, params, batch["frames"])
        spec = attention.CacheSpec("full", max_seq)
        h = h + params["dec_pos"][:S][None]

        def body(carry, lp):
            kv = (jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wk"]),
                  jnp.einsum("bsd,dhk->bshk", enc, lp["cross_attn"]["wv"]))
            a_in = nn.apply_norm(lp["norm1"], carry, cfg.norm, cfg.norm_eps)
            k = jnp.einsum("bsd,dhk->bshk", a_in, lp["self_attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", a_in, lp["self_attn"]["wv"])
            carry = carry + attention.attention(
                cfg, lp["self_attn"], a_in, positions, window=0,
                rope_theta=0.0)
            cache_l = attention.prefill_cache(cfg, spec, k, v,
                                              jnp.arange(S))
            x_in = nn.apply_norm(lp["norm_x"], carry, cfg.norm, cfg.norm_eps)
            carry = carry + attention.attention(
                cfg, lp["cross_attn"], x_in, positions, window=0,
                kv_override=kv)
            f_in = nn.apply_norm(lp["norm2"], carry, cfg.norm, cfg.norm_eps)
            carry = carry + nn.apply_mlp(lp["mlp"], f_in, cfg.act,
                                         cfg.gated_mlp)
            return carry, (cache_l, kv[0], kv[1])

        h, (cs, ck, cv) = _scan_or_loop_ys(cfg, body, h,
                                           params["decoder"],
                                           cfg.num_layers)
        new_cache = {"self": cs, "cross_k": ck, "cross_v": cv}
    else:
        # mixed kinds need per-kind stacking; do the simple uniform case via
        # scan and the mixed case via python loop.
        kinds = _cache_layout(cfg, max_seq)
        if len(set(kinds)) == 1:
            spec = attention.cache_spec(cfg, cfg.layer_type(0), max_seq)

            def body1(carry, xs):
                lp, w, th = xs
                carry, c = _attn_prefill_block(cfg, lp, carry, positions,
                                               spec, w, th)
                return carry, c

            h, nc = _scan_or_loop_ys(cfg, body1, h,
                                     (params["blocks"], windows, thetas),
                                     cfg.num_layers)
            new_cache = {kinds[0]: nc}
        else:
            win_py, theta_py = _layer_statics_py(cfg)
            per_kind = {k: [] for k in set(kinds)}
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda x: x[i], params["blocks"])
                spec = attention.cache_spec(cfg, cfg.layer_type(i), max_seq)
                h, c = _attn_prefill_block(cfg, lp, h, positions, spec,
                                           win_py[i], theta_py[i])
                per_kind[kinds[i]].append(c)
            new_cache = {k: jax.tree.map(lambda *xs: jnp.stack(xs), *v)
                         for k, v in per_kind.items()}

    h = nn.apply_norm(params["final_norm"], h, cfg.norm, cfg.norm_eps)
    logits = logits_fn(cfg, params, h[:, -1:, :])
    return logits[:, 0], new_cache


def _attn_prefill_block(cfg, lp, h, positions, spec, window, theta):
    a_in = nn.apply_norm(lp["norm1"], h, cfg.norm, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", a_in, lp["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", a_in, lp["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", a_in, lp["attn"]["wv"])
    if not (isinstance(theta, (int, float)) and theta <= 0):
        q = nn.rope(q, positions, theta)
        k = nn.rope(k, positions, theta)
    import math as _m
    kk = attention._expand_kv(k, cfg.q_per_kv)
    vv = attention._expand_kv(v, cfg.q_per_kv)
    scale = 1.0 / _m.sqrt(cfg.head_dim)
    scores = (jnp.einsum("bqhk,bshk->bhqs", q, kk).astype(jnp.float32)
              * scale)
    if cfg.attn_softcap > 0:
        scores = nn.softcap(scores, cfg.attn_softcap)
    scores = scores + attention._mask(q.shape[1], kk.shape[1], window, True)
    probs = jax.nn.softmax(scores, axis=-1).astype(vv.dtype)
    out = jnp.einsum("bhqs,bshk->bqhk", probs, vv)
    out = jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
    h = h + out
    f_in = nn.apply_norm(lp["norm2"], h, cfg.norm, cfg.norm_eps)
    if cfg.num_experts > 0:
        h = h + moe.apply_moe(cfg, lp["moe"], f_in)
    elif cfg.d_ff > 0:
        h = h + nn.apply_mlp(lp["mlp"], f_in, cfg.act, cfg.gated_mlp)
    cache = attention.prefill_cache(cfg, spec, k, v,
                                    jnp.arange(positions.shape[1]))
    return h, cache


def _rglru_prefill_block(cfg, lp, h, positions):
    r_in = nn.apply_norm(lp["norm1"], h, cfg.norm, cfg.norm_eps)
    out, st = _rglru_prefill(cfg, lp["rglru"], r_in)
    h = h + out
    f_in = nn.apply_norm(lp["norm2"], h, cfg.norm, cfg.norm_eps)
    return h + nn.apply_mlp(lp["mlp"], f_in, cfg.act, cfg.gated_mlp), st


def _rglru_prefill(cfg, params, x):
    """Like apply_rglru but also returns the decode cache."""
    B, S, D = x.shape
    gate = jax.nn.gelu(x @ params["w_in_gate"], approximate=True)
    rec = x @ params["w_in_rec"]
    conv = params["conv_w"]
    width = conv.shape[0]
    rec_pad = jnp.pad(rec, ((0, 0), (width - 1, 0), (0, 0)))
    rec_c = sum(rec_pad[:, i:i + S, :] * conv[i] for i in range(width))
    rec_c = rec_c + params["conv_b"]
    a, b_scale = rglru._decay(params, rec_c)
    hseq = rglru.rglru_scan_ref(
        a.astype(jnp.float32),
        (b_scale * jax.nn.sigmoid(params["gate_x"]) * rec_c
         ).astype(jnp.float32))
    out = (hseq.astype(x.dtype) * gate) @ params["w_out"]
    cache = {"h": hseq[:, -1].astype(jnp.float32),
             "conv": rec[:, -(width - 1):, :]}
    return out, cache


def _ssd_prefill(cfg, params, x):
    """apply_ssd + final (conv_state, ssm_state) for decode."""
    B_, S, D = x.shape
    di = cfg.ssm_expand * D
    nh = di // cfg.ssm_headdim
    g, N = cfg.ssm_ngroups, cfg.ssm_state

    zxbcdt = x @ params["in_proj"]
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * N, 2 * di + 2 * g * N], axis=-1)
    xbc = jnp.concatenate([xs, Bm, Cm], axis=-1)
    width = cfg.ssm_conv
    conv_state = xbc[:, -(width - 1):, :]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S, :] * params["conv_w"][i]
               for i in range(width)) + params["conv_b"]
    conv = jax.nn.silu(conv)
    xs, Bm, Cm = jnp.split(conv, [di, di + g * N], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(B_, S, nh, cfg.ssm_headdim)
    y, state = ssd_forward_with_state(
        xh.astype(jnp.float32), dt.astype(jnp.float32), A,
        Bm.reshape(B_, S, g, N).astype(jnp.float32),
        Cm.reshape(B_, S, g, N).astype(jnp.float32),
        min(cfg.ssm_chunk, S))
    y = y.astype(x.dtype) + xh * params["D"][None, None, :, None]
    y = y.reshape(B_, S, di)
    y = nn.rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, {"conv": conv_state, "state": state}


def ssd_forward_with_state(x, dt, A, B, C, chunk):
    """ssd_ref variant that also returns the final ssm state
    (b, nh, N, hd) — shares all math with repro.models.ssd.ssd_ref."""
    b, s, nh, hd = x.shape
    g, N = B.shape[2], B.shape[3]
    nc = s // chunk
    rep = nh // g
    xc = x.reshape(b, nc, chunk, nh, hd)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, N), rep, axis=3)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, N), rep, axis=3)
    dA = dtc * A
    cum = jnp.cumsum(dA, axis=2)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bclhn,bcmhn->bclmh", Cc, Bc)
    y_intra = jnp.einsum("bclmh,bclmh,bcmh,bcmhp->bclhp", CB, L, dtc, xc)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    S_ = jnp.einsum("bclh,bclh,bclhn,bclhp->bchnp", decay_to_end, dtc, Bc,
                    xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def scan_fn(Sprev, inp):
        Sc, dec = inp
        return dec[:, :, None, None] * Sprev + Sc, Sprev

    S_t = jnp.moveaxis(S_, 1, 0)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)
    final, Sprev_t = jax.lax.scan(scan_fn, jnp.zeros_like(S_t[0]),
                                  (S_t, dec_t))
    Sprev = jnp.moveaxis(Sprev_t, 0, 1)
    decay_from_start = jnp.exp(cum)
    y_inter = jnp.einsum("bclhn,bclh,bchnp->bclhp", Cc, decay_from_start,
                         Sprev)
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    # final: (b, nh, N, hd) in our layout (N before hd after einsum bchnp)
    return y, final
