"""Observability: span tracing + metrics for the assimilation stack.

``repro.obs`` is a leaf subsystem (it imports nothing from the rest of
``repro``) so every layer — engine, solver, halo exchange, DyDD,
kernels — can report into it without import cycles.

  * :mod:`repro.obs.trace` — nested span tracer with thread attribution,
    device-sync fences and Chrome/Perfetto ``trace_events`` export;
    disabled by default at zero overhead (``trace.span`` is a shared
    no-op until a :class:`~repro.obs.trace.Tracer` is installed).
  * :mod:`repro.obs.meters` — process-wide counters/gauges/series/events
    registry, always on.

See ``src/repro/assim/README.md`` §Observability for the span taxonomy
and meter names.
"""
from repro.obs.trace import (  # noqa: F401
    NULL_TRACER, NullTracer, Tracer, get_tracer, jax_profile, set_tracer,
    span, tracing)
from repro.obs.meters import (  # noqa: F401
    Meters, comm_matrix, get_meters, set_meters)
