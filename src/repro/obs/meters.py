"""Counters / gauges / series registry for the assimilation stack.

One process-wide :class:`Meters` instance (swap it with
:func:`set_meters` for scoped collection) that the engine, the DD-KF
solver, the halo-exchange builder, DyDD and the gram autotuner report
into.  Everything is host-side Python on dict operations — cheap enough
to stay always-on (instruments fire per cycle / per rebalance, never per
solver iteration).

Four instrument kinds:

  * **counter** — monotonically accumulated totals
    (``inc("engine.rebalance.fired")``);
  * **gauge**   — last-written values (``gauge("engine.imbalance", x)``);
  * **series**  — append-only float lists
    (``observe("dydd.cg_residual", r)`` — per-iteration histories);
  * **event**   — timestamped structured payloads
    (``event("gram.autotune", shape=..., block_m=...)`` — the autotune
    decisions, halo-schedule builds, rebalance triggers/suppressions).

``snapshot()`` returns the whole registry as one JSON-ready dict (what
the streaming bench embeds in its report); ``reset()`` clears it.

Meter name taxonomy (dotted, subsystem-first) — the full list lives in
``src/repro/assim/README.md`` §Observability:

    engine.cycles, engine.rebalance.fired, engine.rebalance.suppressed,
    engine.migrated, engine.imbalance, engine.halo_fraction,
    engine.residual_final, engine.straggler.flags,
    solve.comm_bytes_per_cycle,
    halo.builds, halo.edges, halo.rounds,
    dydd.schedule_rounds, dydd.scheduled_movement, dydd.cg_residual,
    gram.autotune
"""
from __future__ import annotations

import json
import threading
import time
from collections import defaultdict
from typing import Optional

import numpy as np


class Meters:
    """A counters/gauges/series/events registry (thread-safe: one lock
    serializes every mutation and export — the serving layer's packing
    pool has many host threads reporting concurrently, and a read-modify-
    write like ``counters[name] += value`` is not atomic under the GIL)."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: dict = defaultdict(float)
        self.gauges: dict = {}
        self.series: dict = defaultdict(list)
        self.events: list = []

    # -- instruments --------------------------------------------------------

    def inc(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] += value

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            self.series[name].append(float(value))

    def extend(self, name: str, values) -> None:
        vals = [float(v) for v in values]
        with self._lock:
            self.series[name].extend(vals)

    def event(self, name: str, **payload) -> None:
        rec = {"name": name, "t": time.time(), **payload}
        with self._lock:
            self.events.append(rec)

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-serializable view of everything recorded so far."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "series": {k: list(v) for k, v in self.series.items()},
                "events": [dict(e) for e in self.events],
            }

    def to_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), **kw)

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.series.clear()
            self.events.clear()


_ACTIVE = Meters()


def get_meters() -> Meters:
    return _ACTIVE


def set_meters(meters: Optional[Meters]) -> Meters:
    """Install a registry (None = a fresh one); returns the previous."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = meters if meters is not None else Meters()
    return prev


# ---------------------------------------------------------------------------
# Comm-matrix helper: per-edge bytes dict -> dense (p, p) matrix.
# ---------------------------------------------------------------------------

def comm_matrix(p: int, per_edge_bytes: dict) -> np.ndarray:
    """(p, p) per-device-pair send-bytes matrix from the ``"i-j"``-keyed
    per-edge dict (:meth:`HaloExchange.edge_send_bytes` /
    ``comm_model()["per_edge_bytes"]``).

    Entry [i, j] is what device i sends to device j; the neighbour
    exchange is symmetric (both endpoints send the shared slots), so the
    matrix is too, and ``matrix.sum()`` equals the model's
    ``state_bytes_total`` at the same itemsize/iteration scaling.
    """
    M = np.zeros((p, p), dtype=np.float64)
    for key, b in per_edge_bytes.items():
        i, j = (int(v) for v in key.split("-"))
        M[i, j] += float(b)
        M[j, i] += float(b)
    return M
