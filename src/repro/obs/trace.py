"""Lightweight span tracer for the assimilation stack.

The engine's cycle loop is a pipeline of host phases (observation
counting, DyDD, halo-schedule build, operator packing) interleaved with
device work (the DD-KF solve), split across two threads under double
buffering.  This module provides the one primitive that makes all of it
visible: a nested ``span("pack")`` context manager with monotonic host
timing that exports Chrome/Perfetto ``trace_events`` JSON — open the
output at https://ui.perfetto.dev (or chrome://tracing) and every
thread/device gets its own row with the nesting rendered as stacked
slices.

Design constraints, in order:

  * **Zero overhead when disabled.**  The module-level :func:`span`
    dispatches through the active tracer; the default
    :class:`NullTracer` returns one shared no-op context manager, so a
    disabled call site costs two function calls and no allocation —
    ``tests/test_obs.py`` pins this with a micro-benchmark.  Call sites
    therefore need exactly one guarded branch: the ``with span(...)``
    statement itself.
  * **Thread-aware.**  Spans land on a per-thread track (Chrome ``tid``)
    keyed by the thread name, so the engine's double-buffered packing
    worker shows up as its own row next to the main solve thread; span
    nesting is tracked per thread (a worker's ``pack`` span never
    becomes a child of the main thread's ``solve``).
  * **Honest device timing.**  Host timestamps lie about async device
    work — a dispatched solve returns immediately.  Spans that wrap
    device work must fence: ``with span("solve") as sp: x = f();
    sp.fence(x)`` blocks on the value (``jax.block_until_ready``) before
    the span closes, so the recorded duration is the device wall time,
    not the dispatch time.  For kernel-level timelines use the
    :func:`jax_profile` passthrough instead (``--profile`` on the bench
    and the example), which wraps ``jax.profiler.trace``.

Spans with an explicit ``track=`` land on a named row instead of the
thread's — :meth:`Tracer.emit` uses this to attach per-device rows
("device 0" ... "device p-1") from timestamps observed after the fact
(per-shard ready times of a sharded solve).
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Optional


# ---------------------------------------------------------------------------
# Disabled path: shared no-op span, no allocation per call.
# ---------------------------------------------------------------------------

class _NullSpan:
    """Shared do-nothing context manager (the disabled-tracing fast path)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def annotate(self, **kw) -> None:
        pass

    def fence(self, value=None):
        return value


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The inactive tracer: every span is the shared no-op instance."""

    enabled = False

    def span(self, name: str, track: Optional[str] = None, **args):
        return _NULL_SPAN

    def emit(self, name: str, t0: float, dur: float,
             track: Optional[str] = None, **args) -> None:
        pass


NULL_TRACER = NullTracer()


# ---------------------------------------------------------------------------
# Active tracer.
# ---------------------------------------------------------------------------

class _Span:
    """One live span; records a complete ("X") event on exit."""

    __slots__ = ("_tracer", "name", "track", "args", "_t0", "_fence")

    def __init__(self, tracer: "Tracer", name: str, track: Optional[str],
                 args: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self._t0 = 0.0
        self._fence = None

    def __enter__(self):
        tracer = self._tracer
        if self.track is None:
            self.track = threading.current_thread().name
        stack = tracer._stack()
        self.args.setdefault("depth", len(stack))
        if stack:
            self.args.setdefault("parent", stack[-1].name)
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        if self._fence is not None:
            _block(self._fence)
            self._fence = None
        t1 = time.perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record(self.name, self._t0, t1 - self._t0,
                             self.track, self.args)
        return False

    def annotate(self, **kw) -> None:
        """Attach JSON-serializable key/values to the span's args."""
        self.args.update(kw)

    def fence(self, value):
        """Register a device value to ``jax.block_until_ready`` at span
        exit, so the span's duration includes the device work that
        produced it.  Returns the value unchanged."""
        self._fence = value
        return value


def _block(value):
    import jax
    return jax.block_until_ready(value)


class Tracer:
    """Span recorder with Chrome ``trace_events`` export.

    Thread safe: each thread keeps its own nesting stack (thread-local)
    and completed events append under a lock.  ``time.perf_counter`` is
    the clock — monotonic and shared across threads, so cross-thread
    span overlap in the exported trace reflects real concurrency.
    """

    enabled = True

    def __init__(self, process_name: str = "repro"):
        self.process_name = process_name
        self.events: list = []          # (name, t0, dur, track, args)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._epoch = time.perf_counter()

    # -- recording ----------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, track: Optional[str] = None, **args):
        """Context manager timing a nested span on this thread's track
        (or an explicit ``track=`` row)."""
        return _Span(self, name, track, args)

    def emit(self, name: str, t0: float, dur: float,
             track: Optional[str] = None, **args) -> None:
        """Record an already-measured span (``t0`` in perf_counter
        seconds) — how per-device rows are attached after the fact."""
        if track is None:
            track = threading.current_thread().name
        self._record(name, t0, dur, track, args)

    def _record(self, name: str, t0: float, dur: float, track: str,
                args: dict) -> None:
        with self._lock:
            self.events.append((name, t0, dur, track, args))

    # -- queries ------------------------------------------------------------

    def spans(self, name: Optional[str] = None) -> list:
        """Completed spans as dicts (filtered by name if given)."""
        with self._lock:
            evs = list(self.events)
        out = [{"name": n, "t0": t0, "dur": dur, "track": tr,
                "args": dict(a)} for n, t0, dur, tr, a in evs]
        if name is not None:
            out = [e for e in out if e["name"] == name]
        return out

    def total_duration(self, name: str) -> float:
        """Summed duration (s) of all spans with this name."""
        return sum(e["dur"] for e in self.spans(name))

    def coverage(self, name: str, wall: float) -> float:
        """Fraction of ``wall`` seconds covered by spans named ``name``
        (the acceptance metric: cycle spans vs measured wall-clock)."""
        return self.total_duration(name) / wall if wall > 0 else 0.0

    # -- export -------------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        """Chrome/Perfetto ``trace_events`` JSON object.

        Complete ("X") events with microsecond timestamps relative to
        the tracer's epoch; one ``tid`` per track with a thread_name
        metadata record so Perfetto labels the rows.  Track order:
        "main" first, then the worker threads, then the device rows.
        """
        with self._lock:
            evs = list(self.events)
        tracks: dict = {}

        def tid_of(track: str) -> int:
            if track not in tracks:
                tracks[track] = len(tracks)
            return tracks[track]

        # Deterministic row order regardless of event arrival order.
        def track_key(t: str):
            if t in ("main", "MainThread"):
                return (0, t)
            if t.startswith("device"):
                return (2, t)
            return (1, t)

        for t in sorted({e[3] for e in evs}, key=track_key):
            tid_of(t)

        events = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": self.process_name}},
        ]
        for track, tid in tracks.items():
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tid, "args": {"name": track}})
            events.append({"ph": "M", "name": "thread_sort_index",
                           "pid": 0, "tid": tid,
                           "args": {"sort_index": tid}})
        for name, t0, dur, track, args in evs:
            events.append({
                "ph": "X", "name": name, "pid": 0, "tid": tid_of(track),
                "ts": (t0 - self._epoch) * 1e6,
                "dur": dur * 1e6,
                "cat": "repro",
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)


# ---------------------------------------------------------------------------
# Active-tracer plumbing (the one guarded branch per call site).
# ---------------------------------------------------------------------------

_ACTIVE: "Tracer | NullTracer" = NULL_TRACER


def get_tracer() -> "Tracer | NullTracer":
    return _ACTIVE


def set_tracer(tracer: "Tracer | NullTracer | None"):
    """Install the process-wide tracer (None = disable).  Returns the
    previous tracer so callers can restore it."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer if tracer is not None else NULL_TRACER
    return prev


@contextlib.contextmanager
def tracing(tracer: "Tracer | NullTracer | None"):
    """Scoped ``set_tracer``: installs for the block, restores after."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


def span(name: str, track: Optional[str] = None, **args):
    """Record a span on the active tracer — a shared no-op when tracing
    is disabled (the call sites' single guarded branch)."""
    return _ACTIVE.span(name, track=track, **args)


def emit(name: str, t0: float, dur: float, track: Optional[str] = None,
         **args) -> None:
    _ACTIVE.emit(name, t0, dur, track=track, **args)


@contextlib.contextmanager
def jax_profile(logdir: Optional[str]):
    """Optional ``jax.profiler.trace`` passthrough: profiles the block
    into ``logdir`` (TensorBoard/XPlane format) when a directory is
    given and the profiler is available; a silent no-op otherwise."""
    if not logdir:
        yield None
        return
    try:
        import jax
        ctx = jax.profiler.trace(logdir)
    except Exception:                     # profiler unavailable: no-op
        yield None
        return
    with ctx:
        yield logdir
