"""Optimizers, LR schedules, gradient clipping/accumulation/compression."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_step  # noqa
from repro.optim.schedule import make_schedule  # noqa: F401
from repro.optim import compress  # noqa: F401
