"""AdamW with decoupled weight decay, global-norm clipping and f32 master
moments (params may be bf16; moments are always f32, the standard
mixed-precision layout whose sharding follows the parameter specs)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    accum_steps: int = 1     # gradient accumulation (microbatching)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_step(cfg: AdamWConfig, grads, opt_state, params,
               lr: jax.Array | float | None = None):
    """One AdamW update.  Returns (new_params, new_opt_state, grad_norm)."""
    lr = cfg.lr if lr is None else lr
    grads, norm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, norm
