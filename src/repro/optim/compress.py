"""int8 error-feedback gradient compression (distributed-optimization
trick; DESIGN.md §8).

Per-tensor symmetric int8 quantization with an error-feedback buffer: the
quantization residual is added back into the next step's gradient, so the
compressed SGD trajectory converges like the uncompressed one (Karimireddy
et al., 2019).  ``compressed_psum`` is the shard_map building block that
halves (bf16) or quarters (f32) the gradient all-reduce bytes; the runtime
exposes it via ``runtime.steps.make_train_step(..., compress_grads=True)``
for shard_map-based data parallelism.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array):
    """x (f32/bf16) -> (int8 values, f32 scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(grad: jax.Array, error: jax.Array):
    """Returns (q, scale, new_error).  new_error = (g + e) - dequant(q)."""
    g = grad.astype(jnp.float32) + error
    q, scale = quantize(g)
    new_error = g - dequantize(q, scale)
    return q, scale, new_error


def compressed_psum(grad: jax.Array, error: jax.Array, axis_name: str):
    """All-reduce a gradient in int8 with error feedback.

    Inside shard_map: quantize locally, psum the int8 payload (XLA upcasts
    the accumulator — wire bytes are the int8 tensor), dequantize with the
    max scale.  Returns (mean_grad, new_error).
    """
    q, scale, new_error = compress_with_feedback(grad, error)
    n = jax.lax.psum(1, axis_name)
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)
    return (summed.astype(jnp.float32) * scale_max / n).astype(grad.dtype), \
        new_error


def init_error_buffers(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)
