"""Learning-rate schedules (linear warmup + cosine/linear/constant decay)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def make_schedule(kind: str, peak_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.1):
    """Returns step -> lr (jnp-traceable)."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0,
                        1.0)
        if kind == "cosine":
            decay = peak_lr * (final_frac + (1 - final_frac)
                               * 0.5 * (1 + jnp.cos(math.pi * frac)))
        elif kind == "linear":
            decay = peak_lr * (1.0 - (1 - final_frac) * frac)
        else:
            decay = jnp.asarray(peak_lr)
        return jnp.where(step < warmup_steps, warm, decay)

    return fn
