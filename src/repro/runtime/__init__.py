"""Distributed runtime: sharding rules, step factories, fault tolerance."""
