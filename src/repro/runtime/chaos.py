"""Deterministic chaos injection for the fault-tolerance stack.

Long-running assimilation services die in exactly the ways that are
hardest to reproduce: a process SIGKILLed mid-stream, a device that
starts straggling, a checkpoint torn by a crash mid-write, a transient
packing/solve error from a flaky host.  This module makes every one of
those failures *schedulable*: a :class:`ChaosInjector` derives a fault
schedule deterministically from ``ChaosConfig.seed``, so the same seed
produces the same kills, the same stragglers and the same transient
faults on every run — which is what lets tests assert bitwise journal
equality between a chaos run and its replay, and lets a kill-and-resume
CI job re-create the exact crash it is recovering from.

Injection sites (all opt-in, all journalled as ``repro.obs`` events
under ``chaos.*``):

  * **kill points** — ``maybe_kill(site, cycle)`` SIGKILLs the process
    at configured cycles (no cleanup handlers run: the honest crash);
  * **transient faults** — ``check(site, cycle)`` raises
    :class:`TransientFault` at scheduled ``(site, cycle)`` points; the
    engine/fleet retry-with-backoff paths treat it as retryable.  The
    engine calls the ``"pack"`` site *before* any state mutation, so a
    retried prepare is bitwise-identical to an uninjected one;
  * **forced stragglers** — ``straggle(cycle, device_times)`` inflates
    the configured device's reported shard-ready time by
    ``straggle_factor`` at scheduled cycles, driving the PR 6
    EWMA-deadline :class:`~repro.runtime.straggler.StragglerMonitor`
    without touching the solve itself (analyses stay bitwise);
  * **torn checkpoints** — :func:`tear_checkpoint` /
    :func:`corrupt_manifest` fabricate the half-written states a killed
    writer leaves behind, for exercising ``latest_checkpoint``'s
    hash-verified fallback.

The injector is host-side bookkeeping only; nothing here touches jax.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Optional

import numpy as np

from repro.obs import meters as meters_mod


class TransientFault(RuntimeError):
    """A retryable injected failure (flaky host, transient OOM, lost
    RPC).  Retry paths back off and re-attempt; anything else raised
    from a prepare/solve is treated as fatal for that stream."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Schedule parameters for one :class:`ChaosInjector`.

    Explicit cycle tuples (``kill_cycles``/``straggle_cycles``/
    ``pack_fault_cycles``/``solve_fault_cycles``) pin faults to exact
    cycles; the ``*_fault_rate`` knobs draw additional per-cycle faults
    Bernoulli(seeded) over ``max_cycle`` cycles at construction time —
    the schedule is fixed before the first cycle runs, never sampled
    on the fly, which is what makes a chaos run replayable.
    """

    seed: int = 0
    max_cycle: int = 4096            # horizon the random schedule covers
    kill_cycles: tuple = ()          # SIGKILL the process after these
                                     # cycles complete (site "cycle_end")
    pack_fault_cycles: tuple = ()    # transient faults at prepare entry
    solve_fault_cycles: tuple = ()   # transient faults at solve dispatch
    pack_fault_rate: float = 0.0     # extra Bernoulli pack faults
    solve_fault_rate: float = 0.0    # extra Bernoulli solve faults
    straggle_cycles: tuple = ()      # cycles with a forced straggler
    straggle_device: int = 0         # which device straggles
    straggle_factor: float = 50.0    # reported time multiplier
    fail_every_attempt: bool = False  # if True, a scheduled fault fires
                                     # on retries too (exhausts bounded
                                     # retry); default fires once, so
                                     # the first retry succeeds


class ChaosInjector:
    """Seeded fault injector with a precomputed, replayable schedule.

    One injector serves one stream/engine.  ``schedule()`` exposes the
    full precomputed plan as a JSON-ready dict (the determinism tests
    compare two injectors' schedules and injection logs); every firing
    is appended to ``self.injections`` (timestamp-free) and emitted as
    a ``chaos.inject`` event on the active meters registry.
    """

    def __init__(self, config: ChaosConfig | None = None):
        self.cfg = config or ChaosConfig()
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        # Draw both rate-based schedules unconditionally (and in a fixed
        # order) so adding one rate never shifts the other's draws.
        pack_draw = rng.random(cfg.max_cycle) < cfg.pack_fault_rate
        solve_draw = rng.random(cfg.max_cycle) < cfg.solve_fault_rate
        self._faults = {
            "pack": set(int(c) for c in cfg.pack_fault_cycles)
            | set(np.where(pack_draw)[0].tolist()),
            "solve": set(int(c) for c in cfg.solve_fault_cycles)
            | set(np.where(solve_draw)[0].tolist()),
        }
        self._kills = set(int(c) for c in cfg.kill_cycles)
        self._straggles = set(int(c) for c in cfg.straggle_cycles)
        self._fired: set = set()     # (site, cycle) already injected
        self.injections: list = []   # timestamp-free firing log

    # -- schedule introspection --------------------------------------------

    def schedule(self) -> dict:
        """The full precomputed plan, JSON-serializable (for determinism
        assertions and bench reports)."""
        return {
            "seed": int(self.cfg.seed),
            "kill_cycles": sorted(self._kills),
            "pack_fault_cycles": sorted(self._faults["pack"]),
            "solve_fault_cycles": sorted(self._faults["solve"]),
            "straggle_cycles": sorted(self._straggles),
            "straggle_device": int(self.cfg.straggle_device),
            "straggle_factor": float(self.cfg.straggle_factor),
        }

    def _log(self, site: str, cycle: int, **extra) -> None:
        rec = {"site": site, "cycle": int(cycle), **extra}
        self.injections.append(rec)
        meters_mod.get_meters().event("chaos.inject", **rec)
        meters_mod.get_meters().inc(f"chaos.injected.{site}")

    # -- injection sites ----------------------------------------------------

    def check(self, site: str, cycle: int) -> None:
        """Raise :class:`TransientFault` if a fault is scheduled at
        ``(site, cycle)``.  Fires once per point unless
        ``fail_every_attempt`` — so a bounded retry observes exactly one
        failure and then succeeds."""
        if cycle not in self._faults.get(site, ()):
            return
        key = (site, int(cycle))
        if key in self._fired and not self.cfg.fail_every_attempt:
            return
        self._fired.add(key)
        self._log(site, cycle, kind="transient_fault")
        raise TransientFault(f"injected transient {site} fault at "
                             f"cycle {cycle}")

    def maybe_kill(self, site: str, cycle: int) -> None:
        """SIGKILL the process if a kill point is scheduled at this
        cycle.  SIGKILL on purpose: no atexit/finally runs, exactly
        like the OOM-killer or a preempted host."""
        if cycle not in self._kills:
            return
        self._log(site, cycle, kind="kill")
        os.kill(os.getpid(), signal.SIGKILL)

    def straggle(self, cycle: int, device_times: list) -> list:
        """Inflate the scheduled device's reported time at straggle
        cycles (returns a new list; the input is never mutated).  Only
        the *reported* timing changes — the solve already happened —
        so analyses stay bitwise while the EWMA-deadline monitor sees
        a genuinely late device."""
        if cycle not in self._straggles or not device_times:
            return list(device_times)
        out = list(device_times)
        dev = min(self.cfg.straggle_device, len(out) - 1)
        out[dev] = float(out[dev]) * float(self.cfg.straggle_factor)
        self._log("straggle", cycle, device=int(dev),
                  factor=float(self.cfg.straggle_factor))
        return out


# ---------------------------------------------------------------------------
# Torn/corrupt checkpoint fabrication (what a killed writer leaves).
# ---------------------------------------------------------------------------

def tear_checkpoint(path: str, seed: int = 0) -> str:
    """Truncate one leaf ``.npy`` of a finalized checkpoint mid-bytes —
    the state a crash leaves when the rename landed but a leaf write
    didn't make it to disk (or the disk lied about durability).
    Returns the truncated file's path."""
    rng = np.random.default_rng(seed)
    leaves = sorted(f for f in os.listdir(path) if f.endswith(".npy"))
    if not leaves:
        raise FileNotFoundError(f"no leaf arrays under {path}")
    victim = os.path.join(path, leaves[int(rng.integers(len(leaves)))])
    size = os.path.getsize(victim)
    keep = int(rng.integers(1, max(size, 2)))
    with open(victim, "rb+") as f:
        f.truncate(keep)
    return victim


def corrupt_manifest(path: str, seed: int = 0) -> str:
    """Flip bytes in the middle of ``manifest.json`` — a torn metadata
    write.  Returns the manifest path."""
    rng = np.random.default_rng(seed)
    manifest = os.path.join(path, "manifest.json")
    data = bytearray(open(manifest, "rb").read())
    if not data:
        raise ValueError(f"empty manifest at {manifest}")
    for _ in range(max(len(data) // 8, 1)):
        data[int(rng.integers(len(data)))] = int(rng.integers(256))
    with open(manifest, "wb") as f:
        f.write(bytes(data))
    return manifest


# ---------------------------------------------------------------------------
# Bounded retry-with-backoff (shared by the engine and the fleet).
# ---------------------------------------------------------------------------

def retry_transient(fn, *, retries: int = 2, backoff: float = 0.05,
                    site: str = "solve", cycle: int = -1,
                    sleep=time.sleep):
    """Call ``fn()``; on :class:`TransientFault`, back off exponentially
    and retry up to ``retries`` times (``backoff * 2**attempt`` seconds),
    emitting a ``chaos.retry`` event per re-attempt.  Any other
    exception — and a fault that outlives the retry budget — propagates
    to the caller's fatal path."""
    m = meters_mod.get_meters()
    for attempt in range(retries + 1):
        try:
            return fn()
        except TransientFault:
            if attempt >= retries:
                raise
            delay = backoff * (2.0 ** attempt)
            m.event("chaos.retry", site=site, cycle=int(cycle),
                    attempt=attempt + 1, delay=delay)
            m.inc("chaos.retries")
            sleep(delay)
