"""Elastic scaling: resume a run on a different device population.

Checkpoints are mesh-agnostic (checkpoint/manager stores full logical
arrays), so scaling is: build the new mesh -> recompute PartitionSpecs ->
``restore_pytree`` with the new NamedShardings -> continue.  The global
batch is re-split over the new data-parallel width; the DyDD data balancer
re-plans on the new ring automatically (its topology is a constructor
argument).

``remesh`` below is the single entry point; it is exercised in tests by
saving under a (2,2) forced-host mesh and restoring under (4,1)/(1,2).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding

from repro.checkpoint import manager as ckpt
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.runtime import steps as steps_mod


def named_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def remesh(cfg: ModelConfig, checkpoint_dir: str, new_mesh,
           dtype=None):
    """Restore (params, opt_state, metadata) re-sharded onto ``new_mesh``.

    Returns (params, opt_state, manifest). Raises FileNotFoundError if no
    valid checkpoint exists (caller then cold-starts).
    """
    with jax.sharding.set_mesh(new_mesh):
        shapes = {
            "params": transformer.param_shapes(cfg, dtype=dtype),
        }
        pspecs = transformer.param_specs(cfg)
        ospecs = steps_mod.opt_specs(cfg)
        import jax.numpy as jnp
        opt_shapes = {
            "m": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                shapes["params"]),
            "v": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                shapes["params"]),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        like = {"params": shapes["params"], "opt": opt_shapes}
        shard_tree = {
            "params": named_shardings(new_mesh, pspecs),
            "opt": named_shardings(new_mesh, ospecs),
        }
        path = ckpt.latest_checkpoint(checkpoint_dir)
        if path is None:
            raise FileNotFoundError(checkpoint_dir)
        tree, manifest = ckpt.restore_pytree(path, like=like,
                                             shardings=shard_tree)
    return tree["params"], tree["opt"], manifest
