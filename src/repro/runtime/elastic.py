"""Elastic scaling: resume a run on a different device population.

Checkpoints are mesh-agnostic (checkpoint/manager stores full logical
arrays), so scaling is: build the new mesh -> recompute PartitionSpecs ->
``restore_pytree`` with the new NamedShardings -> continue.  The global
batch is re-split over the new data-parallel width; the DyDD data balancer
re-plans on the new ring automatically (its topology is a constructor
argument).

Two entry points:

  * ``remesh`` — the transformer training path (params/opt re-shard);
  * ``resume_assim_engine`` — the assimilation path: restore an
    :class:`~repro.assim.engine.AssimilationEngine` from its snapshot
    and, when the requested subdomain count p′ differs from the saved
    p, *re-derive the domain decomposition for p′* from the load
    history the journal recorded (``remesh_assim_domain``): the k-d
    tree warm-starts a rebuild from a synthetic density cloud, the
    interval/shelf tilings re-cut their edges at the quantiles of the
    journalled piecewise-constant observation density.  Either way the
    stream continues from the saved cursor — no completed cycle is
    ever replayed.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import numpy as np
import jax
from jax.sharding import NamedSharding

from repro.checkpoint import manager as ckpt
from repro.core import domain as domain_mod
from repro.core import kdtree as kdtree_mod
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.runtime import steps as steps_mod


def named_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def remesh(cfg: ModelConfig, checkpoint_dir: str, new_mesh,
           dtype=None):
    """Restore (params, opt_state, metadata) re-sharded onto ``new_mesh``.

    Returns (params, opt_state, manifest). Raises FileNotFoundError if no
    valid checkpoint exists (caller then cold-starts).
    """
    with jax.sharding.set_mesh(new_mesh):
        shapes = {
            "params": transformer.param_shapes(cfg, dtype=dtype),
        }
        pspecs = transformer.param_specs(cfg)
        ospecs = steps_mod.opt_specs(cfg)
        import jax.numpy as jnp
        opt_shapes = {
            "m": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                shapes["params"]),
            "v": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                shapes["params"]),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        like = {"params": shapes["params"], "opt": opt_shapes}
        shard_tree = {
            "params": named_shardings(new_mesh, pspecs),
            "opt": named_shardings(new_mesh, ospecs),
        }
        path = ckpt.latest_checkpoint(checkpoint_dir)
        if path is None:
            raise FileNotFoundError(checkpoint_dir)
        tree, manifest = ckpt.restore_pytree(path, like=like,
                                             shardings=shard_tree)
    return tree["params"], tree["opt"], manifest


# ---------------------------------------------------------------------------
# Assimilation-engine elastic resume (remesh on p change).
# ---------------------------------------------------------------------------

def rebalanced_edges(edges, loads, new_p: int) -> np.ndarray:
    """Re-cut a 1D tiling for a new subdomain count from its load
    history: the journalled ``loads`` define a piecewise-constant
    observation density over the old ``edges``; the new edges sit at
    the ``new_p``-quantiles of that density (piecewise-linear inverse
    CDF via ``np.interp``).  Zero total mass falls back to uniform."""
    edges = np.asarray(edges, np.float64)
    loads = np.asarray(loads, np.float64)
    total = float(loads.sum())
    if total <= 0.0:
        return np.linspace(edges[0], edges[-1], new_p + 1)
    cum = np.concatenate([[0.0], np.cumsum(loads)])
    out = np.interp(np.linspace(0.0, total, new_p + 1), cum, edges)
    out[0], out[-1] = edges[0], edges[-1]
    return out


def _merged_x_density(x_edges: np.ndarray, cell_loads: np.ndarray,
                      weights: np.ndarray) -> tuple:
    """(breakpoints, per-segment masses) of the y-overlap-weighted
    combination of the old strips' x densities — the 1D density a new
    strip sees when it spans fractions ``weights[r]`` of old strips."""
    bps = np.unique(np.asarray(x_edges, np.float64).reshape(-1))
    seg_lo, seg_hi = bps[:-1], bps[1:]
    dens = np.zeros(seg_lo.shape[0])
    for r in range(x_edges.shape[0]):
        if weights[r] <= 0.0:
            continue
        for c in range(cell_loads.shape[1]):
            lo, hi = x_edges[r, c], x_edges[r, c + 1]
            if hi <= lo:
                continue
            inside = (seg_lo >= lo) & (seg_hi <= hi)
            dens[inside] += weights[r] * cell_loads[r, c] / (hi - lo)
    return bps, dens * (seg_hi - seg_lo)


def _shelf_grid(p: int, pr_old: int, pr: Optional[int],
                pc: Optional[int]) -> tuple:
    """(pr', pc') for a p-subdomain shelf: explicit values win, else the
    largest divisor of p not exceeding the old strip count (shrinking p
    keeps the strip granularity rather than collapsing to one row)."""
    if pr is not None or pc is not None:
        pr = pr if pr is not None else p // pc
        pc = pc if pc is not None else p // pr
        if pr * pc != p:
            raise ValueError(f"pr*pc = {pr}*{pc} != p = {p}")
        return pr, pc
    best = 1
    for d in range(1, min(pr_old, p) + 1):
        if p % d == 0:
            best = d
    return best, p // best


def remesh_assim_domain(meta: dict, flat: dict, p: int,
                        pr: Optional[int] = None,
                        pc: Optional[int] = None) -> tuple:
    """Derive a (domain, config) pair for a new subdomain count from an
    engine snapshot's metadata + array tree.

    The observation-count history lives in the journal: the last
    record's post-repartition ``loads`` against the saved boundary
    state are the best density estimate the snapshot holds, and every
    domain kind re-tiles from them — interval/shelf by quantile
    re-cutting (:func:`rebalanced_edges`), the k-d tree by a
    warm-started rebuild over a synthetic density cloud (one point per
    journalled observation, placed on the old leaf's mesh-cell
    centres).  With no journalled cycles the new domain starts from its
    default even tiling.
    """
    from repro.assim.engine import EngineConfig

    desc = meta["domain"]
    kind = desc["kind"]
    saved_cfg = EngineConfig(**meta["config"])
    records = meta.get("journal", {}).get("records", [])
    loads = (np.asarray(records[-1]["loads"], np.float64)
             if records else None)

    if kind == "interval1d":
        cfg = dataclasses.replace(saved_cfg, p=p)
        if loads is None:
            return domain_mod.Interval1D(n=desc["n"], p=p), cfg
        edges = rebalanced_edges(np.asarray(flat["domain/boundaries"]),
                                 loads, p)
        return domain_mod.Interval1D(n=desc["n"], p=p,
                                     boundaries=edges), cfg

    if kind == "shelf2d":
        new_pr, new_pc = _shelf_grid(p, desc["pr"], pr, pc)
        cfg = dataclasses.replace(saved_cfg, p=p, pr=new_pr, pc=new_pc)
        dom = domain_mod.ShelfTiling2D(nx=desc["nx"], ny=desc["ny"],
                                       pr=new_pr, pc=new_pc)
        if loads is None:
            return dom, cfg
        y_edges = np.asarray(flat["domain/y_edges"], np.float64)
        x_edges = np.asarray(flat["domain/x_edges"], np.float64)
        cell_loads = loads.reshape(desc["pr"], desc["pc"])
        new_y = rebalanced_edges(y_edges, cell_loads.sum(axis=1), new_pr)
        new_x = np.empty((new_pr, new_pc + 1))
        for s in range(new_pr):
            lo, hi = new_y[s], new_y[s + 1]
            # Fraction of each old strip the new strip covers in y.
            over = (np.minimum(hi, y_edges[1:])
                    - np.maximum(lo, y_edges[:-1]))
            spans = np.maximum(y_edges[1:] - y_edges[:-1], 1e-300)
            w = np.clip(over, 0.0, None) / spans
            bps, masses = _merged_x_density(x_edges, cell_loads, w)
            new_x[s] = rebalanced_edges(bps, masses, new_pc)
        dom.load_state({"y_edges": new_y, "x_edges": new_x,
                        "y_tie_ranks": np.zeros(max(new_pr - 1, 0),
                                                np.int64),
                        "x_tie_ranks": np.zeros((new_pr,
                                                 max(new_pc - 1, 0)),
                                                np.int64)})
        return dom, cfg

    if kind == "kdtree":
        cfg = dataclasses.replace(saved_cfg, p=p)
        dom = kdtree_mod.KDTreeDomain(nx=desc["nx"], ny=desc["ny"], p=p)
        if loads is None or loads.sum() <= 0:
            return dom, cfg
        old = kdtree_mod.KDTreeDomain(nx=desc["nx"], ny=desc["ny"],
                                      p=desc["p"],
                                      rects=np.asarray(
                                          flat["domain/rects"]))
        pts = []
        for i, rect in enumerate(old.rects):
            li = int(loads[i])
            if li <= 0:
                continue
            ix0, ix1, iy0, iy1 = old._cell_ranges(rect)
            if ix1 <= ix0 or iy1 <= iy0:
                continue
            cx = (np.arange(ix0, ix1) + 0.5) / desc["nx"]
            cy = (np.arange(iy0, iy1) + 0.5) / desc["ny"]
            grid = np.stack(
                [np.repeat(cx, cy.size), np.tile(cy, cx.size)], axis=1)
            # Cycle the leaf's cell centres until the leaf's journalled
            # mass is reproduced (row pairs stay aligned: the row length
            # 2 divides the resized buffer evenly).
            pts.append(np.resize(grid, (li, 2)))
        if pts:
            dom.rebalance(np.concatenate(pts, axis=0))
        return dom, cfg

    raise ValueError(f"cannot remesh domain kind {kind!r}")


def resume_assim_engine(checkpoint: str, *, p: Optional[int] = None,
                        pr: Optional[int] = None,
                        pc: Optional[int] = None,
                        mesh=None, mesh_axis=None, forecast=None,
                        straggler_config=None, chaos=None) -> tuple:
    """Restore an assimilation engine (elastically if ``p`` differs)
    and its stream continuation.

    ``checkpoint`` is a checkpoint directory (latest verified step wins;
    torn checkpoints are skipped by hash verification) or a specific
    ``step_XXXX`` path.  With ``p`` omitted or equal to the saved
    subdomain count this is an exact bitwise resume; otherwise the
    domain is re-derived for the new p (:func:`remesh_assim_domain`)
    while truth/rng/analysis/journal/cursor carry over.  Returns
    ``(engine, stream)`` — ``stream`` is the fast-forwarded
    :class:`~repro.assim.streams.ResumableStream` (None if the snapshot
    was taken without a cursor-bearing stream); no completed cycle is
    replayed either way.
    """
    from repro.assim.engine import AssimilationEngine

    path = checkpoint
    if not os.path.basename(path).startswith("step_"):
        path = ckpt.latest_checkpoint(checkpoint)
        if path is None:
            raise FileNotFoundError(f"no verified checkpoint under "
                                    f"{checkpoint}")
    kw = dict(mesh=mesh, mesh_axis=mesh_axis, forecast=forecast,
              straggler_config=straggler_config, chaos=chaos)
    flat, manifest = ckpt.restore_pytree(path)
    meta = manifest["metadata"]
    saved_p = int(meta["domain"]["p"])
    if p is None or (p == saved_p and pr is None and pc is None):
        eng = AssimilationEngine.restore(path, **kw)
    else:
        domain, cfg = remesh_assim_domain(meta, flat, p, pr=pr, pc=pc)
        eng = AssimilationEngine.restore(path, config=cfg,
                                         domain=domain, **kw)
    return eng, eng.resume_stream()
