"""Shared continuous-batching scheduler: FIFO queue + fixed slot table.

One admission/retirement engine for every serving surface in the repo:
the LM driver (:mod:`repro.launch.serve` admits prompt requests in
waves) and the assimilation fleet (:mod:`repro.assim.serving` keeps N
streams in flight through batched cohort solves).  Both need the same
small mechanism — a bounded table of *slots* holding in-flight work, a
FIFO queue of work waiting for a slot, and admit/retire transitions that
never disturb the other occupants — so it lives here once.

The scheduler is bookkeeping only: it never touches devices and holds
opaque payloads.  Callers decide *when* to admit (each fleet round, each
LM wave) and what a payload means.  Telemetry rides along on the active
:class:`~repro.obs.meters.Meters`: a ``<prefix>queue_depth`` /
``<prefix>active`` gauge pair updated on every transition plus
``<prefix>admit`` / ``<prefix>retire`` events carrying the slot id —
the serving dashboards are built from exactly these.

Thread-safety: all transitions take one internal lock, so producers may
``submit`` from worker threads while a driver loop admits/retires.
"""
from __future__ import annotations

import itertools
import threading
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from repro.obs import meters as meters_mod


class SlotScheduler:
    """Fixed-capacity slot table with a FIFO admission queue.

    ``capacity=None`` means unbounded (every submission is admissible
    immediately — the fleet's "run everything" mode); a positive integer
    bounds the number of in-flight payloads, with the rest parked in
    arrival order.  Slot ids are stable for the lifetime of an occupancy
    and are recycled lowest-first after retirement, so a capacity-k
    scheduler only ever hands out ids ``0..k-1`` — which is what lets
    the fleet treat a slot id as a position in a bounded batch.
    """

    def __init__(self, capacity: Optional[int] = None,
                 meters_prefix: str = "sched."):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None (unbounded), "
                             f"got {capacity}")
        self.capacity = capacity
        self._prefix = meters_prefix
        self._lock = threading.Lock()
        self._queue: deque = deque()          # (seq, payload) FIFO
        self._slots: Dict[int, Any] = {}      # slot id -> payload
        self._free: List[int] = []            # recycled slot ids (heapless:
                                              # sorted on retire, popped
                                              # lowest-first)
        self._next_slot = 0
        self._seq = itertools.count()
        self._submitted = 0
        self._retired = 0

    # -- transitions -------------------------------------------------------

    def submit(self, payload: Any) -> None:
        """Park a payload on the admission queue (FIFO)."""
        with self._lock:
            self._queue.append((next(self._seq), payload))
            self._submitted += 1
            self._gauges_locked()

    def admit(self, max_new: Optional[int] = None) -> List[Tuple[int, Any]]:
        """Move queued payloads into free slots, in arrival order.

        Returns the newly admitted ``(slot, payload)`` pairs (possibly
        empty).  Admission stops at the capacity bound and, if given, at
        ``max_new`` admissions — the LM driver uses the latter to shape
        waves smaller than the table.
        """
        out: List[Tuple[int, Any]] = []
        m = meters_mod.get_meters()
        with self._lock:
            while self._queue:
                if max_new is not None and len(out) >= max_new:
                    break
                if self.capacity is not None \
                        and len(self._slots) >= self.capacity:
                    break
                _, payload = self._queue.popleft()
                if self._free:
                    slot = self._free.pop(0)
                else:
                    slot = self._next_slot
                    self._next_slot += 1
                self._slots[slot] = payload
                out.append((slot, payload))
            self._gauges_locked()
        for slot, _ in out:
            m.event(self._prefix + "admit", slot=slot)
        return out

    def retire(self, slot: int) -> Any:
        """Free a slot; returns its payload.  The slot id becomes
        reusable by the next :meth:`admit`."""
        with self._lock:
            if slot not in self._slots:
                raise KeyError(f"slot {slot} is not occupied")
            payload = self._slots.pop(slot)
            self._free.append(slot)
            self._free.sort()
            self._retired += 1
            self._gauges_locked()
        meters_mod.get_meters().event(self._prefix + "retire", slot=slot)
        return payload

    # -- views -------------------------------------------------------------

    def active(self) -> Dict[int, Any]:
        """Snapshot of occupied slots (slot id -> payload)."""
        with self._lock:
            return dict(self._slots)

    def queue_depth(self) -> int:
        with self._lock:
            return len(self._queue)

    def idle(self) -> bool:
        """True when nothing is queued and nothing is in flight."""
        with self._lock:
            return not self._queue and not self._slots

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"submitted": self._submitted,
                    "retired": self._retired,
                    "active": len(self._slots),
                    "queued": len(self._queue)}

    def _gauges_locked(self) -> None:
        m = meters_mod.get_meters()
        m.gauge(self._prefix + "queue_depth", len(self._queue))
        m.gauge(self._prefix + "active", len(self._slots))
