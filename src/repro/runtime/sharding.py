"""Logical-axis sharding rules (GSPMD partitioning for the production mesh).

Parameters and activations are annotated with *logical* axis names; this
module maps them onto whatever physical mesh axes exist (pod/data/model).
Rules (DESIGN.md §8):

  * weights' d_model-like dims  -> 'data'  (ZeRO-3/FSDP, per-pod)
  * heads / d_ff / vocab dims   -> 'model' (tensor parallel)
  * activation batch            -> ('pod', 'data')  (pure DP across pods)
  * expert dim                  -> replicated (TP shards each expert's d_ff;
                                   see DESIGN.md §4 for the DyDD/EP view)

``shard(x, *axes)`` is a no-op when no mesh is active, so model code runs
unchanged in single-device tests.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P


# logical axis -> physical mesh axis (or tuple).  None = replicated.
# Profile "tp": FSDP on 'data' + tensor parallel on 'model' (big archs).
PARAM_RULES_TP = {
    "embed": "data",        # FSDP dim
    "embed_table": "data",  # embedding d_model dim (FSDP in tp profile)
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "expert": None,
    "moe_expert": "model",   # EP: whole experts on the model axis
    "lru": "model",
    "ssm_inner": "model",
    None: None,
}

ACT_RULES_TP = {
    "kv_seq": "model",   # decode-cache sequence sharding (long context)
    "loss_batch": ("pod", "data"),  # loss chunks: leave 'model' for vocab
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "expert": None,
    "moe_expert": "model",
    "lru": "model",
    "ssm_inner": "model",
    None: None,
}

# Profile "dp": pure data parallelism over every mesh axis + FSDP on
# 'data'.  The right mapping for small-d_model / indivisible-head archs
# (gemma3-1b, whisper) where 16-way TP would spend more on per-layer
# all-reduces than it saves (EXPERIMENTS.md §Perf).
PARAM_RULES_DP = {k: ("data" if k == "embed" else None)
                  for k in PARAM_RULES_TP}
# PERF-B3: the embedding table stays replicated in the dp profile — its
# per-loss-chunk FSDP gathers cost more wire than the copy costs HBM.
PARAM_RULES_DP["embed_table"] = None
ACT_RULES_DP = {k: None for k in ACT_RULES_TP}
ACT_RULES_DP["batch"] = ("pod", "data", "model")
# KV-cache sequence sharding stays on 'model' in every profile: it is the
# only thing bounding long-context decode memory.
ACT_RULES_DP["kv_seq"] = "model"
# Logits stay vocab-sharded on 'model' even in the dp profile: the embed
# table is replicated over 'model' anyway, so sharding the (B, chunk, V)
# loss activations costs nothing and cuts loss bytes 16x (PERF-B2).
ACT_RULES_DP["vocab"] = "model"
# NOTE (PERF-B2, refuted): sharding loss chunks (batch 16-way x vocab
# 16-way) under the dp profile forces a batch-256 -> batch-16 reshard of
# every chunk's hidden states (XLA-CPU falls back to full
# rematerialization) — measured WORSE (EXPERIMENTS.md §Perf B2).  The loss
# keeps the fully batch-sharded layout instead.
ACT_RULES_DP["loss_batch"] = ("pod", "data", "model")

_PROFILE = threading.local()


@contextlib.contextmanager
def profile(name: str):
    """Activate a sharding profile ('tp' | 'dp') for the enclosed trace."""
    prev = getattr(_PROFILE, "name", "tp")
    _PROFILE.name = name
    try:
        yield
    finally:
        _PROFILE.name = prev


def current_profile() -> str:
    return getattr(_PROFILE, "name", "tp")


def _param_rules():
    return PARAM_RULES_DP if current_profile() == "dp" else PARAM_RULES_TP


def _act_rules():
    return ACT_RULES_DP if current_profile() == "dp" else ACT_RULES_TP


_DEFAULT_SIZES = {"pod": 2, "data": 16, "model": 16}


def _mesh_axis_sizes():
    """{axis: size} of the ambient mesh, or None outside any mesh.

    Version-portable: the public ``jax.sharding.get_abstract_mesh``
    (jax >= 0.5) when it exists; on older jax the private
    ``jax._src.mesh.get_abstract_mesh`` (whose unset value is a bare
    config sentinel, not a mesh) and, failing that, the classic
    ``thread_resources`` physical mesh a ``with mesh:`` block installs.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        mesh = fn()
        if mesh is None or mesh.empty:
            return None
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    from jax._src import mesh as _mesh_src
    mesh = _mesh_src.get_abstract_mesh()
    if hasattr(mesh, "axis_names") and not getattr(mesh, "empty", False):
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    phys = getattr(_mesh_src.thread_resources.env, "physical_mesh", None)
    if phys is not None and not phys.empty:
        return {k: int(v) for k, v in phys.shape.items()}
    return None


def _resolve(axes, rules, sizes, shape=None):
    """Map logical axes to a PartitionSpec, dropping mesh axes that are
    absent, whose size does not divide the tensor dimension (replicate
    fallback — e.g. kv_heads=1 under model=16 stays replicated), or that a
    previous dim already claimed (a mesh axis may appear only once)."""
    parts = []
    used: set = set()
    for i, a in enumerate(axes):
        phys = rules.get(a, None)
        dim = None if shape is None else shape[i]
        if phys is None:
            parts.append(None)
            continue
        cand = phys if isinstance(phys, tuple) else (phys,)
        cand = [x for x in cand if x in sizes and x not in used]
        if dim is not None:
            # keep the largest prefix whose product divides the dim
            kept = []
            prod = 1
            for x in cand:
                if dim % (prod * sizes[x]) == 0:
                    kept.append(x)
                    prod *= sizes[x]
            cand = kept
        used.update(cand)
        if not cand:
            parts.append(None)
        elif len(cand) == 1:
            parts.append(cand[0])
        else:
            parts.append(tuple(cand))
    return P(*parts)


def param_spec(shape, *axes) -> P:
    """PartitionSpec for a parameter under the current (or production)
    mesh, shape-aware (divisibility fallback)."""
    sizes = _mesh_axis_sizes() or dict(_DEFAULT_SIZES)
    return _resolve(axes, _param_rules(), sizes, shape)


def act_spec(*axes) -> P:
    sizes = _mesh_axis_sizes() or dict(_DEFAULT_SIZES)
    return _resolve(axes, _act_rules(), sizes)


def act_spec_shaped(shape, *axes) -> P:
    """Shape-aware activation spec (for jit in/out_shardings on inputs
    whose dims may not divide the mesh, e.g. global_batch=1)."""
    sizes = _mesh_axis_sizes() or dict(_DEFAULT_SIZES)
    return _resolve(axes, _act_rules(), sizes, shape)


def shard(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint by logical axes; identity without a mesh."""
    sizes = _mesh_axis_sizes()
    if not sizes:
        return x
    spec = _resolve(axes, _act_rules(), sizes, x.shape)
    return jax.lax.with_sharding_constraint(x, spec)
