"""jit'd step factories: train / prefill / serve, with production shardings.

``make_train_step`` builds the pjit-compiled update with:
  * parameter/optimizer shardings from ``transformer.param_specs`` (FSDP on
    'data', TP on 'model' — DESIGN.md §8),
  * batch sharded over ('pod','data'),
  * donated params/opt (in-place update, halves peak memory),
  * optional gradient accumulation (scan over microbatches),
  * per-block remat via cfg.remat (set in the arch configs).

XLA/GSPMD inserts and overlaps the FSDP all-gathers and the gradient
reduce-scatters; the §Perf iterations in EXPERIMENTS.md work on this
schedule via the sharding rules and cfg knobs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime import sharding


_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "mask": ("batch", "seq"),
    "frames": ("batch", "seq", "embed"),
    "patches": ("batch", "seq", "embed"),
}


def batch_specs(cfg: ModelConfig, batch_shapes: dict):
    """Shape-aware PartitionSpecs for an input batch dict."""
    with sharding.profile(cfg.sharding_profile):
        return {name: sharding.act_spec_shaped(s.shape, *_BATCH_AXES[name])
                for name, s in batch_shapes.items()}


def opt_specs(cfg: ModelConfig):
    pspec = transformer.param_specs(cfg)
    return {"m": pspec, "v": pspec, "step": P()}


def cache_specs_tree(cfg: ModelConfig, cache_shapes):
    """PartitionSpecs for a decode cache: batch dim over ('pod','data'),
    kv-head dim over 'model' where present (shape-aware fallbacks)."""
    def spec_for(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = len(leaf.shape)
        if name == "pos":
            return P()
        if name in ("k", "v", "cross_k", "cross_v"):
            # (L, B, S, KV, D) stacked / (B, S, KV, D) unstacked.
            # Prefer kv-head TP; fall back to *sequence sharding* of the
            # cache when kv heads don't divide the model axis (MQA/GQA<16,
            # whisper's 20 heads) — the long-context decode memory fix
            # (EXPERIMENTS.md §Perf): softmax over the sharded key axis is
            # handled by GSPMD with a cheap scalar all-reduce.
            axes = (None, "batch", None, "kv_heads", None) if nd == 5 \
                else ("batch", None, "kv_heads", None)
            spec = sharding.act_spec_shaped(leaf.shape, *axes)
            kv_dim = 3 if nd == 5 else 2
            if spec[kv_dim] is None:
                axes = (None, "batch", "kv_seq", None, None) if nd == 5 \
                    else ("batch", "kv_seq", None, None)
                spec = sharding.act_spec_shaped(leaf.shape, *axes)
            return spec
        # recurrent states: (L, B, ...) — batch-shard only
        axes = [None, "batch"] + [None] * (nd - 2)
        return sharding.act_spec_shaped(leaf.shape, *axes)

    with sharding.profile(cfg.sharding_profile):
        return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)


def make_loss_fn(cfg: ModelConfig):
    def loss(params, batch):
        with sharding.profile(cfg.sharding_profile):
            return transformer.loss_fn(cfg, params, batch)
    return loss


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    lr_schedule=None, mesh=None, donate: bool = True,
                    batch_shapes: dict | None = None):
    """Returns train_step(params, opt_state, batch) -> (loss, params, opt).

    When ``mesh`` is provided the function is jitted with explicit
    in/out_shardings (the dry-run path; ``batch_shapes`` — a dict of
    ShapeDtypeStructs — is then required for shape-aware batch specs);
    otherwise plain jit (tests).
    """
    loss_fn = make_loss_fn(cfg)
    accum = opt_cfg.accum_steps

    def step(params, opt_state, batch):
        lr = (lr_schedule(opt_state["step"]) if lr_schedule is not None
              else opt_cfg.lr)
        if accum > 1:
            # microbatch scan over the leading batch split
            def micro(carry, mb):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            mbatch = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (zeros, 0.0), mbatch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if mesh is not None:
            # Pin gradient shardings to the parameter specs so XLA emits
            # reduce-scatters into the FSDP layout instead of full
            # all-reduces (PERF-A1 in EXPERIMENTS.md §Perf).
            gspec = transformer.param_specs(cfg)
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, gspec)
        new_params, new_opt, gnorm = adamw.adamw_step(
            opt_cfg, grads, opt_state, params, lr=lr)
        return loss, new_params, new_opt

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    pspec = transformer.param_specs(cfg)
    ospec = opt_specs(cfg)
    bspec = batch_specs(cfg, batch_shapes)
    return jax.jit(
        step,
        in_shardings=(pspec, ospec, bspec),
        out_shardings=(P(), pspec, ospec),
        donate_argnums=(0, 1) if donate else ())


def make_prefill_step(cfg: ModelConfig, mesh=None, max_seq: int | None
                      = None, batch_shapes: dict | None = None):
    def step(params, batch):
        with sharding.profile(cfg.sharding_profile):
            return transformer.prefill(cfg, params, batch,
                                       max_seq=max_seq)

    if mesh is None:
        return jax.jit(step)
    pspec = transformer.param_specs(cfg)
    bspec = batch_specs(cfg, batch_shapes)
    return jax.jit(step, in_shardings=(pspec, bspec),
                   out_shardings=None)


def make_serve_step(cfg: ModelConfig, mesh=None, cache_shapes=None,
                    donate: bool = True):
    def step(params, cache, tokens, pos):
        with sharding.profile(cfg.sharding_profile):
            return transformer.serve_step(cfg, params, cache, tokens, pos)

    if mesh is None:
        return jax.jit(step, donate_argnums=(1,) if donate else ())
    pspec = transformer.param_specs(cfg)
    cspec = cache_specs_tree(cfg, cache_shapes)
    B = jax.tree.leaves(cache_shapes)[0].shape[1]
    with sharding.profile(cfg.sharding_profile):
        tspec = sharding.act_spec_shaped((B, 1), "batch", None)
        lspec = sharding.act_spec_shaped((B, 1, cfg.vocab_size), "batch",
                                         None, "vocab")
    return jax.jit(
        step,
        in_shardings=(pspec, cspec, tspec, P()),
        out_shardings=(lspec, cspec),
        donate_argnums=(1,) if donate else ())
