"""Straggler detection & mitigation (host-side; DESIGN.md §8).

Two layers of defence:

  1. *Data-induced* stragglers — unequal per-shard work — are prevented
     upstream by the DyDD balancer (the paper's contribution applied to the
     token pipeline; ``data.pipeline.BalancedLoader``).
  2. *Hardware* stragglers — a slow/failing host — are detected here by an
     EWMA step-time deadline.  On a real cluster the runner triggers the
     elastic path (checkpoint -> drop host -> re-mesh, see
     ``runtime.elastic``); in this container the trigger is surfaced to the
     caller and unit-tested with injected timings.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StragglerConfig:
    ewma_alpha: float = 0.1
    deadline_factor: float = 3.0     # step slower than 3x EWMA -> straggler
    grace_steps: int = 5             # ignore the first (compile) steps
    consecutive_trigger: int = 2     # require N consecutive slow steps


class StragglerMonitor:
    """Feed per-step wall times; fires ``on_straggler`` when the deadline is
    repeatedly exceeded."""

    def __init__(self, config: StragglerConfig | None = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.cfg = config or StragglerConfig()
        self.on_straggler = on_straggler
        self.ewma: float | None = None
        self.step = 0
        self._slow_streak = 0
        self.events: list = []

    def record(self, seconds: float) -> bool:
        """Returns True if this step was flagged."""
        self.step += 1
        flagged = False
        if self.step <= self.cfg.grace_steps:
            return False
        if self.ewma is None:
            self.ewma = seconds
            return False
        if seconds > self.cfg.deadline_factor * self.ewma:
            self._slow_streak += 1
            if self._slow_streak >= self.cfg.consecutive_trigger:
                flagged = True
                self.events.append((self.step, seconds))
                if self.on_straggler:
                    self.on_straggler(self.step, seconds)
                self._slow_streak = 0
        else:
            self._slow_streak = 0
            a = self.cfg.ewma_alpha
            self.ewma = (1 - a) * self.ewma + a * seconds
        return flagged

    def state_dict(self) -> dict:
        """JSON-ready EWMA/streak state (``events`` stays host-local —
        it's an operator log, not detector state)."""
        return {"ewma": self.ewma, "step": int(self.step),
                "slow_streak": int(self._slow_streak)}

    def load_state(self, state: dict) -> None:
        self.ewma = (None if state.get("ewma") is None
                     else float(state["ewma"]))
        self.step = int(state.get("step", 0))
        self._slow_streak = int(state.get("slow_streak", 0))

    def timed(self, fn, *args, **kwargs):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        out = jax_block(out)
        self.record(time.perf_counter() - t0)
        return out


def jax_block(out):
    import jax
    return jax.block_until_ready(out)
