"""Fallback for the small slice of the hypothesis API this suite uses.

When hypothesis is installed it is re-exported untouched.  Otherwise
``given``/``settings``/``strategies`` degrade to a deterministic, seeded
sweep: each ``@given`` test runs a fixed number of examples drawn with a
``numpy`` RNG keyed on the test name, so failures reproduce exactly and
the suite collects in environments without hypothesis.

Only ``st.integers`` and ``st.sampled_from`` are emulated — the two
strategies the suite uses.  Add more draws here if a test needs them.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:
    import functools
    import inspect
    import os
    import zlib

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

    def settings(**kwargs):
        def deco(fn):
            fn._shim_settings = dict(kwargs)
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                cfg = getattr(wrapper, "_shim_settings", {})
                n = min(int(cfg.get("max_examples", 10)),
                        int(os.environ.get("SHIM_MAX_EXAMPLES", "12")))
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(max(n, 1)):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            # Hide the drawn parameters from pytest's fixture resolution.
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strats])
            return wrapper
        return deco
