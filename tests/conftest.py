import os
import sys

# Tests see the single real CPU device (the 512-device flag is ONLY set
# inside launch/dryrun.py, per the dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)
