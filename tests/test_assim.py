"""Streaming assimilation engine: scenario registry (1D + 2D), rebalance
policy, double-buffered pipelining, agreement with the one-shot DD-KF
solve, and the dimension-agnostic Domain layer (degenerate 2D == 1D)."""
import json

import numpy as np
import pytest

from repro.assim import (AssimilationEngine, EngineConfig, Journal,
                         imbalance_ratio, streams)
from repro.core import domain as domain_mod

THRESHOLD = 1.5
CYCLES = 6


def small_config(**kw):
    base = dict(n=64, p=4, iters=80, imbalance_threshold=THRESHOLD,
                track_reference=True)
    base.update(kw)
    return EngineConfig(**base)


def small_config_2d(**kw):
    base = dict(ndim=2, nx=12, ny=8, pr=2, pc=2, iters=600, damping=0.7,
                imbalance_threshold=THRESHOLD, track_reference=True)
    base.update(kw)
    return EngineConfig(**base)


# ---------------------------------------------------------------------------
# Stream registry.
# ---------------------------------------------------------------------------

def test_registry_has_the_five_scenarios():
    names = streams.available(ndim=1)
    assert len(names) >= 5
    for required in ("drifting_swarm", "bursty_clusters", "sensor_dropout",
                     "diurnal", "storm_front"):
        assert required in names


def test_registry_has_four_2d_scenarios():
    names = streams.available(ndim=2)
    assert len(names) >= 4
    for required in ("storm_front_2d", "rotating_swarm", "coastal_band",
                     "grid_dropout"):
        assert required in names
    # the unfiltered listing carries both dimensions
    assert set(streams.available()) >= set(names) | set(
        streams.available(ndim=1))


def test_unknown_scenario_raises():
    with pytest.raises(ValueError, match="unknown stream scenario"):
        streams.make_stream("nope", 10, 2)


def test_duplicate_registration_raises():
    with pytest.raises(ValueError, match="already registered"):
        streams.register("diurnal")(lambda m, cycles, seed: iter(()))


@pytest.mark.parametrize("name", streams.available())
def test_stream_determinism_and_shapes(name):
    m, cycles = 120, 5
    ndim = streams.get(name).ndim
    a = list(streams.make_stream(name, m, cycles, seed=7))
    b = list(streams.make_stream(name, m, cycles, seed=7))
    c = list(streams.make_stream(name, m, cycles, seed=8))
    assert len(a) == cycles
    for xa, xb in zip(a, b):
        np.testing.assert_array_equal(xa, xb)
    assert any(not np.array_equal(xa, xc) for xa, xc in zip(a, c))
    for obs in a:
        assert (obs >= 0).all() and (obs < 1).all()
        if ndim == 1:
            assert obs.shape == (m,)
            assert (np.diff(obs) >= 0).all()
        else:
            assert obs.shape == (m, 2)
            # lex-sorted by (y, x)
            order = np.lexsort((obs[:, 0], obs[:, 1]))
            np.testing.assert_array_equal(order, np.arange(m))


# ---------------------------------------------------------------------------
# Engine: every scenario, >= 6 cycles, correctness + rebalance invariants.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", streams.available(ndim=1))
def test_engine_runs_scenario_and_matches_one_shot(name):
    # Additive Schwarz converges slowly on cycles where the observation
    # mass is split across far-apart subdomain interfaces (bursty_clusters
    # mid-run, storm_front's post-storm bimodal network); 1500 iterations
    # covers the worst registered scenario at this size.
    eng = AssimilationEngine(small_config(iters=1500))
    journal = eng.run_scenario(name, m=160, cycles=CYCLES, seed=0)
    assert len(journal) == CYCLES
    for r in journal.records:
        # Engine analysis == per-cycle one-shot solve to tolerance.
        assert r.error_vs_direct < 1e-8, (name, r.cycle, r.error_vs_direct)
        assert sum(r.loads) == 160
        # Wherever a repartition fired, post-migration imbalance is under
        # the configured threshold.
        if r.repartitioned:
            assert r.imbalance <= THRESHOLD, (name, r.cycle, r.loads)
    assert eng.analysis is not None and eng.analysis.shape == (64,)


def test_rebalancing_beats_static_on_drifting_swarm():
    runs = {}
    for rebalance in (True, False):
        eng = AssimilationEngine(small_config(rebalance=rebalance,
                                              track_reference=False))
        runs[rebalance] = eng.run_scenario("drifting_swarm", m=160,
                                           cycles=CYCLES, seed=0)
    imb_dydd = np.mean(runs[True].imbalance_trajectory)
    imb_static = np.mean(runs[False].imbalance_trajectory)
    assert runs[False].repartition_count == 0
    assert runs[True].repartition_count >= 1
    assert imb_dydd < imb_static


def test_double_buffer_matches_serial_execution():
    outs = {}
    for db in (True, False):
        eng = AssimilationEngine(small_config(double_buffer=db,
                                              track_reference=False))
        journal = eng.run_scenario("bursty_clusters", m=160, cycles=CYCLES,
                                   seed=3)
        outs[db] = (np.asarray(eng.analysis), journal)
    np.testing.assert_array_equal(outs[True][0], outs[False][0])
    for a, b in zip(outs[True][1].records, outs[False][1].records):
        assert a.loads == b.loads
        assert a.repartitioned == b.repartitioned
        assert a.migrated == b.migrated
        assert a.imbalance == b.imbalance


def test_empty_subdomain_always_fires_dd_step():
    """All observations in the right half: subdomains 0-1 are empty, so the
    DD step must fire immediately even with an enormous threshold."""
    def half_domain(m, cycles, seed):
        rng = np.random.default_rng(seed)
        for _ in range(cycles):
            yield np.sort(rng.uniform(0.5, 1.0, m))

    eng = AssimilationEngine(small_config(imbalance_threshold=1e9,
                                          track_reference=False))
    journal = eng.run(half_domain(160, 3, seed=0))
    assert journal.records[0].repartitioned
    assert all(v > 0 for v in journal.records[0].loads)


def test_hysteresis_defers_repartition():
    """A skewed-but-nowhere-empty network over threshold every cycle: with
    hysteresis=3 the first repartition fires on cycle 2 (third cycle)."""
    def skewed(m, cycles, seed):
        rng = np.random.default_rng(seed)
        for _ in range(cycles):
            hot = rng.uniform(0.0, 0.25, (4 * m) // 5)
            cold = rng.uniform(0.25, 1.0, m - len(hot))
            yield np.sort(np.concatenate([hot, cold]))

    eng = AssimilationEngine(small_config(hysteresis=3,
                                          track_reference=False))
    journal = eng.run(skewed(160, 5, seed=0))
    fired = [r.cycle for r in journal.records if r.repartitioned]
    assert journal.records[0].imbalance_before > THRESHOLD
    assert fired and fired[0] == 2, fired


def test_no_thrash_on_static_tied_clustered_stream():
    """ISSUE 5 regression: a static clustered stream with heavily tied
    coordinates used to repartition every cycle (the tie bug left an
    'empty' subdomain that re-fired the DD step).  With the rank-split
    migration the first repartition balances exactly; no further cycle
    may fire."""
    def clustered(m, cycles, seed):
        obs = np.sort(np.concatenate([np.full(3 * m // 4, 0.1),
                                      np.full(m - 3 * m // 4, 0.9)]))
        for _ in range(cycles):
            yield obs

    eng = AssimilationEngine(small_config(track_reference=False))
    journal = eng.run(clustered(160, 6, seed=0))
    assert journal.records[0].repartitioned
    assert journal.repartition_count == 1
    assert journal.records[0].loads == [40, 40, 40, 40]
    for r in journal.records[1:]:
        assert not r.repartitioned
        assert not r.rebalance_suppressed  # balanced, trigger never arms


def test_unpopulatable_empty_subdomain_suppressed_and_journalled():
    """Fewer observations than subdomains: the empty trigger fires once,
    the rebalance cannot populate every subdomain, and every later cycle
    of the static stream suppresses the re-fire (journalled) instead of
    thrashing the DD step."""
    def tiny(m, cycles, seed):
        for _ in range(cycles):
            yield np.array([0.5, 0.5])

    eng = AssimilationEngine(small_config(track_reference=False,
                                          double_buffer=False))
    journal = eng.run(tiny(2, 5, seed=0))
    assert journal.records[0].repartitioned
    assert journal.repartition_count == 1
    for r in journal.records[1:]:
        assert r.rebalance_suppressed and not r.repartitioned
    assert journal.summary()["repartitions_suppressed"] == 4
    d = json.loads(journal.to_json())
    assert d["records"][1]["rebalance_suppressed"] is True


def test_suppression_lifts_when_the_stream_moves():
    """Suppression keys on exact load equality: once the stream shifts
    the counts, the trigger fires again."""
    def shifting(m, cycles, seed):
        yield np.array([0.5, 0.5])
        yield np.array([0.5, 0.5])
        yield np.array([0.05, 0.06])   # different loads -> re-fire

    eng = AssimilationEngine(small_config(track_reference=False,
                                          double_buffer=False))
    journal = eng.run(shifting(2, 3, seed=0))
    fired = [r.cycle for r in journal.records if r.repartitioned]
    assert fired[0] == 0 and len(fired) >= 2 and 2 in fired
    assert journal.records[1].rebalance_suppressed


def test_static_mode_never_repartitions():
    eng = AssimilationEngine(small_config(rebalance=False,
                                          track_reference=False))
    journal = eng.run_scenario("storm_front", m=160, cycles=CYCLES, seed=0)
    assert journal.repartition_count == 0
    assert journal.migrated_total == 0


# ---------------------------------------------------------------------------
# 2D domain: ShelfTiling2D engine runs, rebalance wins, degenerate parity.
# ---------------------------------------------------------------------------

# Station-network scenarios with quantized (tied) coordinates: a shelf
# tiling cannot cut inside a tie group, so its post-rebalance imbalance
# carries a tie-group floor above the trigger threshold (the gap the
# KDTreeDomain closes — see test_kdtree.py).
TIED_2D = frozenset({"satellite_track", "river_gauges"})


@pytest.mark.parametrize("name", streams.available(ndim=2))
def test_engine_runs_2d_scenario_and_matches_one_shot(name):
    eng = AssimilationEngine(small_config_2d())
    journal = eng.run_scenario(name, m=160, cycles=4, seed=0)
    assert len(journal) == 4
    assert journal.meta["ndim"] == 2
    bound = 2.5 if name in TIED_2D else THRESHOLD
    for r in journal.records:
        assert r.error_vs_direct < 1e-8, (name, r.cycle, r.error_vs_direct)
        assert sum(r.loads) == 160
        if r.repartitioned:
            assert r.imbalance <= bound, (name, r.cycle, r.loads)
    assert eng.analysis is not None and eng.analysis.shape == (96,)


@pytest.mark.parametrize("name", streams.available(ndim=2))
def test_2d_rebalancing_beats_static(name):
    runs = {}
    for rebalance in (True, False):
        eng = AssimilationEngine(small_config_2d(rebalance=rebalance,
                                                 iters=150,
                                                 track_reference=False))
        runs[rebalance] = eng.run_scenario(name, m=160, cycles=4, seed=0)
    assert runs[False].repartition_count == 0
    assert runs[True].repartition_count >= 1
    assert (np.mean(runs[True].imbalance_trajectory)
            < np.mean(runs[False].imbalance_trajectory))
    # final-cycle imbalance also improves (the benchmark's acceptance bar)
    assert (runs[True].imbalance_trajectory[-1]
            < runs[False].imbalance_trajectory[-1])


def test_engine_rejects_dimension_mismatch():
    with pytest.raises(ValueError, match="1D"):
        AssimilationEngine(small_config_2d()).run_scenario(
            "drifting_swarm", m=40, cycles=2)
    with pytest.raises(ValueError, match="2D"):
        AssimilationEngine(small_config()).run_scenario(
            "rotating_swarm", m=40, cycles=2)


def test_2d_overlap_converges_to_same_fixed_point():
    """Schwarz halo on the shelf tiling: overlap > 0 reaches the same
    fixed point (the one-shot CLS estimate, so also the overlap=0
    block-exact solve) on a seeded 2D scenario, and the halo-augmented
    decomposition genuinely overlaps."""
    eng = AssimilationEngine(small_config_2d(overlap=2))
    dec = eng.domain.decomposition(overlap=2)
    assert dec.boundaries is None
    assert dec.has_overlap and dec.column_multiplicity.max() > 1
    journal = eng.run_scenario("rotating_swarm", m=160, cycles=3, seed=0)
    for r in journal.records:
        assert r.error_vs_direct < 1e-8, (r.cycle, r.error_vs_direct)

    eng0 = AssimilationEngine(small_config_2d(overlap=0))
    eng0.run_scenario("rotating_swarm", m=160, cycles=3, seed=0)
    assert float(np.linalg.norm(np.asarray(eng.analysis)
                                - np.asarray(eng0.analysis))) < 1e-8


def test_negative_overlap_rejected():
    with pytest.raises(ValueError, match="overlap"):
        AssimilationEngine(small_config_2d(overlap=-1))
    with pytest.raises(ValueError, match="overlap"):
        AssimilationEngine(small_config(overlap=-2))


def test_grid_dropout_fires_empty_cell_dd_step():
    """grid_dropout empties whole tiling cells mid-run: the DD-step must
    fire even with an enormous threshold, and leave no cell empty."""
    eng = AssimilationEngine(small_config_2d(imbalance_threshold=1e9,
                                             iters=150,
                                             track_reference=False))
    journal = eng.run_scenario("grid_dropout", m=200, cycles=5, seed=0)
    outage = [r for r in journal.records if 0 in r.loads_before]
    assert outage, "scenario never emptied a cell"
    for r in outage:
        assert r.repartitioned
        assert all(v > 0 for v in r.loads), (r.cycle, r.loads)


@pytest.mark.parametrize("overlap", [0, 2])
def test_shelf_pr1_degenerates_to_interval1d_bitwise(overlap):
    """A ShelfTiling2D with pr=1, ny=1 is exactly the 1D engine: same
    analyses and same journal loads, bit for bit — including the halo
    path (overlap=s reduces to the 1D interval overlap of eq. 21)."""
    n, p, m, cycles = 48, 4, 120, 5
    one_d = list(streams.make_stream("drifting_swarm", m, cycles, seed=5))

    eng1 = AssimilationEngine(EngineConfig(n=n, p=p, iters=120,
                                           overlap=overlap))
    j1 = eng1.run(iter(one_d))

    def lifted():
        for obs in one_d:
            yield np.stack([obs, np.full_like(obs, 0.5)], axis=1)

    eng2 = AssimilationEngine(EngineConfig(ndim=2, nx=n, ny=1, pr=1, pc=p,
                                           iters=120, overlap=overlap))
    j2 = eng2.run(lifted())

    np.testing.assert_array_equal(np.asarray(eng1.analysis),
                                  np.asarray(eng2.analysis))
    for a, b in zip(j1.records, j2.records):
        assert a.loads == b.loads
        assert a.loads_before == b.loads_before
        assert a.repartitioned == b.repartitioned
        assert a.migrated == b.migrated
    np.testing.assert_array_equal(eng1.domain.boundaries,
                                  eng2.domain.x_edges[0])


def test_explicit_domain_overrides_config():
    dom = domain_mod.ShelfTiling2D(nx=8, ny=8, pr=2, pc=2)
    eng = AssimilationEngine(small_config(), domain=dom)
    assert eng.domain is dom
    assert eng.n == 64 and eng.p == 4
    assert eng.journal.meta["kind"] == "shelf2d"


def test_domain_kind_kdtree_config():
    """domain_kind='kdtree' builds a p-leaf KDTreeDomain over the nx x ny
    mesh and runs 2D scenarios end to end."""
    cfg = EngineConfig(ndim=2, domain_kind="kdtree", p=4, nx=12, ny=8,
                      iters=40, track_reference=False)
    eng = AssimilationEngine(cfg)
    assert eng.journal.meta["kind"] == "kdtree"
    assert eng.p == 4 and eng.n == 96
    journal = eng.run_scenario("satellite_track", m=80, cycles=2, seed=0)
    assert len(journal) == 2
    for r in journal.records:
        assert sum(r.loads) == 80
    # a 1D scenario is rejected like any other 2D domain
    with pytest.raises(ValueError, match="1D"):
        AssimilationEngine(cfg).run_scenario("drifting_swarm", m=40,
                                             cycles=2)


def test_unknown_domain_kind_raises():
    with pytest.raises(ValueError, match="domain_kind"):
        AssimilationEngine(EngineConfig(domain_kind="voronoi"))


# ---------------------------------------------------------------------------
# Metrics journal.
# ---------------------------------------------------------------------------

def test_imbalance_ratio():
    assert imbalance_ratio([4, 4, 4, 4]) == 1.0
    assert imbalance_ratio([8, 0, 0, 0]) == 4.0
    assert imbalance_ratio([0, 0]) == 1.0


def test_journal_json_roundtrip(tmp_path):
    eng = AssimilationEngine(small_config(track_reference=False))
    journal = eng.run_scenario("diurnal", m=120, cycles=3, seed=0)
    d = json.loads(journal.to_json())
    assert len(d["records"]) == 3
    assert d["summary"]["cycles"] == 3
    for key in ("repartitions", "migrated_total", "imbalance_max",
                "cycle_time_mean"):
        assert key in d["summary"]
    path = tmp_path / "journal.json"
    journal.save(str(path))
    assert json.loads(path.read_text())["summary"]["cycles"] == 3


def test_empty_journal_summary():
    assert Journal().summary() == {"cycles": 0}


# ---------------------------------------------------------------------------
# Config validation.
# ---------------------------------------------------------------------------

def test_shardmap_without_mesh_raises():
    # On this single-device test session the device count cannot match
    # p=4, so auto-building the mesh is rejected with the fix spelled out.
    with pytest.raises(ValueError, match="requires a mesh"):
        AssimilationEngine(EngineConfig(solver="shardmap"))


def test_shardmap_mesh_device_count_mismatch_raises():
    """p != mesh device count must fail up front with an actionable
    message, not as an opaque shard_map shape error mid-solve."""
    from repro.core import _compat
    mesh = _compat.make_device_mesh((1,), ("sub",))
    with pytest.raises(ValueError, match="one device per subdomain"):
        AssimilationEngine(EngineConfig(solver="shardmap", p=4), mesh=mesh)


def test_shardmap_single_device_mesh_runs():
    """p=1 matches the 1-device test session: the engine auto-builds the
    (1,) mesh and the sharded path solves a cycle end to end."""
    cfg = EngineConfig(n=32, p=1, iters=60, solver="shardmap",
                       track_reference=True)
    eng = AssimilationEngine(cfg)
    assert eng.mesh is not None and eng.mesh_axis == "sub"
    journal = eng.run_scenario("drifting_swarm", m=60, cycles=2, seed=0)
    for r in journal.records:
        assert r.error_vs_direct < 1e-8


def test_unknown_solver_raises():
    with pytest.raises(ValueError, match="unknown solver"):
        AssimilationEngine(EngineConfig(solver="quantum"))


def test_zero_hysteresis_raises():
    with pytest.raises(ValueError, match="hysteresis"):
        AssimilationEngine(EngineConfig(hysteresis=0))


def test_sub_unity_threshold_raises():
    with pytest.raises(ValueError, match="imbalance_threshold"):
        AssimilationEngine(EngineConfig(imbalance_threshold=0.5))
