"""Generic balancer API + the DyDD-balanced data pipeline (DESIGN.md §4)."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import balance, dydd
from repro.data import pipeline, observations


def test_topology_ring_neighbours():
    topo = balance.Topology.ring(6)
    assert topo.neighbours(0) == [1, 5]
    assert topo.neighbours(3) == [2, 4]


def test_plan_moves_are_neighbour_only():
    topo = balance.Topology.ring(8)
    loads = np.array([100, 0, 0, 0, 0, 0, 0, 0])
    plan = balance.plan(loads, topo, max_rounds=32)
    edge_set = {frozenset(e) for e in topo.edges}
    for src, dst, cnt in plan.moves:
        assert frozenset((src, dst)) in edge_set
        assert cnt > 0
    assert plan.loads_after.sum() == 100
    assert plan.efficiency > 0.5


@settings(max_examples=40, deadline=None)
@given(p=st.integers(2, 16), seed=st.integers(0, 10_000))
def test_plan_conservation_and_improvement(p, seed):
    rng = np.random.default_rng(seed)
    loads = rng.integers(0, 1000, p)
    topo = balance.Topology.ring(p)
    plan = balance.plan(loads, topo)
    assert plan.loads_after.sum() == loads.sum()
    assert plan.efficiency >= dydd.balance_ratio(loads) - 1e-12


def test_synthetic_corpus_heavy_tail_deterministic():
    docs1 = pipeline.synthetic_corpus(100, 1000, seed=7)
    docs2 = pipeline.synthetic_corpus(100, 1000, seed=7)
    assert all((a.tokens == b.tokens).all() for a, b in zip(docs1, docs2))
    lens = np.array([len(d.tokens) for d in docs1])
    assert lens.std() > 0.3 * lens.mean()   # genuinely heavy-tailed


def test_pack_documents_masks_padding():
    docs = pipeline.synthetic_corpus(10, 100, seed=0, mean_len=20,
                                     max_len=40)
    toks, labs, mask = pipeline.pack_documents(docs, batch=4, seq=64)
    assert toks.shape == labs.shape == mask.shape == (4, 64)
    # labels are next-token shifted
    np.testing.assert_array_equal(labs[:, :-1], toks[:, 1:])
    assert 0 < mask.sum() <= 4 * 64


def test_balanced_loader_improves_efficiency():
    ld = pipeline.BalancedLoader(vocab_size=1000, dp=8, batch_per_shard=2,
                                 seq=256, seed=0, balance=True)
    toks, labs, mask = ld.next_batch()
    assert toks.shape == (16, 256)
    st = ld.last_stats
    assert st.efficiency_after >= st.efficiency_before
    assert st.loads_after.sum() == st.loads_before.sum()


def test_balanced_loader_beats_unbalanced_on_average():
    kw = dict(vocab_size=1000, dp=8, batch_per_shard=2, seq=256, seed=3)
    bal = pipeline.BalancedLoader(balance=True, **kw)
    unb = pipeline.BalancedLoader(balance=False, **kw)
    e_b, e_u = [], []
    for _ in range(5):
        bal.next_batch()
        unb.next_batch()
        e_b.append(bal.last_stats.efficiency_after)
        e_u.append(unb.last_stats.efficiency_after)
    assert np.mean(e_b) > np.mean(e_u)


def test_loader_state_restart_determinism():
    kw = dict(vocab_size=500, dp=4, batch_per_shard=2, seq=128, seed=11)
    a = pipeline.BalancedLoader(**kw)
    for _ in range(3):
        a.next_batch()
    state = a.state_dict()
    want = a.next_batch()
    b = pipeline.BalancedLoader(**kw)
    b.load_state_dict(state)
    got = b.next_batch()
    for x, y in zip(want, got):
        np.testing.assert_array_equal(x, y)


def test_observation_generators():
    for kind in ("uniform", "beta", "clustered"):
        obs = observations.make_observations(500, kind=kind, seed=1)
        assert obs.shape == (500,)
        assert (obs >= 0).all() and (obs < 1).all()


def test_observation_empty_subdomains():
    obs = observations.make_observations(
        1000, kind="uniform", seed=2, empty_subdomains=(0, 1), p=4)
    counts = np.histogram(obs, bins=4, range=(0, 1))[0]
    assert counts[0] == 0 and counts[1] == 0
    assert counts[2] + counts[3] == 1000
