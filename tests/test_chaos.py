"""Elastic fault tolerance: deterministic chaos injection, engine
snapshot/resume (bitwise journal continuation), remesh-on-p-change, and
the fleet's failure paths (transient retry, crashed-stream retirement
with slot reclamation)."""
import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.assim import AssimilationEngine, EngineConfig, FleetServer, streams
from repro.core import domain as domain_mod
from repro.core import kdtree as kdtree_mod
from repro.obs import meters as obs_meters
from repro.runtime import chaos
from repro.runtime import elastic
from repro.runtime.straggler import StragglerConfig


@pytest.fixture()
def fresh_meters():
    prev = obs_meters.get_meters()
    m = obs_meters.Meters()
    obs_meters.set_meters(m)
    yield m
    obs_meters.set_meters(prev)


# ---------------------------------------------------------------------------
# Injector determinism and retry mechanics.
# ---------------------------------------------------------------------------

def test_injector_schedule_and_replay_deterministic(fresh_meters):
    cfg = chaos.ChaosConfig(seed=7, max_cycle=64, pack_fault_rate=0.1,
                            solve_fault_rate=0.05, kill_cycles=(9,),
                            straggle_cycles=(3,))
    a, b = chaos.ChaosInjector(cfg), chaos.ChaosInjector(cfg)
    assert a.schedule() == b.schedule()
    json.dumps(a.schedule())   # JSON-ready
    for inj in (a, b):
        for c in range(64):
            for site in ("pack", "solve"):
                try:
                    inj.check(site, c)
                except chaos.TransientFault:
                    pass
    assert a.injections == b.injections and a.injections
    other = chaos.ChaosInjector(
        chaos.ChaosConfig(seed=8, max_cycle=64, pack_fault_rate=0.1,
                          solve_fault_rate=0.05))
    assert other.schedule()["pack_fault_cycles"] != \
        a.schedule()["pack_fault_cycles"]


def test_fault_fires_once_unless_fail_every_attempt(fresh_meters):
    inj = chaos.ChaosInjector(chaos.ChaosConfig(pack_fault_cycles=(2,)))
    with pytest.raises(chaos.TransientFault):
        inj.check("pack", 2)
    inj.check("pack", 2)          # second attempt passes
    inj.check("pack", 1)          # unscheduled cycle never fires

    hard = chaos.ChaosInjector(
        chaos.ChaosConfig(pack_fault_cycles=(2,), fail_every_attempt=True))
    with pytest.raises(chaos.TransientFault):
        chaos.retry_transient(lambda: hard.check("pack", 2), retries=2,
                              backoff=0.0, site="pack", cycle=2,
                              sleep=lambda s: None)
    assert len(hard.injections) == 3   # initial + both retries


def test_retry_transient_backoff_sequence(fresh_meters):
    delays, calls = [], {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] < 3:
            raise chaos.TransientFault("flaky")
        return "ok"

    out = chaos.retry_transient(fn, retries=3, backoff=0.05, site="solve",
                                cycle=1, sleep=delays.append)
    assert out == "ok"
    assert delays == [0.05, 0.1]   # exponential
    snap = fresh_meters.snapshot()
    assert snap["counters"]["chaos.retries"] == 2
    assert [e["attempt"] for e in snap["events"]
            if e["name"] == "chaos.retry"] == [1, 2]


def test_retry_transient_does_not_catch_fatal():
    with pytest.raises(ZeroDivisionError):
        chaos.retry_transient(lambda: 1 / 0, retries=5, backoff=0.0,
                              sleep=lambda s: None)


# ---------------------------------------------------------------------------
# Engine-level chaos: retried faults leave the journal bitwise identical.
# ---------------------------------------------------------------------------

def _cfg(**kw):
    return EngineConfig(n=48, p=3, iters=6, **kw)


def _stream(cycles=6, seed=3, m=60):
    return streams.make_stream("drifting_swarm", m, cycles, seed=seed)


@pytest.mark.parametrize("double_buffer", [True, False])
def test_engine_transient_faults_retry_bitwise(fresh_meters, double_buffer):
    base = AssimilationEngine(_cfg(double_buffer=double_buffer)) \
        .run(_stream())
    inj = chaos.ChaosInjector(
        chaos.ChaosConfig(pack_fault_cycles=(1, 3), solve_fault_cycles=(2,)))
    eng = AssimilationEngine(_cfg(double_buffer=double_buffer), chaos=inj)
    j = eng.run(_stream())
    assert j.deterministic_json() == base.deterministic_json()
    assert {(r["site"], r["cycle"]) for r in inj.injections} == \
        {("pack", 1), ("pack", 3), ("solve", 2)}
    assert fresh_meters.snapshot()["counters"]["chaos.retries"] == 3


def test_engine_fault_outliving_retries_is_fatal(fresh_meters):
    inj = chaos.ChaosInjector(
        chaos.ChaosConfig(solve_fault_cycles=(1,), fail_every_attempt=True))
    eng = AssimilationEngine(_cfg(solve_retries=1), chaos=inj)
    with pytest.raises(chaos.TransientFault):
        eng.run(_stream())


def test_forced_straggler_flags_without_touching_numerics(fresh_meters):
    base = AssimilationEngine(_cfg()).run(_stream())
    scfg = StragglerConfig(grace_steps=1, consecutive_trigger=1,
                           deadline_factor=10.0)
    inj = chaos.ChaosInjector(
        chaos.ChaosConfig(straggle_cycles=(4,), straggle_device=0,
                          straggle_factor=1e6))
    eng = AssimilationEngine(_cfg(), straggler_config=scfg, chaos=inj)
    j = eng.run(_stream())
    # The inflated report flags the device...
    assert j.records[4].straggler_flags == [0]
    snap = fresh_meters.snapshot()
    assert snap["counters"]["engine.straggler.flags"] >= 1
    assert any(r["site"] == "straggle" for r in inj.injections)
    # ...while the analyses/journal stay bitwise (only reported timing
    # changed; straggler_flags are excluded from the deterministic view
    # by design — they are chaos evidence).
    assert j.deterministic_json() == base.deterministic_json()


# ---------------------------------------------------------------------------
# Snapshot / restore: bitwise journal continuation on every domain kind.
# ---------------------------------------------------------------------------

KINDS = {
    "interval": (dict(n=48, p=3, iters=6), ("drifting_swarm", 60)),
    "shelf": (dict(n=64, ndim=2, nx=8, ny=8, pr=2, pc=2, iters=6),
              ("rotating_swarm", 80)),
    "kdtree": (dict(n=64, domain_kind="kdtree", p=4, nx=8, ny=8, iters=6),
               ("rotating_swarm", 80)),
}
_CYCLES = 8


def _kind_run(kind, tmp_path, **run_kw):
    cfg_kw, (scen, m) = KINDS[kind]
    eng = AssimilationEngine(EngineConfig(**cfg_kw))
    j = eng.run(streams.ResumableStream(scen, m, _CYCLES, seed=11),
                **run_kw)
    return eng, j


@pytest.mark.parametrize("kind", list(KINDS))
def test_snapshot_resume_bitwise(tmp_path, kind):
    base_eng, base = _kind_run(kind, tmp_path)
    ck = str(tmp_path / kind)
    _kind_run(kind, tmp_path, checkpoint_dir=ck, snapshot_every=4)
    eng2, stream2 = elastic.resume_assim_engine(
        os.path.join(ck, "step_00000004"))
    assert stream2 is not None and stream2.pos == 4
    assert stream2.remaining() == _CYCLES - 4
    j = eng2.run(stream2)
    assert j.deterministic_json() == base.deterministic_json()
    np.testing.assert_array_equal(np.asarray(eng2.analysis),
                                  np.asarray(base_eng.analysis))
    assert j.meta["resume"] == [
        {"at_cycle": 4, "p": eng2.p, "remeshed": False}]


@pytest.mark.parametrize("kind", list(KINDS))
def test_elastic_remesh_in_process(tmp_path, kind):
    new_p = 2
    ck = str(tmp_path / kind)
    _kind_run(kind, tmp_path, checkpoint_dir=ck, snapshot_every=4)
    eng2, stream2 = elastic.resume_assim_engine(
        os.path.join(ck, "step_00000004"), p=new_p)
    assert eng2.p == new_p and stream2.pos == 4
    j = eng2.run(stream2)
    # Continues without replaying: cycles 4.._CYCLES-1 on the new p.
    assert [r.cycle for r in j.records] == list(range(_CYCLES))
    assert all(len(r.loads) == new_p for r in j.records[4:])
    assert all(len(r.loads) > new_p for r in j.records[:4])
    assert j.meta["resume"][-1] == \
        {"at_cycle": 4, "p": new_p, "remeshed": True}


def test_restore_rejects_unknown_snapshot_version(tmp_path):
    from repro.checkpoint import manager as ckpt
    path = ckpt.save_pytree({"truth": np.zeros(4)}, str(tmp_path), step=1,
                            metadata={"snapshot_version": 99})
    with pytest.raises(ValueError, match="snapshot version"):
        AssimilationEngine.restore(path)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       kind=st.sampled_from(["interval", "shelf", "kdtree"]))
def test_domain_state_roundtrip(seed, kind):
    """state_dict/load_state round-trips the boundary state bitwise for
    all three domain kinds, across arbitrary rebalance histories."""
    rng = np.random.default_rng(seed)
    if kind == "interval":
        dom, fresh = (domain_mod.Interval1D(n=32, p=4),
                      domain_mod.Interval1D(n=32, p=4))
        obs = np.sort(rng.random(50))
    elif kind == "shelf":
        dom, fresh = (domain_mod.ShelfTiling2D(nx=8, ny=8, pr=2, pc=2),
                      domain_mod.ShelfTiling2D(nx=8, ny=8, pr=2, pc=2))
        obs = rng.random((50, 2))
    else:
        dom, fresh = (kdtree_mod.KDTreeDomain(nx=8, ny=8, p=4),
                      kdtree_mod.KDTreeDomain(nx=8, ny=8, p=4))
        obs = rng.random((50, 2))
    dom.rebalance(obs)
    state = dom.state_dict()
    fresh.load_state({k: np.array(v) for k, v in state.items()})
    for k, v in fresh.state_dict().items():
        np.testing.assert_array_equal(v, state[k])
    np.testing.assert_array_equal(fresh.counts(obs), dom.counts(obs))


# ---------------------------------------------------------------------------
# Remesh derivation helpers.
# ---------------------------------------------------------------------------

def test_rebalanced_edges_quantile_cut():
    edges = np.array([0.0, 1.0, 2.0, 3.0, 4.0])
    out = elastic.rebalanced_edges(edges, [0, 0, 4, 4], new_p=2)
    np.testing.assert_allclose(out, [0.0, 3.0, 4.0])
    # Zero mass -> uniform; endpoints always pinned.
    np.testing.assert_allclose(
        elastic.rebalanced_edges(edges, [0, 0, 0, 0], new_p=4),
        np.linspace(0.0, 4.0, 5))


def test_shelf_grid_selection():
    assert elastic._shelf_grid(4, pr_old=2, pr=None, pc=None) == (2, 2)
    assert elastic._shelf_grid(2, pr_old=2, pr=None, pc=None) == (2, 1)
    assert elastic._shelf_grid(6, pr_old=4, pr=None, pc=None) == (3, 2)
    assert elastic._shelf_grid(8, pr_old=2, pr=4, pc=None) == (4, 2)
    with pytest.raises(ValueError):
        elastic._shelf_grid(8, pr_old=2, pr=3, pc=3)


# ---------------------------------------------------------------------------
# Fleet failure paths.
# ---------------------------------------------------------------------------

def test_fleet_prepare_failure_reclaims_slot(fresh_meters):
    cfg = _cfg()
    server = FleetServer(max_active=1, pack_workers=2, gather_window=0.0)
    # np.asarray("boom", float64) raises inside prepare on the pool.
    server.add_stream("bad", cfg, iter(["boom"]))
    server.add_stream("good", cfg, _stream(cycles=4, seed=1))
    journals = server.serve()
    assert len(journals["bad"]) == 0
    assert len(journals["good"]) == 4   # got the reclaimed slot
    assert server.scheduler.idle()
    snap = fresh_meters.snapshot()
    assert snap["counters"]["fleet.streams_failed"] == 1
    assert any(e["name"] == "fleet.stream_failed" and e["sid"] == "bad"
               for e in snap["events"])


def test_fleet_transient_pack_fault_retry_bitwise(fresh_meters):
    cfg = _cfg()

    def run_fleet(with_chaos):
        server = FleetServer(pack_workers=2, gather_window=0.0,
                             retry_backoff=0.001)
        for i in range(2):
            inj = (chaos.ChaosInjector(
                chaos.ChaosConfig(pack_fault_cycles=(1, 3)))
                if with_chaos else None)
            server.add_stream(f"s{i}", cfg, _stream(cycles=5, seed=i),
                              chaos=inj)
        return server.serve()

    a, b = run_fleet(False), run_fleet(True)
    for sid in a:
        assert a[sid].deterministic_json() == b[sid].deterministic_json()
    assert fresh_meters.snapshot()["counters"]["chaos.retries"] >= 4


def test_fleet_cohort_solve_retry_bitwise(fresh_meters):
    cfg = _cfg()

    def run_fleet(inj):
        server = FleetServer(pack_workers=2, gather_window=0.0,
                             retry_backoff=0.001, chaos=inj)
        for i in range(2):
            server.add_stream(f"s{i}", cfg, _stream(cycles=5, seed=i))
        return server.serve()

    a = run_fleet(None)
    b = run_fleet(chaos.ChaosInjector(
        chaos.ChaosConfig(solve_fault_cycles=(0, 2))))
    for sid in a:
        assert a[sid].deterministic_json() == b[sid].deterministic_json()
    assert fresh_meters.snapshot()["counters"]["chaos.retries"] >= 2


def test_fleet_snapshot_resume_bitwise(tmp_path, fresh_meters):
    cfg = _cfg()
    cycles = 7
    base = AssimilationEngine(cfg).run(
        streams.ResumableStream("drifting_swarm", 60, cycles, seed=4))
    ck = str(tmp_path / "fleet")
    server = FleetServer(pack_workers=2, gather_window=0.0)
    server.add_stream("s", cfg,
                      streams.ResumableStream("drifting_swarm", 60, cycles,
                                              seed=4),
                      checkpoint_dir=ck, snapshot_every=3)
    fleet_j = server.serve()["s"]
    assert fleet_j.deterministic_json() == base.deterministic_json()
    # Cross-path resume: a fleet-taken snapshot continues bitwise under
    # the single-engine run loop.
    eng2, stream2 = elastic.resume_assim_engine(
        os.path.join(ck, "step_00000003"))
    assert stream2.pos == 3
    j = eng2.run(stream2)
    assert j.deterministic_json() == base.deterministic_json()


# ---------------------------------------------------------------------------
# Subprocess integration: SIGKILL mid-stream + elastic restart under a
# forced-host CPU mesh.
# ---------------------------------------------------------------------------

_CHILD_PRELUDE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.assim.engine import AssimilationEngine, EngineConfig
from repro.assim import streams
from repro.runtime import elastic
from repro.runtime.chaos import ChaosConfig, ChaosInjector
"""


def _run_child(script, devices=None, timeout=300):
    env = dict(os.environ)
    if devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    return subprocess.run([sys.executable, "-c",
                           _CHILD_PRELUDE + script], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_kill_and_resume_bitwise_subprocess(tmp_path):
    """SIGKILL the engine mid-stream (after the cycle-6 snapshot), resume
    in this process from the surviving checkpoint, and require the
    concatenated journal to be bitwise identical to an uninterrupted
    run."""
    ck = str(tmp_path / "ck")
    out = _run_child(f"""
inj = ChaosInjector(ChaosConfig(kill_cycles=(5,)))
eng = AssimilationEngine(EngineConfig(n=48, p=3, iters=6), chaos=inj)
eng.run(streams.ResumableStream("drifting_swarm", 60, 10, seed=2),
        checkpoint_dir=r"{ck}", snapshot_every=2)
print("UNREACHABLE")
""")
    assert out.returncode == -signal.SIGKILL, out.stderr[-2000:]
    assert "UNREACHABLE" not in out.stdout

    from repro.checkpoint import manager as ckpt
    latest = ckpt.latest_checkpoint(ck)
    assert latest is not None and latest.endswith("step_00000006")

    base = AssimilationEngine(EngineConfig(n=48, p=3, iters=6)).run(
        streams.ResumableStream("drifting_swarm", 60, 10, seed=2))
    eng2, stream2 = elastic.resume_assim_engine(ck)
    assert stream2.pos == 6
    j = eng2.run(stream2)
    assert j.deterministic_json() == base.deterministic_json()


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["shelf", "kdtree"])
@pytest.mark.parametrize("new_p", [4, 2])
def test_elastic_restart_forced_host_subprocess(tmp_path, kind, new_p):
    """Save at p=8 under an 8-device forced-host CPU mesh; restart at
    p=4 / p=2 under a matching smaller mesh — the stream continues
    without replaying any completed cycle (acceptance criterion)."""
    ck = str(tmp_path / kind)
    cfg_src = {
        "shelf": "EngineConfig(n=64, ndim=2, nx=8, ny=8, pr=4, pc=2, "
                 "iters=6)",
        "kdtree": "EngineConfig(n=64, domain_kind='kdtree', p=8, nx=8, "
                  "ny=8, iters=6)",
    }[kind]
    out = _run_child(f"""
eng = AssimilationEngine({cfg_src})
assert eng.p == 8
eng.run(streams.ResumableStream("rotating_swarm", 80, 6, seed=9),
        checkpoint_dir=r"{ck}", snapshot_every=3)
print("SAVED")
""", devices=8)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SAVED" in out.stdout

    out = _run_child(f"""
import os
eng, stream = elastic.resume_assim_engine(
    os.path.join(r"{ck}", "step_00000003"), p={new_p})
assert eng.p == {new_p}, eng.p
assert stream.pos == 3, stream.pos
j = eng.run(stream)
assert [r.cycle for r in j.records] == list(range(6))
assert all(len(r.loads) == {new_p} for r in j.records[3:])
assert all(len(r.loads) == 8 for r in j.records[:3])
assert j.meta["resume"][-1]["remeshed"] is True
print("RESUMED")
""", devices=new_p)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RESUMED" in out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["shelf", "kdtree"])
@pytest.mark.parametrize("p_old,p_new", [(2, 4), (4, 8)])
def test_elastic_growth_forced_host_subprocess(tmp_path, kind, p_old,
                                               p_new):
    """The growth direction: save at p under a forced-host mesh matching
    p, restart at 2p under a doubled mesh.  The stream continues without
    replaying any completed cycle, and the re-derived load-aware cut
    beats a cold default decomposition of the same shape on the first
    post-restart cycle's incoming imbalance (the point of carrying the
    load history through the remesh; satellite_track's anisotropic swath
    structure persists across cycles, so the journalled density is
    informative)."""
    ck = str(tmp_path / f"{kind}{p_old}")
    shelf_grid = {2: "pr=2, pc=1", 4: "pr=2, pc=2"}
    cfg_src = (
        f"EngineConfig(n=128, ndim=2, nx=16, ny=8, {shelf_grid[p_old]}, "
        f"iters=6)" if kind == "shelf"
        else f"EngineConfig(n=128, domain_kind='kdtree', p={p_old}, "
             f"nx=16, ny=8, iters=6)")
    out = _run_child(f"""
eng = AssimilationEngine({cfg_src})
assert eng.p == {p_old}
eng.run(streams.ResumableStream("satellite_track", 240, 8, seed=3),
        checkpoint_dir=r"{ck}", snapshot_every=4)
print("SAVED")
""", devices=p_old)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SAVED" in out.stdout

    out = _run_child(f"""
import os
from repro.assim.metrics import imbalance_ratio
from repro.core import domain as domain_mod
from repro.core import kdtree as kdtree_mod
eng, stream = elastic.resume_assim_engine(
    os.path.join(r"{ck}", "step_00000004"), p={p_new})
assert eng.p == {p_new}, eng.p
assert stream.pos == 4, stream.pos
if "{kind}" == "shelf":
    cold_dom = domain_mod.ShelfTiling2D(nx=16, ny=8, pr=eng.domain.pr,
                                        pc=eng.domain.pc)
else:
    cold_dom = kdtree_mod.KDTreeDomain(nx=16, ny=8, p={p_new})
j = eng.run(stream)
assert [r.cycle for r in j.records] == list(range(8))
assert all(len(r.loads) == {p_new} for r in j.records[4:])
assert all(len(r.loads) == {p_old} for r in j.records[:4])
assert j.meta["resume"][-1] == {{"at_cycle": 4, "p": {p_new},
                                 "remeshed": True}}
it = streams.make_stream("satellite_track", 240, 8, seed=3)
obs4 = [next(it) for _ in range(5)][4]
warm = j.records[4].imbalance_before
cold = imbalance_ratio(cold_dom.counts(obs4))
assert warm < cold, (warm, cold)
print("GROWN", warm, cold)
""", devices=p_new)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "GROWN" in out.stdout
