"""Fault-tolerant checkpointing: atomicity, corruption detection, async,
elastic re-shard."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.checkpoint import manager as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 8))),
                   "b": jnp.asarray(rng.normal(size=(8,)))},
        "opt": {"m": jnp.zeros((4, 8)), "step": jnp.asarray(7)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    path = ckpt.save_pytree(tree, str(tmp_path), step=3,
                            metadata={"loader": {"seed": 1, "step": 9}})
    got, manifest = ckpt.restore_pytree(path, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 3
    assert manifest["metadata"]["loader"]["step"] == 9


def test_verify_detects_corruption(tmp_path):
    tree = _tree()
    path = ckpt.save_pytree(tree, str(tmp_path), step=1)
    assert ckpt.verify(path)
    # corrupt one leaf file
    files = [f for f in os.listdir(path) if f.endswith(".npy")]
    victim = os.path.join(path, files[0])
    arr = np.load(victim)
    np.save(victim, arr + 1)
    assert not ckpt.verify(path)


def test_latest_skips_torn_checkpoint(tmp_path):
    tree = _tree()
    p1 = ckpt.save_pytree(tree, str(tmp_path), step=1)
    p2 = ckpt.save_pytree(tree, str(tmp_path), step=2)
    # tear the newest
    files = [f for f in os.listdir(p2) if f.endswith(".npy")]
    os.remove(os.path.join(p2, files[0]))
    assert ckpt.latest_checkpoint(str(tmp_path)) == p1


def test_tmp_dirs_ignored(tmp_path):
    tree = _tree()
    ckpt.save_pytree(tree, str(tmp_path), step=1)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    latest = ckpt.latest_checkpoint(str(tmp_path))
    assert latest.endswith("step_00000001")


def test_manager_async_and_gc(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in range(5):
        mgr.save(tree, step=s, blocking=False)
    mgr.wait()
    kept = sorted(d for d in os.listdir(str(tmp_path))
                  if d.startswith("step_"))
    assert len(kept) == 2
    got = mgr.restore_latest(like=tree)
    assert got is not None
    mgr.close()


def test_restore_shape_mismatch_raises(tmp_path):
    tree = _tree()
    path = ckpt.save_pytree(tree, str(tmp_path), step=1)
    bad = {"params": {"w": jnp.zeros((3, 3)),
                      "b": jnp.zeros((8,))},
           "opt": {"m": jnp.zeros((4, 8)), "step": jnp.asarray(0)}}
    with pytest.raises(ValueError):
        ckpt.restore_pytree(path, like=bad)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_roundtrip_random_trees(tmp_path_factory, seed):
    tmp = tmp_path_factory.mktemp(f"ck{seed}")
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(rng.integers(1, 10),))),
            "nested": {"b": jnp.asarray(rng.integers(0, 5, size=(3, 2)))}}
    path = ckpt.save_pytree(tree, str(tmp), step=0)
    got, _ = ckpt.restore_pytree(path, like=tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.fixture()
def fresh_meters():
    from repro.obs import meters as obs_meters
    prev = obs_meters.get_meters()
    m = obs_meters.Meters()
    obs_meters.set_meters(m)
    yield m
    obs_meters.set_meters(prev)


def test_latest_falls_back_on_truncated_leaf(tmp_path, fresh_meters):
    """A leaf .npy truncated mid-bytes (torn write surviving the rename)
    fails hash verification; latest_checkpoint falls back to the
    previous verified step and journals the skip."""
    from repro.runtime import chaos as chaos_mod
    tree = _tree()
    p1 = ckpt.save_pytree(tree, str(tmp_path), step=1)
    p2 = ckpt.save_pytree(tree, str(tmp_path), step=2)
    chaos_mod.tear_checkpoint(p2, seed=3)
    assert not ckpt.verify(p2)
    assert ckpt.latest_checkpoint(str(tmp_path)) == p1
    snap = fresh_meters.snapshot()
    assert snap["counters"]["checkpoint.corrupt_skipped"] == 1
    assert any(e["name"] == "checkpoint.corrupt_skipped"
               and e["path"] == p2 for e in snap["events"])


def test_latest_falls_back_on_corrupt_manifest(tmp_path):
    tree = _tree()
    p1 = ckpt.save_pytree(tree, str(tmp_path), step=1)
    p2 = ckpt.save_pytree(tree, str(tmp_path), step=2)
    from repro.runtime import chaos as chaos_mod
    chaos_mod.corrupt_manifest(p2, seed=7)
    assert ckpt.latest_checkpoint(str(tmp_path)) == p1


def test_gc_removes_stale_tmp_keeps_live(tmp_path, fresh_meters):
    """_gc sweeps staging dirs whose writer pid is dead, and leaves
    another live writer's staging dir alone."""
    import subprocess
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    # A certainly-dead writer pid: spawn-and-reap a child.
    child = subprocess.Popen(["true"])
    child.wait()
    dead = os.path.join(str(tmp_path),
                        f"step_00000005.{child.pid}-1.tmp")
    live = os.path.join(str(tmp_path),
                        f"step_00000006.{os.getpid()}-123.tmp")
    other = os.path.join(str(tmp_path), "step_00000007.tmp")  # no pid tag
    for d in (dead, live, other):
        os.makedirs(d)
    for s in range(3):
        mgr.save(tree, step=s, blocking=False)
    mgr.wait()
    assert not os.path.exists(dead)
    assert os.path.exists(live)
    assert os.path.exists(other)   # unparseable: never touched
    snap = fresh_meters.snapshot()
    assert snap["counters"]["checkpoint.stale_tmp_removed"] >= 1
    mgr.close()


def test_async_save_failure_emits_event(tmp_path, fresh_meters):
    """An async save that dies surfaces as an obs event/counter at
    failure time, and still raises on wait()."""
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    # Squat a regular file on the checkpoint directory path so the
    # worker's makedirs fails — a realistic misconfigured-path failure.
    shutil.rmtree(str(tmp_path))
    with open(str(tmp_path), "w") as f:
        f.write("not a directory")
    mgr.save(_tree(), step=1, blocking=False)
    with pytest.raises(Exception):
        mgr.wait()
    snap = fresh_meters.snapshot()
    assert snap["counters"]["checkpoint.save_failed"] == 1
    assert any(e["name"] == "checkpoint.save_failed" and e["step"] == 1
               for e in snap["events"])
    mgr.close()
    os.remove(str(tmp_path))


@pytest.mark.slow
def test_elastic_remesh_subprocess(tmp_path):
    """Save under a (2,2) mesh, restore under (4,1) and (1,2) — the
    scale-up/down path (DESIGN.md §8)."""
    import subprocess
    import sys
    script = f"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import manager as ckpt
from repro.core._compat import make_device_mesh

tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
mesh1 = make_device_mesh((2, 2), ("data", "model"))
sharded = jax.device_put(tree["w"], NamedSharding(mesh1, P("data", "model")))
ckpt.save_pytree({{"w": sharded}}, r"{tmp_path}", step=1)

for shape, axes, spec in [((4, 1), ("data", "model"), P("data", None)),
                          ((1, 2), ("data", "model"), P(None, "model"))]:
    mesh2 = make_device_mesh(shape, axes)
    like = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float64)}}
    shardings = {{"w": NamedSharding(mesh2, spec)}}
    got, _ = ckpt.restore_pytree(r"{tmp_path}", like=like,
                                 shardings=shardings)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.arange(64.0).reshape(8, 8))
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([__import__("sys").executable, "-c", script],
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
