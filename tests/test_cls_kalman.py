"""CLS model + Kalman filter: the paper's reference solvers (§2-3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cls, kalman


@pytest.fixture(scope="module")
def prob():
    return cls.random_problem(jax.random.PRNGKey(0), n=48, m0=64, m1=80)


def test_normal_equations_solve_minimizes(prob):
    x = cls.solve(prob)
    j0 = cls.objective(prob, x)
    # any perturbation increases J (SPD normal matrix)
    for seed in range(3):
        d = 1e-3 * jax.random.normal(jax.random.PRNGKey(seed), (prob.n,),
                                     jnp.float64)
        assert cls.objective(prob, x + d) > j0


def test_gradient_zero_at_solution(prob):
    x = cls.solve(prob)
    g = jax.grad(lambda v: cls.objective(prob, v))(x)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-8)


def test_cg_matches_cholesky(prob):
    x_chol = cls.solve(prob)
    x_cg = cls.solve_cg(prob)
    np.testing.assert_allclose(np.asarray(x_cg), np.asarray(x_chol),
                               atol=1e-8)


def test_kf_sequential_equals_direct(prob):
    """The paper's KF-on-CLS reference: sequential assimilation of the
    observation rows reaches the CLS solution (error ~ 1e-11, §6)."""
    x_direct = cls.solve(prob)
    x_kf = kalman.solve_cls_sequential(prob, block=1)
    assert float(jnp.linalg.norm(x_kf - x_direct)) < 1e-9


def test_kf_blocked_assimilation(prob):
    x_direct = cls.solve(prob)
    x_kf = kalman.solve_cls_sequential(prob, block=8)
    assert float(jnp.linalg.norm(x_kf - x_direct)) < 1e-9


def test_kf_predict_correct_shapes():
    n, m = 8, 5
    st = kalman.KFState(x=jnp.zeros(n), P=jnp.eye(n))
    M = 0.9 * jnp.eye(n)
    Q = 0.01 * jnp.eye(n)
    st = kalman.predict(st, M, Q)
    H = jnp.ones((m, n)) / n
    st = kalman.correct(st, H, jnp.ones(m), jnp.ones(m))
    assert st.x.shape == (n,) and st.P.shape == (n, n)
    # covariance stays symmetric PSD-ish
    np.testing.assert_allclose(np.asarray(st.P), np.asarray(st.P.T),
                               atol=1e-10)


def test_kf_run_scan():
    n, m, r = 6, 4, 5
    key = jax.random.PRNGKey(1)
    Ms = jnp.stack([0.95 * jnp.eye(n)] * r)
    Qs = jnp.stack([0.01 * jnp.eye(n)] * r)
    Hs = jax.random.normal(key, (r, m, n), jnp.float64)
    ys = jnp.ones((r, m))
    Rs = jnp.ones((r, m))
    final, xs = kalman.run(jnp.zeros(n), jnp.eye(n), Ms, Qs, Hs, ys, Rs)
    assert xs.shape == (r, n)
    assert not bool(jnp.any(jnp.isnan(final.x)))


def test_local_problem_is_spatially_local():
    obs = np.linspace(0.05, 0.3, 20)  # all obs in the left third
    prob = cls.local_problem(jax.random.PRNGKey(0), 64, obs)
    H1 = np.asarray(prob.H1)
    # every H1 row's support lies in the left half of the columns
    nz = np.nonzero(H1)[1]
    assert nz.max() < 32


def test_observation_operator_block_confines_stencil():
    """With block=nx (a raster-ordered 2D mesh) an interpolation window
    near a mesh-row edge must not leak onto the next row's first column,
    which is physically on the opposite side of the domain."""
    n, nx = 24, 12
    pos = [11.7 / n]  # center column 11, the last column of raster row 0
    leaky = cls.observation_operator(n, pos)
    assert leaky[0, nx:].sum() > 0  # unconfined: weight crosses the seam
    H = cls.observation_operator(n, pos, block=nx)
    assert H[0, nx:].sum() == 0.0
    np.testing.assert_allclose(H[0].sum(), 1.0)
    # block spanning the whole vector is a no-op (the 1D degenerate case)
    np.testing.assert_array_equal(
        cls.observation_operator(n, pos, block=n), leaky)
