"""DD-CLS Schwarz iteration (paper §4) and DD-KF (the distributed solve)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import cls, dd, ddkf, dydd


@pytest.fixture(scope="module")
def local_prob():
    rng = np.random.default_rng(0)
    obs = rng.beta(2.0, 5.0, 300)
    return cls.local_problem(jax.random.PRNGKey(0), 96, obs), obs


def test_reduction_extension_roundtrip():
    w = jnp.arange(1.0, 6.0)
    idx = jnp.asarray([1, 3, 4])
    r = dd.restrict_vec(w, idx)
    e = dd.extend_vec(r, idx, 5)
    np.testing.assert_array_equal(np.asarray(e), [0, 2, 0, 4, 5])


@settings(max_examples=30, deadline=None)
@given(n=st.integers(8, 64), seed=st.integers(0, 10_000))
def test_extend_restrict_identity(n, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(np.sort(rng.choice(n, size=max(1, n // 3),
                                         replace=False)))
    w = jnp.asarray(rng.normal(size=len(idx)))
    assert np.allclose(dd.restrict_vec(dd.extend_vec(w, idx, n), idx), w)


def test_decompose_1d_partitions_columns():
    dec = dd.decompose_1d(60, dd.uniform_boundaries(4), overlap=0)
    cols = np.concatenate([np.asarray(c) for c in dec.col_sets])
    np.testing.assert_array_equal(np.sort(cols), np.arange(60))


def test_decompose_1d_overlap_sets():
    dec = dd.decompose_1d(60, dd.uniform_boundaries(3), overlap=2)
    ovs = dec.overlap_sets()
    assert all(len(o) == 4 for o in ovs)   # 2 donated from each side


def test_multiplicative_schwarz_converges_to_cls(local_prob):
    prob, _ = local_prob
    x_direct = cls.solve(prob)
    for p in (2, 4):
        dec = dd.decompose_1d(prob.n, dd.uniform_boundaries(p))
        sol = dd.SchwarzSolver(prob, dec)
        x, iters, _ = sol.solve(iters=200, mode="multiplicative")
        assert float(jnp.linalg.norm(x - x_direct)) < 1e-9, (p, iters)


def test_additive_schwarz_converges_on_local_problem(local_prob):
    prob, _ = local_prob
    x_direct = cls.solve(prob)
    dec = dd.decompose_1d(prob.n, dd.uniform_boundaries(4))
    sol = dd.SchwarzSolver(prob, dec)
    x, iters, _ = sol.solve(iters=300, mode="additive")
    assert float(jnp.linalg.norm(x - x_direct)) < 1e-8


def test_overlap_schwarz_converges(local_prob):
    prob, _ = local_prob
    x_direct = cls.solve(prob)
    dec = dd.decompose_1d(prob.n, dd.uniform_boundaries(3), overlap=2)
    sol = dd.SchwarzSolver(prob, dec, mu=1.0)
    x, _, hist = sol.solve(iters=300, mode="multiplicative")
    assert float(jnp.linalg.norm(x - x_direct)) < 1e-7
    assert hist[-1] < hist[0]


def test_ddkf_vmapped_equals_direct(local_prob):
    """error_DD-DA ~ 1e-11 (paper Table 11)."""
    prob, obs = local_prob
    x_direct = cls.solve(prob)
    for p in (2, 4, 8):
        res = dydd.dydd_1d(obs, p)
        dec = dd.decompose_1d(prob.n, res.boundaries)
        packed = ddkf.pack(prob, dec)
        x = ddkf.solve_vmapped(packed, iters=120)
        err = float(jnp.linalg.norm(x - x_direct))
        assert err < 1e-9, (p, err)


def test_ddkf_with_dydd_balances_and_solves(local_prob):
    prob, obs = local_prob
    x, res, dec = ddkf.ddkf_with_dydd(prob, obs, p=4, iters=150)
    assert res.efficiency > 0.95
    x_direct = cls.solve(prob)
    assert float(jnp.linalg.norm(x - x_direct)) < 1e-8


def test_ddkf_overlap_path(local_prob):
    prob, obs = local_prob
    x_direct = cls.solve(prob)
    dec = dd.decompose_1d(prob.n, dd.uniform_boundaries(3), overlap=2)
    packed = ddkf.pack(prob, dec, mu=1.0)
    x = ddkf.solve_vmapped(packed, iters=200)
    assert float(jnp.linalg.norm(x - x_direct)) < 1e-6
