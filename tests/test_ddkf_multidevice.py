"""shard_map DD-KF under real (forced) multi-device XLA — the production
communication path, exercised in a subprocess so the main test session
keeps its single-device view — plus parity of the device-side batched
operator packing (kernels.ops.gram + vmap(cholesky)) against the old
per-subdomain numpy Cholesky loop."""
import os
import subprocess
import sys

import jax
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cls, dd, ddkf, dydd

SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import cls, dd, ddkf, dydd, _compat

rng = np.random.default_rng(0)
obs = rng.beta(2, 5, size=400)
prob = cls.local_problem(jax.random.PRNGKey(0), 128, obs)
x_direct = cls.solve(prob)
res = dydd.dydd_1d(obs, 8)
dec = dd.decompose_1d(prob.n, res.boundaries, overlap=0)
packed = ddkf.pack(prob, dec)
mesh = _compat.make_device_mesh((8,), ("sub",))
x_s = ddkf.solve_shardmap(packed, mesh, axis="sub", iters=120)
err = float(jnp.linalg.norm(x_s - x_direct))
assert err < 1e-9, err
# the (m,) product reduce-scatter path (dense-network regime; here the
# auto switch picks it since m = 528 >= 2 * n) matches the plain psum
x_sc = ddkf.solve_shardmap(packed, mesh, axis="sub", iters=120,
                           mvec="scatter")
x_ps = ddkf.solve_shardmap(packed, mesh, axis="sub", iters=120,
                           mvec="psum")
d_m = float(np.abs(np.asarray(x_sc) - np.asarray(x_ps)).max())
assert d_m < 1e-13, d_m
# neighbour-only halo exchange (with overlap) matches allreduce to ULPs
dec2 = dd.decompose_1d(prob.n, res.boundaries, overlap=2)
packed2 = ddkf.pack(prob, dec2)
x_a = ddkf.solve_shardmap(packed2, mesh, axis="sub", iters=120)
x_n = ddkf.solve_shardmap(packed2, mesh, axis="sub", iters=120,
                          comm="neighbour", halo=dec2.halo_exchange)
d_c = float(np.abs(np.asarray(x_a) - np.asarray(x_n)).max())
assert d_c < 1e-13, d_c
err_n = float(jnp.linalg.norm(x_n - x_direct))
assert err_n < 1e-9, err_n
# fused Schwarz-step kernel (interpret path off-TPU): ULP parity with
# the jnp local step on both solvers
packed2f = ddkf.pack(prob, dec2, solver_kernel="fused_interpret")
assert packed2f.solve_kernel == "fused_interpret"
assert packed2f.solve_block is not None
x_vj = ddkf.solve_vmapped(packed2, iters=60, damping=0.7)
x_vf = ddkf.solve_vmapped(packed2f, iters=60, damping=0.7)
d_v = float(np.abs(np.asarray(x_vj) - np.asarray(x_vf)).max())
assert d_v < 1e-13, d_v
x_sj = ddkf.solve_shardmap(packed2, mesh, axis="sub", iters=60,
                           damping=0.7, comm="neighbour",
                           halo=dec2.halo_exchange)
x_sf = ddkf.solve_shardmap(packed2f, mesh, axis="sub", iters=60,
                           damping=0.7, comm="neighbour",
                           halo=dec2.halo_exchange)
d_f = float(np.abs(np.asarray(x_sj) - np.asarray(x_sf)).max())
assert d_f < 1e-13, d_f
# the packed buffer exchange issues exactly halo.rounds ppermutes per
# iteration (the fori_loop body is traced once) regardless of per-pair
# edge multiplicity
jaxpr = str(jax.make_jaxpr(lambda pk: ddkf.solve_shardmap(
    pk, mesh, axis="sub", iters=60, damping=0.7, comm="neighbour",
    halo=dec2.halo_exchange))(packed2))
n_pp = jaxpr.count("ppermute")
assert n_pp == dec2.halo_exchange.rounds, (n_pp,
                                           dec2.halo_exchange.rounds)
print("OK", err, d_m, d_c, d_v, d_f)
"""

SCRIPT_2D = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import cls, dd, ddkf, dydd2d, domain, _compat

ny, nx = 8, 16
n = nx * ny
dom = domain.ShelfTiling2D(nx=nx, ny=ny, pr=2, pc=4)
obs2 = dydd2d.make_observations_2d(400, kind="clustered", seed=4)
dom.rebalance(obs2)
dec = dom.decomposition(overlap=1)
obs_raster = (np.clip((obs2[:, 1] * ny).astype(int), 0, ny - 1) * nx
              + np.clip((obs2[:, 0] * nx).astype(int), 0, nx - 1)
              + 0.5) / n
prob = cls.local_problem(jax.random.PRNGKey(0), n, np.sort(obs_raster))
packed = ddkf.pack(prob, dec)
x_v = ddkf.solve_vmapped(packed, iters=200, damping=0.7)
mesh = _compat.make_device_mesh((2, 4), ("row", "col"))
x_s = ddkf.solve_shardmap(packed, mesh, axis=("row", "col"), iters=200,
                          damping=0.7)
# The grid-sharded solve runs the identical iteration; the collective
# reduction order differs from the batched einsum by a few ULPs, nothing
# more (bitwise-equal up to reduction associativity).
d = float(np.abs(np.asarray(x_v) - np.asarray(x_s)).max())
assert d < 1e-13, d
err = float(jnp.linalg.norm(x_s - cls.solve(prob)))
assert err < 1e-9, err
# neighbour-only halo exchange on the 2D mesh: ppermute rounds over the
# coloured edge schedule (grid neighbours + the corner halo∩halo pairs)
# reproduce the allreduce exchange to reduction-order ULPs.
x_n = ddkf.solve_shardmap(packed, mesh, axis=("row", "col"), iters=200,
                          damping=0.7, comm="neighbour",
                          halo=dec.halo_exchange)
d_n = float(np.abs(np.asarray(x_s) - np.asarray(x_n)).max())
assert d_n < 1e-13, d_n
err_n = float(jnp.linalg.norm(x_n - cls.solve(prob)))
assert err_n < 1e-9, err_n
# fused local step on the 2D device mesh: parity with the jnp path
packedf = ddkf.pack(prob, dec, solver_kernel="fused_interpret")
x_fj = ddkf.solve_shardmap(packed, mesh, axis=("row", "col"), iters=60,
                           damping=0.7, comm="neighbour",
                           halo=dec.halo_exchange)
x_ff = ddkf.solve_shardmap(packedf, mesh, axis=("row", "col"), iters=60,
                           damping=0.7, comm="neighbour",
                           halo=dec.halo_exchange)
d_f = float(np.abs(np.asarray(x_fj) - np.asarray(x_ff)).max())
assert d_f < 1e-13, d_f
print("OK", d, err, d_n, d_f)
"""

SCRIPT_ENGINE = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.assim import AssimilationEngine, EngineConfig

kw = dict(ndim=2, nx=16, ny=8, pr=2, pc=4, iters=200, damping=0.7,
          overlap=1, imbalance_threshold=1.5)
js = AssimilationEngine(EngineConfig(solver="shardmap", **kw)).run_scenario(
    "rotating_swarm", m=160, cycles=2, seed=0)
jv = AssimilationEngine(EngineConfig(solver="vmapped", **kw)).run_scenario(
    "rotating_swarm", m=160, cycles=2, seed=0)
jn = AssimilationEngine(EngineConfig(solver="shardmap", comm="neighbour",
                                     **kw)).run_scenario(
    "rotating_swarm", m=160, cycles=2, seed=0)
for a, b, c in zip(js.records, jv.records, jn.records):
    assert a.loads == b.loads == c.loads
    assert a.repartitioned == b.repartitioned == c.repartitioned
    # neighbour path journals strictly less modelled traffic
    assert c.comm_bytes_per_cycle < a.comm_bytes_per_cycle
print("OK")
"""


SCRIPT_KDTREE = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import cls, ddkf, kdtree, _compat
from repro.assim import streams, AssimilationEngine, EngineConfig

# Irregular-graph halo exchange: a rebalanced 8-leaf k-d tree's face
# adjacency is NOT a grid, so the coloured ppermute schedule runs between
# arbitrary device pairs of the flat ("sub",) mesh.
dom = kdtree.KDTreeDomain(nx=16, ny=8, p=8)
obs2 = next(iter(streams.make_stream("satellite_track", 400, 1, seed=3)))
dom.rebalance(obs2)
dec = dom.decomposition(overlap=1)
he = dec.halo_exchange
assert len(he.edges) > 7, he.edges            # more than a chain
prob = cls.local_problem(jax.random.PRNGKey(0), dom.n,
                         np.sort(dom.obs_positions(obs2)))
packed = ddkf.pack(prob, dec)
mesh = _compat.make_device_mesh((8,), ("sub",))
x_a = ddkf.solve_shardmap(packed, mesh, axis="sub", iters=200, damping=0.7)
x_n = ddkf.solve_shardmap(packed, mesh, axis="sub", iters=200, damping=0.7,
                          comm="neighbour", halo=he)
d = float(np.abs(np.asarray(x_a) - np.asarray(x_n)).max())
assert d < 1e-13, d
err = float(jnp.linalg.norm(x_n - cls.solve(prob)))
assert err < 1e-9, err
# fused local step over the irregular leaf graph: parity with jnp
packedf = ddkf.pack(prob, dec, solver_kernel="fused_interpret")
x_fj = ddkf.solve_shardmap(packed, mesh, axis="sub", iters=60,
                           damping=0.7, comm="neighbour", halo=he)
x_ff = ddkf.solve_shardmap(packedf, mesh, axis="sub", iters=60,
                           damping=0.7, comm="neighbour", halo=he)
d_f = float(np.abs(np.asarray(x_fj) - np.asarray(x_ff)).max())
assert d_f < 1e-13, d_f
# engine end to end on the leaf graph, both comm paths + vmapped parity
kw = dict(ndim=2, domain_kind="kdtree", p=8, nx=16, ny=8, iters=200,
          damping=0.7, overlap=1, imbalance_threshold=1.5)
js = AssimilationEngine(EngineConfig(solver="shardmap", **kw)).run_scenario(
    "satellite_track", m=160, cycles=2, seed=0)
jn = AssimilationEngine(EngineConfig(solver="shardmap", comm="neighbour",
                                     **kw)).run_scenario(
    "satellite_track", m=160, cycles=2, seed=0)
jv = AssimilationEngine(EngineConfig(solver="vmapped", **kw)).run_scenario(
    "satellite_track", m=160, cycles=2, seed=0)
for a, b, c in zip(js.records, jn.records, jv.records):
    assert a.loads == b.loads == c.loads
    assert a.repartitioned == b.repartitioned == c.repartitioned
    # neighbour path journals strictly less modelled traffic
    assert b.comm_bytes_per_cycle < a.comm_bytes_per_cycle
print("OK", d, err)
"""


SCRIPT_TIMEPAR = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.assim import AssimilationEngine, EngineConfig, streams
from repro.assim.timepar import TimeParEngine

name, m, cycles, seed = "drifting_swarm", 160, 12, 0
kw = dict(n=64, p=2, iters=60)

seq = AssimilationEngine(EngineConfig(**kw))
chain = []
seq.on_analysis = lambda cycle, x: chain.append(np.asarray(x))
seq.run(streams.make_stream(name, m, cycles, seed=seed))

# 8 devices, W=4 windows, p=2 -> the auto mesh factors as
# ("time": 4, "sub": 2): windows shard over time, subdomains over sub.
cfg = EngineConfig(time_windows=4, pint_tol=1e-8, **kw)
tp = TimeParEngine(cfg)
journal = tp.run(streams.make_stream(name, m, cycles, seed=seed))
pint = journal.meta["pint"]
assert pint["mesh"] == {"time": 4, "sub": 2}, pint["mesh"]
assert pint["converged"], pint
assert len(tp.analyses) == cycles
diff = max(float(np.max(np.abs(a - b)))
           for a, b in zip(tp.analyses, chain))
assert diff < 1e-6, diff
for rw, rs in zip(journal.records, seq.journal.records):
    assert rw.loads == rs.loads
    assert rw.repartitioned == rs.repartitioned
print("OK", pint["iters"], diff)
"""


def _run_forced_8dev(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_shardmap_ddkf_8_devices():
    _run_forced_8dev(SCRIPT)


@pytest.mark.slow
def test_shardmap_ddkf_2d_mesh_matches_vmapped():
    """2D shelf tiling with halo overlap on a real 2 x 4 device mesh:
    grid axes map onto mesh axes; result matches solve_vmapped to
    reduction-order ULPs and the direct CLS solve to 1e-9."""
    _run_forced_8dev(SCRIPT_2D)


@pytest.mark.slow
def test_engine_shardmap_journal_matches_vmapped():
    """AssimilationEngine with solver='shardmap' auto-builds the pr x pc
    mesh and journals the same loads/repartitions as the vmapped run."""
    _run_forced_8dev(SCRIPT_ENGINE)


@pytest.mark.slow
def test_kdtree_shardmap_irregular_graph_8_devices():
    """KDTreeDomain end to end on a forced 8-device mesh: the leaf
    face-adjacency graph is irregular (first real exercise of the
    graph-general halo machinery beyond chains and grids), and the
    neighbour-only ppermute exchange matches allreduce to ULPs."""
    _run_forced_8dev(SCRIPT_KDTREE)


@pytest.mark.slow
def test_timepar_time_sub_mesh_8_devices():
    """Parareal engine on a forced 8-device ("time", "sub") mesh:
    windows shard over the time axis, subdomains over sub, and the
    converged analysis chain matches the sequential engine within the
    Parareal tolerance."""
    _run_forced_8dev(SCRIPT_TIMEPAR)


# ---------------------------------------------------------------------------
# Device-side operator packing parity vs the old numpy Cholesky loop.
# ---------------------------------------------------------------------------

def _pack_factors_numpy(A, r, dec, mu):
    """The pre-refactor reference: per-subdomain numpy normal matrices and
    Cholesky factors (what ddkf.pack_operator used to build on the host)."""
    A = np.asarray(A)
    r = np.asarray(r)
    m, n = A.shape
    w = max(int(np.asarray(c).shape[0]) for c in dec.col_sets)
    counts = np.zeros(n, dtype=np.int64)
    for c in dec.col_sets:
        counts[np.asarray(c)] += 1
    L_ref = np.zeros((dec.p, w, w), dtype=A.dtype)
    for i, c in enumerate(dec.col_sets):
        c = np.asarray(c)
        k = c.shape[0]
        A_i = np.zeros((m, w), dtype=A.dtype)
        A_i[:, :k] = A[:, c]
        N = (A_i.T * r) @ A_i
        if dec.overlap > 0 and mu > 0.0:
            ov = (counts[c] > 1).astype(N.dtype)
            N[:k, :k] += mu * np.diag(ov)
        pad = np.arange(k, w)
        N[pad, pad] = 1.0
        L_ref[i] = np.linalg.cholesky(N)
    return L_ref


@pytest.mark.parametrize("overlap,mu", [(0, 1.0), (2, 0.7)])
def test_pack_operator_gram_matches_numpy_loop(overlap, mu):
    rng = np.random.default_rng(3)
    obs = rng.beta(2, 5, 300)
    prob = cls.local_problem(jax.random.PRNGKey(0), 96, obs)
    res = dydd.dydd_1d(obs, 6)
    dec = dd.decompose_1d(prob.n, res.boundaries, overlap=overlap)
    A, b, r = prob.stacked()

    packed = ddkf.pack_operator(A, r, dec, mu=mu)
    L_ref = _pack_factors_numpy(A, r, dec, mu)
    np.testing.assert_allclose(np.asarray(packed.L_loc), L_ref,
                               rtol=1e-10, atol=1e-10)
    # and the packed solve still matches the direct CLS estimate
    x = ddkf.solve_vmapped(ddkf.with_rhs(packed, b), iters=150)
    err = float(jnp.linalg.norm(x - cls.solve(prob)))
    assert err < 1e-8, err


def test_pack_operator_gram_interpret_mode_close():
    """Forcing the Pallas gram kernel (interpret mode, f32 accumulation)
    keeps the factors within kernel tolerance of the f64 reference."""
    rng = np.random.default_rng(4)
    obs = np.sort(rng.uniform(0, 1, 200))
    prob = cls.local_problem(jax.random.PRNGKey(1), 64, obs)
    dec = dd.decompose_1d(prob.n, dd.uniform_boundaries(4))
    A, _, r = prob.stacked()
    ref = ddkf.pack_operator(A, r, dec, gram_mode="ref")
    ker = ddkf.pack_operator(A, r, dec, gram_mode="interpret")
    np.testing.assert_allclose(np.asarray(ker.L_loc),
                               np.asarray(ref.L_loc), rtol=2e-3, atol=2e-3)
