"""shard_map DD-KF under real (forced) multi-device XLA — the production
communication path, exercised in a subprocess so the main test session
keeps its single-device view."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import cls, dd, ddkf, dydd

rng = np.random.default_rng(0)
obs = rng.beta(2, 5, size=400)
prob = cls.local_problem(jax.random.PRNGKey(0), 128, obs)
x_direct = cls.solve(prob)
res = dydd.dydd_1d(obs, 8)
dec = dd.decompose_1d(prob.n, res.boundaries, overlap=0)
packed = ddkf.pack(prob, dec)
mesh = jax.make_mesh((8,), ("sub",),
                     axis_types=(jax.sharding.AxisType.Auto,))
x_s = ddkf.solve_shardmap(packed, mesh, axis="sub", iters=120)
err = float(jnp.linalg.norm(x_s - x_direct))
assert err < 1e-9, err
print("OK", err)
"""


@pytest.mark.slow
def test_shardmap_ddkf_8_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
