"""Validate the committed dry-run artifacts (deliverables e and g).

These tests read results/dryrun_*.json produced by repro.launch.dryrun on
the production meshes; they skip gracefully on a fresh clone.
"""
import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        pytest.skip(f"{name} not generated (run repro.launch.dryrun)")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("name,chips", [
    ("dryrun_singlepod.json", 256),
    ("dryrun_multipod.json", 512),
])
def test_dryrun_covers_all_cells_without_failures(name, chips):
    data = _load(name)
    assert len(data) == 40, "10 archs x 4 shapes"
    status = {k: v.get("status") for k, v in data.items()}
    fails = [k for k, s in status.items() if s == "fail"]
    assert not fails, fails
    n_ok = sum(1 for s in status.values() if s == "ok")
    n_skip = sum(1 for s in status.values() if s == "skipped")
    assert n_ok == 34 and n_skip == 6
    for k, v in data.items():
        if v["status"] != "ok":
            assert "long_500k" in k     # only documented skips
            continue
        assert v["chips"] == chips
        assert v["memory"]["peak_per_device"] > 0
        assert v["compute_s"] >= 0 and v["memory_s"] > 0


def test_roofline_terms_consistent():
    data = _load("dryrun_singlepod.json")
    for k, v in data.items():
        if v.get("status") != "ok":
            continue
        # dominant really is the max term
        terms = {"compute": v["compute_s"], "memory": v["memory_s"],
                 "collective": v["collective_s"]}
        assert v["dominant"] == max(terms, key=terms.get), k
        # roofline_frac = ideal compute / bound
        import math
        ideal = v["model_flops"] / (v["chips"] * 197e12)
        bound = max(terms.values())
        assert math.isclose(v["roofline_frac"], ideal / bound,
                            rel_tol=1e-6), k


def test_optimized_beats_baseline_on_hillclimbed_cells():
    """The §Perf wins are visible in the committed tables."""
    base_p = os.path.join(RESULTS, "dryrun_singlepod_baseline.json")
    if not os.path.exists(base_p):
        pytest.skip("baseline snapshot not present")
    base = json.load(open(base_p))
    opt = _load("dryrun_singlepod.json")
    # mixtral: collective down >=30%, fits-gap down
    k = "mixtral-8x22b|train_4k"
    assert opt[k]["collective_s"] < 0.7 * base[k]["collective_s"]
    assert opt[k]["memory"]["temp_bytes"] < 0.3 * \
        base[k]["memory"]["temp_bytes"]
    # olmoe: collective down >=25%
    k = "olmoe-1b-7b|train_4k"
    assert opt[k]["collective_s"] < 0.75 * base[k]["collective_s"]
    # gemma3: collective down >=15%
    k = "gemma3-1b|train_4k"
    assert opt[k]["collective_s"] < 0.85 * base[k]["collective_s"]
