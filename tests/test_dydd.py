"""DyDD scheduling/migration — paper §5, incl. the worked example."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import dydd


PAPER_EDGES = [(0, 1), (0, 2), (1, 2), (1, 3), (2, 3), (2, 4), (4, 5),
               (5, 6), (5, 7), (6, 7)]
PAPER_LOADS = np.array([5, 4, 6, 2, 5, 3, 5, 2])


def test_paper_laplacian_matrix():
    """eq. (30): the 8x8 Laplacian of the Figure-2 processor graph."""
    L = dydd.laplacian(8, PAPER_EDGES)
    expected = np.array([
        [2, -1, -1, 0, 0, 0, 0, 0],
        [-1, 3, -1, -1, 0, 0, 0, 0],
        [-1, -1, 4, -1, -1, 0, 0, 0],
        [0, -1, -1, 2, 0, 0, 0, 0],
        [0, 0, -1, 0, 2, -1, 0, 0],
        [0, 0, 0, 0, -1, 3, -1, -1],
        [0, 0, 0, 0, 0, -1, 2, -1],
        [0, 0, 0, 0, 0, -1, -1, 2],
    ], dtype=np.float64)
    np.testing.assert_array_equal(L, expected)


def test_paper_worked_example_deltas():
    """The published migrations: delta12=1, delta13=0, delta32=0,
    delta34=1, delta35=1, delta56=2, delta67=0, delta68=1, delta78=1."""
    sch = dydd.schedule(PAPER_LOADS, PAPER_EDGES)
    d = dict(zip(sch.edges, sch.deltas))
    assert d[(0, 1)] == 1          # delta_{1,2}
    assert d[(0, 2)] == 0          # delta_{1,3}
    assert d[(1, 2)] == 0          # -delta_{3,2}
    assert d[(2, 3)] == 1          # delta_{3,4}
    assert d[(2, 4)] == 1          # delta_{3,5}
    assert d[(4, 5)] == 2          # delta_{5,6}
    assert d[(5, 6)] == 0          # delta_{6,7}
    assert d[(5, 7)] == 1          # delta_{6,8}
    assert d[(6, 7)] == 1          # delta_{7,8}


def test_paper_worked_example_balances_to_average():
    """Figure 4: every subdomain ends at the average load 4."""
    final, _ = dydd.balance(PAPER_LOADS, PAPER_EDGES)
    np.testing.assert_array_equal(final, 4 * np.ones(8))


def test_balance_ratio():
    assert dydd.balance_ratio([4, 4, 4]) == 1.0
    assert dydd.balance_ratio([2, 4]) == 0.5


@settings(max_examples=60, deadline=None)
@given(
    p=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
    graph=st.sampled_from(["chain", "star", "ring"]),
)
def test_balance_properties(p, seed, graph):
    """Invariants for arbitrary loads on the paper's graph families:
    conservation, non-negativity, and E >= E_initial (never worse)."""
    rng = np.random.default_rng(seed)
    loads = rng.integers(0, 500, p)
    edges = {"chain": dydd.chain_edges, "star": dydd.star_edges,
             "ring": dydd.ring_edges}[graph](p)
    final, schedules = dydd.balance(loads, edges)
    assert final.sum() == loads.sum()
    assert final.min() >= 0
    assert dydd.balance_ratio(final) >= dydd.balance_ratio(loads) - 1e-12
    # movement restricted to graph edges by construction of Schedule.apply
    for sch in schedules:
        assert set(sch.edges) <= set(tuple(e) for e in edges)


@settings(max_examples=40, deadline=None)
@given(p=st.integers(2, 10), seed=st.integers(0, 2**31 - 1))
def test_balance_reaches_rounding_floor(p, seed):
    """On a chain, the final max deviation is within the rounding floor
    (paper Table 13 stopping criterion ~ deg/2)."""
    rng = np.random.default_rng(seed)
    loads = rng.integers(0, 300, p)
    final, _ = dydd.balance(loads, dydd.chain_edges(p), max_rounds=128)
    lbar = loads.sum() / p
    floor = max(1.0, max(dydd.degrees(p, dydd.chain_edges(p))) / 2.0)
    assert np.abs(final - lbar).max() <= floor + 1.0


def test_schedule_conserves_and_zero_on_balanced():
    loads = np.array([10, 10, 10, 10])
    sch = dydd.schedule(loads, dydd.chain_edges(4))
    assert sch.total_movement == 0


# ---------------------------------------------------------------------------
# Geometric 1D DyDD (DD step / migration / update).
# ---------------------------------------------------------------------------

def test_dydd_1d_balances_beta_distribution():
    rng = np.random.default_rng(0)
    obs = rng.beta(2, 5, 1500)
    res = dydd.dydd_1d(obs, 8)
    assert res.loads_final.sum() == 1500
    assert res.efficiency > 0.95


def test_dydd_1d_migration_exact_counts():
    rng = np.random.default_rng(1)
    obs = rng.uniform(0, 1, 999)
    res = dydd.dydd_1d(obs, 7)
    # update step recount equals the scheduled targets exactly
    lbar = 999 / 7
    assert np.abs(res.loads_final - lbar).max() <= 2.0


def test_dydd_1d_empty_subdomain_repartition():
    """Paper Example 1 Case 2 structure: one empty subdomain triggers the
    DD step (split the max-load adjacent subdomain)."""
    rng = np.random.default_rng(2)
    obs = rng.uniform(0, 0.5, 1500)    # right half empty under p=2
    res = dydd.dydd_1d(obs, 2)
    assert res.repartitioned
    assert res.loads_initial[1] == 0
    assert res.loads_final.min() > 0
    assert res.efficiency > 0.99


def test_dydd_1d_three_empty_subdomains():
    """Paper Example 2 Case 4 structure: 3 of 4 subdomains empty."""
    rng = np.random.default_rng(3)
    obs = rng.uniform(0.75, 1.0, 1500)  # all mass in the last quarter
    res = dydd.dydd_1d(obs, 4)
    assert (res.loads_initial[:3] == 0).all()
    assert res.loads_final.min() > 0
    assert res.efficiency > 0.95


def test_dydd_1d_tied_coordinates_realize_targets():
    """The ISSUE 5 repro: six observations at 0.1 and two at 0.9 under
    p=2 must realize the scheduled [4, 4] — not dump the whole tie group
    on one side ([0, 8]).  The boundary sits on the tied value and the
    rank split assigns four of the ties to the left subdomain."""
    res = dydd.dydd_1d(np.array([0.1] * 6 + [0.9] * 2), 2)
    np.testing.assert_array_equal(res.loads_final, [4, 4])
    np.testing.assert_array_equal(res.tie_ranks, [4])
    assert res.boundaries[1] == 0.1
    # True movement: [6, 2] (the initial geometric counts) -> [4, 4]
    # moves exactly two observations.
    assert res.total_movement == 2


def test_dydd_1d_all_identical_coordinates():
    """Every observation at the same point: rank splits still realize a
    perfect balance (the degenerate tie group spans all cuts)."""
    res = dydd.dydd_1d(np.full(12, 0.5), 4)
    np.testing.assert_array_equal(res.loads_final, [3, 3, 3, 3])
    assert res.efficiency == 1.0


def test_counts_zero_ranks_match_legacy_side_right():
    """tie_ranks=None / all-zero reproduces the historic
    searchsorted(side='right') counting bit for bit, including
    observations exactly on a boundary."""
    obs = np.array([0.0, 0.25, 0.25, 0.3, 0.5, 0.999])
    b = np.array([0.0, 0.25, 0.5, 1.0])
    legacy = np.bincount(
        np.clip(np.searchsorted(b, obs, side="right") - 1, 0, 2),
        minlength=3)
    np.testing.assert_array_equal(dydd._counts(obs, b), legacy)
    np.testing.assert_array_equal(
        dydd._counts(obs, b, np.zeros(2, np.int64)), legacy)
    # a nonzero rank moves exactly that many boundary-tied obs left
    np.testing.assert_array_equal(
        dydd._counts(obs, b, np.array([1, 0])), legacy + [1, -1, 0])


@settings(max_examples=50, deadline=None)
@given(p=st.integers(2, 8), q=st.integers(2, 12),
       seed=st.integers(0, 2**31 - 1))
def test_dydd_1d_quantized_realizes_balance_targets(p, q, seed):
    """Integer-grid (heavily tied) observation streams: the migration
    realizes the diffusion schedule's balance() targets *exactly* — the
    step-4 recount equals what balance() scheduled from the
    post-DD-step loads, and conservation holds."""
    rng = np.random.default_rng(seed)
    m = 64
    obs = rng.integers(0, q, m) / q
    res = dydd.dydd_1d(obs, p)
    targets, _ = dydd.balance(res.loads_repartitioned,
                              dydd.chain_edges(p))
    np.testing.assert_array_equal(res.loads_final, targets)
    assert res.loads_final.sum() == m
    # the realized decomposition is reproducible from the carried state
    np.testing.assert_array_equal(
        dydd._counts(obs, res.boundaries, res.tie_ranks), targets)


def test_star_graph_example3_structure():
    """Example 3: star topology (deg(1) = p-1)."""
    for p in (2, 4, 8, 16, 32):
        edges = dydd.star_edges(p)
        deg = dydd.degrees(p, edges)
        assert deg[0] == p - 1
        assert (deg[1:] == 1).all()
        rng = np.random.default_rng(p)
        loads = rng.integers(1, 200, p)
        final, _ = dydd.balance(loads, edges)
        assert final.sum() == loads.sum()
        assert dydd.balance_ratio(final) >= dydd.balance_ratio(loads)


def test_grid_torus_edges():
    edges = dydd.grid_edges(4, 4, torus=True)
    deg = dydd.degrees(16, edges)
    assert (deg == 4).all()     # torus is 4-regular


def test_schedule_jnp_matches_numpy():
    import jax.numpy as jnp
    loads = PAPER_LOADS.astype(np.float64)
    L = dydd.laplacian(8, PAPER_EDGES)
    pinv = np.linalg.pinv(L)
    inc = dydd.incidence_matrix(8, PAPER_EDGES)
    d_np = dydd.schedule(loads, PAPER_EDGES).deltas
    d_j = dydd.schedule_jnp(jnp.asarray(loads), jnp.asarray(pinv),
                            jnp.asarray(inc))
    np.testing.assert_array_equal(np.asarray(d_j, dtype=np.int64), d_np)
