"""2D DyDD — the paper's Ω ⊂ R² setting (Figures 1-4) — plus the gram
kernel (DD-KF normal-matrix hot spot)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.core import cls, dd, ddkf, dydd, dydd2d
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# 2D DyDD.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["uniform", "beta", "clustered"])
def test_dydd_2d_balances(kind):
    obs = dydd2d.make_observations_2d(1600, kind=kind, seed=3)
    res = dydd2d.dydd_2d(obs, pr=4, pc=4)
    assert res.loads_final.sum() == 1600
    assert res.efficiency > 0.95, res.loads_final
    # figures 1-4 structure: the initial clustered tiling is unbalanced
    if kind == "clustered":
        assert dydd.balance_ratio(res.loads_initial.reshape(-1)) < 0.5


def test_dydd_2d_empty_cells():
    """Figure 1's configuration: whole regions without observations."""
    rng = np.random.default_rng(0)
    obs = np.stack([rng.uniform(0, 0.45, 900),
                    rng.uniform(0.55, 1.0, 900)], axis=1)  # top-left only
    res = dydd2d.dydd_2d(obs, pr=2, pc=4)
    assert (res.loads_initial == 0).any()
    assert res.loads_final.min() > 0
    assert res.efficiency > 0.95


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), pr=st.integers(2, 4),
       pc=st.integers(2, 5))
def test_dydd_2d_properties(seed, pr, pc):
    obs = dydd2d.make_observations_2d(800, kind="clustered", seed=seed)
    res = dydd2d.dydd_2d(obs, pr=pr, pc=pc)
    assert res.loads_final.sum() == 800                  # conservation
    lbar = 800 / (pr * pc)
    assert np.abs(res.loads_final - lbar).max() <= max(2.0, 0.05 * lbar)
    # y-edges monotone; x-edges monotone per strip
    assert (np.diff(res.y_edges) >= 0).all()
    assert (np.diff(res.x_edges, axis=1) >= 0).all()


def test_dydd_2d_matches_grid_graph_schedule_floor():
    """The geometric result is at least as balanced as the grid-graph
    diffusion schedule's fixed point."""
    obs = dydd2d.make_observations_2d(1024, kind="beta", seed=9)
    res = dydd2d.dydd_2d(obs, pr=4, pc=4)
    graph_final, _ = dydd.balance(res.loads_initial.reshape(-1),
                                  dydd.grid_edges(4, 4, torus=False))
    assert res.efficiency >= dydd.balance_ratio(graph_final) - 0.02


def test_cell_col_sets_partition_mesh():
    obs = dydd2d.make_observations_2d(500, seed=1)
    res = dydd2d.dydd_2d(obs, pr=2, pc=3)
    sets = dydd2d.cell_col_sets(12, 10, res.y_edges, res.x_edges)
    allc = np.concatenate(sets)
    np.testing.assert_array_equal(np.sort(allc), np.arange(120))


# ---------------------------------------------------------------------------
# Halo (Schwarz overlap) column sets on the shelf tiling.
# ---------------------------------------------------------------------------

def test_cell_col_sets_halo_covers_and_overlaps():
    """overlap=s supersets the core partition; every column is still
    covered; interior seams carry multiplicity > 1."""
    obs = dydd2d.make_observations_2d(500, seed=1)
    res = dydd2d.dydd_2d(obs, pr=2, pc=3)
    core = dydd2d.cell_col_sets(12, 10, res.y_edges, res.x_edges)
    halo = dydd2d.cell_col_sets(12, 10, res.y_edges, res.x_edges,
                                overlap=2)
    counts = np.zeros(120, np.int64)
    for cset, hset in zip(core, halo):
        assert set(np.asarray(cset)) <= set(np.asarray(hset))
        assert (np.diff(hset) > 0).all()      # ascending, unique
        counts[hset] += 1
    assert counts.min() >= 1                   # full coverage
    assert counts.max() > 1                    # halos actually overlap


def test_cell_col_sets_halo_is_cross_shaped():
    """On a uniform 2x2 tiling of an 8x8 mesh with overlap=1, cell (0,0)
    absorbs one column from its right neighbour and one row from the
    strip below — but not the diagonal corner point (4,4)."""
    y = np.linspace(0, 1, 3)
    x = np.tile(np.linspace(0, 1, 3), (2, 1))
    halo = dydd2d.cell_col_sets(8, 8, y, x, overlap=1)
    cell00 = set(np.asarray(halo[0]).tolist())
    assert 0 * 8 + 4 in cell00        # right halo column, own rows
    assert 4 * 8 + 0 in cell00        # bottom halo row, own columns
    assert 4 * 8 + 4 not in cell00    # diagonal corner: not a neighbour
    # boundary clipping: nothing outside the mesh, nothing left of x=0
    assert min(cell00) == 0 and max(cell00) < 64


def test_cell_col_sets_empty_core_gets_no_halo():
    """A cell whose x-window holds no mesh column stays empty even with
    overlap > 0 (a halo without a core would break load accounting)."""
    y = np.linspace(0, 1, 2)
    x = np.array([[0.0, 0.001, 1.0]])     # cell (0,0) owns no column
    halo = dydd2d.cell_col_sets(8, 4, y, x, overlap=2)
    assert halo[0].size == 0
    assert halo[1].size == 32


def test_cell_col_sets_ny1_pr1_matches_decompose_1d():
    """Degenerate mesh: the halo construction reproduces the 1D interval
    overlap (eq. 21) exactly."""
    for s in (0, 1, 3):
        halo = dydd2d.cell_col_sets(
            48, 1, np.linspace(0, 1, 2),
            np.tile(np.linspace(0, 1, 5), (1, 1)), overlap=s)
        dec = dd.decompose_1d(48, dd.uniform_boundaries(4), overlap=s)
        for a, b in zip(halo, dec.col_sets):
            np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("overlap", [1, 2])
def test_ddkf_2d_overlap_converges_to_direct(overlap):
    """Multiplicity-weighted halo assembly: the overlapping 2D Schwarz
    solve reaches the same fixed point (the direct CLS estimate) as the
    overlap=0 block-exact decomposition."""
    nx, ny = 12, 8
    n = nx * ny
    obs2 = dydd2d.make_observations_2d(400, kind="clustered", seed=4)
    obs_raster = (np.clip((obs2[:, 1] * ny).astype(int), 0, ny - 1) * nx
                  + np.clip((obs2[:, 0] * nx).astype(int), 0, nx - 1)
                  + 0.5) / n
    prob = cls.local_problem(jax.random.PRNGKey(0), n, np.sort(obs_raster))
    res = dydd2d.dydd_2d(obs2, pr=2, pc=2)
    col_sets = dydd2d.cell_col_sets(nx, ny, res.y_edges, res.x_edges,
                                    overlap=overlap)
    dec = dd.Decomposition(n=n, col_sets=tuple(col_sets), overlap=overlap)
    assert dec.boundaries is None and dec.has_overlap
    packed = ddkf.pack(prob, dec)
    x = ddkf.solve_vmapped(packed, iters=300, damping=0.7)
    err = float(jnp.linalg.norm(x - cls.solve(prob)))
    assert err < 1e-6, err


def test_ddkf_on_2d_decomposition():
    """End-to-end: 2D DyDD tiling -> DD-KF solve == direct CLS (the 2D
    analogue of the paper's pipeline; Remark 4's I x J decomposition)."""
    nx, ny = 12, 8
    n = nx * ny
    obs2 = dydd2d.make_observations_2d(400, kind="clustered", seed=4)
    # project obs to 1D raster position for the spatially-local operator
    obs_raster = (np.clip((obs2[:, 1] * ny).astype(int), 0, ny - 1) * nx
                  + np.clip((obs2[:, 0] * nx).astype(int), 0, nx - 1)
                  + 0.5) / n
    prob = cls.local_problem(jax.random.PRNGKey(0), n, np.sort(obs_raster))
    res = dydd2d.dydd_2d(obs2, pr=2, pc=2)
    col_sets = dydd2d.cell_col_sets(nx, ny, res.y_edges, res.x_edges)
    col_sets = [c for c in col_sets if c.size]
    dec = dd.Decomposition(n=n, col_sets=tuple(col_sets),
                           boundaries=np.linspace(0, 1, len(col_sets) + 1),
                           overlap=0)
    packed = ddkf.pack(prob, dec)
    x = ddkf.solve_vmapped(packed, iters=250, damping=0.7)
    err = float(jnp.linalg.norm(x - cls.solve(prob)))
    assert err < 1e-6, err


def test_dydd_2d_reports_rounds_and_respects_cap():
    obs = dydd2d.make_observations_2d(1200, kind="clustered", seed=7)
    res = dydd2d.dydd_2d(obs, pr=3, pc=3, max_rounds=8)
    assert 1 <= res.rounds <= 8
    capped = dydd2d.dydd_2d(obs, pr=3, pc=3, max_rounds=1)
    assert capped.rounds == 1
    # more rounds can only do at least as well as the 1-round cap
    assert res.efficiency >= capped.efficiency - 1e-12


def test_dydd_2d_iterates_until_no_improvement():
    """The y-pass/x-pass pair is iterated: the returned loads are within
    integer rounding of the mean OR a further round would not improve."""
    obs = dydd2d.make_observations_2d(900, kind="beta", seed=11)
    res = dydd2d.dydd_2d(obs, pr=4, pc=4, max_rounds=8)
    lbar = 900 / 16
    dev = np.abs(res.loads_final - lbar).max()
    if dev >= 1.0:
        again = dydd2d.dydd_2d(obs, pr=4, pc=4,
                               y_edges=res.y_edges, x_edges=res.x_edges,
                               max_rounds=1)
        dev2 = np.abs(again.loads_final - lbar).max()
        assert dev2 >= dev - 1e-12


def test_dydd_2d_warm_start_boundaries():
    """Passing current shelf edges warm-starts the rebalance: the initial
    loads are counted against them, and an already-balanced tiling needs
    no movement."""
    obs = dydd2d.make_observations_2d(800, kind="clustered", seed=2)
    first = dydd2d.dydd_2d(obs, pr=2, pc=3)
    warm = dydd2d.dydd_2d(obs, pr=2, pc=3,
                          y_edges=first.y_edges, x_edges=first.x_edges)
    np.testing.assert_array_equal(warm.loads_initial, first.loads_final)
    assert warm.efficiency >= first.efficiency - 1e-12
    assert warm.total_movement <= first.total_movement


def test_dydd_2d_pr1_is_exactly_dydd_1d():
    """Degenerate dimension: a 1 x pc shelf on 2D points with constant y
    reproduces dydd_1d on the x coordinates exactly."""
    rng = np.random.default_rng(5)
    xs = np.sort(rng.beta(2, 5, 500))
    obs2 = np.stack([xs, np.full_like(xs, 0.5)], axis=1)
    res2 = dydd2d.dydd_2d(obs2, pr=1, pc=6)
    res1 = dydd.dydd_1d(xs, 6)
    np.testing.assert_array_equal(res2.x_edges[0], res1.boundaries)
    np.testing.assert_array_equal(res2.loads_final.reshape(-1),
                                  res1.loads_final)
    assert res2.total_movement == res1.total_movement


def test_counts_2d_none_ranks_match_historic_rule():
    """tie_ranks=None reproduces the searchsorted(side='right') + clip
    counting bit for bit (the pre-tie-fix behaviour, random inputs)."""
    obs = dydd2d.make_observations_2d(700, kind="clustered", seed=9)
    y_edges = np.linspace(0.0, 1.0, 4)
    x_edges = np.tile(np.linspace(0.0, 1.0, 5), (3, 1))
    rows = np.clip(np.searchsorted(y_edges, obs[:, 1], side="right") - 1,
                   0, 2)
    want = np.zeros((3, 4), np.int64)
    for r in range(3):
        xs = obs[rows == r, 0]
        cols = np.clip(np.searchsorted(x_edges[r], xs, side="right") - 1,
                       0, 3)
        want[r] = np.bincount(cols, minlength=4)
    got = dydd2d._counts_2d(obs, y_edges, x_edges)
    np.testing.assert_array_equal(got, want)


def test_dydd_2d_quantized_ties_split_across_boundaries():
    """The carried-over ROADMAP bug: a quantized stream whose y values
    all sit exactly on the strip boundary used to count wholesale into
    the lower strip (historic all-right tie rule), so the recount never
    saw the loads the migration realized and the result stayed [m, 0]
    per column.  With the rank-split recount (the 2D analogue of the 1D
    tie_ranks fix) the schedule's targets are realized exactly."""
    m = 16
    obs = np.stack([np.linspace(0.03, 0.97, m), np.full(m, 0.5)], axis=1)
    res = dydd2d.dydd_2d(obs, pr=2, pc=2)
    assert res.loads_final.sum() == m
    # Perfect split: every cell gets m/4 despite every y being tied.
    np.testing.assert_array_equal(res.loads_final,
                                  np.full((2, 2), m // 4))
    assert res.y_tie_ranks is not None and res.y_tie_ranks[0] == m // 2
    # The counting rule itself honours the returned ranks.
    np.testing.assert_array_equal(
        dydd2d._counts_2d(obs, res.y_edges, res.x_edges,
                          res.y_tie_ranks, res.x_tie_ranks),
        res.loads_final)


def test_dydd_2d_x_ties_within_strip_split():
    """Per-strip x ties: quantized x coordinates tied on a cell edge
    split by rank inside each strip independently."""
    rng = np.random.default_rng(11)
    # Two strips, 12 obs each, every x equal to 0.5 (the pc=2 cell edge).
    ys = np.concatenate([rng.uniform(0.0, 0.45, 12),
                         rng.uniform(0.55, 1.0, 12)])
    obs = np.stack([np.full(24, 0.5), ys], axis=1)
    res = dydd2d.dydd_2d(obs, pr=2, pc=2)
    np.testing.assert_array_equal(res.loads_final, np.full((2, 2), 6))
    assert res.x_tie_ranks is not None
    np.testing.assert_array_equal(res.x_tie_ranks, np.full((2, 1), 6))


def test_dydd_2d_tie_ranks_thread_through_warm_start():
    """DyDD2DResult's tie ranks carry into the next online rebalance the
    same way boundaries do — the warm-started recount sees the realized
    loads, so an already-balanced quantized stream needs no movement."""
    m = 16
    obs = np.stack([np.linspace(0.03, 0.97, m), np.full(m, 0.5)], axis=1)
    first = dydd2d.dydd_2d(obs, pr=2, pc=2)
    warm = dydd2d.dydd_2d(obs, pr=2, pc=2,
                          y_edges=first.y_edges, x_edges=first.x_edges,
                          y_tie_ranks=first.y_tie_ranks,
                          x_tie_ranks=first.x_tie_ranks)
    np.testing.assert_array_equal(warm.loads_initial, first.loads_final)
    assert warm.total_movement == 0


# ---------------------------------------------------------------------------
# gram kernel.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,m,w", [(4, 300, 32), (2, 512, 64), (1, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel_sweep(p, m, w, dtype):
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(p, m, w)), jnp.float32).astype(dtype)
    r = jnp.asarray(rng.uniform(0.5, 2.0, (p, m)),
                    jnp.float32).astype(dtype)
    out = ops.gram(A, r, mode="interpret", block_m=128)
    want = ref.gram_ref(A.astype(jnp.float32), r.astype(jnp.float32))
    tol = 1e-3 if dtype == jnp.float32 else 3e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=tol * float(
                                   jnp.max(jnp.abs(want))) / 100 + tol,
                               rtol=tol)


def test_gram_autotune_picks_and_caches_block():
    """First call per shape sweeps the block_m candidates and caches the
    winner; the tuning report exposes the chosen block + timed sweep."""
    shape = (2, 320, 16)
    b1 = ops.autotune_gram_block(*shape, jnp.float32, interpret=True)
    assert b1 in {min(c, shape[1]) for c in ops.GRAM_BLOCK_CANDIDATES}
    b2 = ops.autotune_gram_block(*shape, jnp.float32, interpret=True)
    assert b2 == b1
    report = ops.gram_tuning_report()
    key = "p2_m320_w16_float32_interpret"
    assert key in report
    assert report[key]["block_m"] == b1
    assert set(report[key]["sweep_s"]) == {min(c, shape[1])
                                           for c in ops.GRAM_BLOCK_CANDIDATES}
    # the ref path has no blocking to tune
    assert ops.gram_block_for(shape, jnp.float64, mode="auto") is None


def test_gram_autotune_rejects_over_vmem_candidates(monkeypatch):
    """Candidates whose tile footprint exceeds the VMEM budget are
    skipped without being timed and recorded in the tuning report; the
    narrowest candidate survives even under an absurdly small budget."""
    shape = (2, 2048, 24)
    # Budget between the smallest and largest candidate footprints
    # (candidates are clipped to min(c, m), so the widest here is 1024).
    budget = (ops.gram_tile_bytes(64, 24)
              + ops.gram_tile_bytes(1024, 24)) // 2
    monkeypatch.setattr(ops, "GRAM_VMEM_BUDGET_BYTES", budget)
    b = ops.autotune_gram_block(*shape, jnp.float32, interpret=True)
    key = "p2_m2048_w24_float32_interpret"
    report = ops.gram_tuning_report()
    assert key in report
    rej = report[key]["rejected_vmem"]
    assert rej, "expected at least one over-budget candidate"
    assert str(b) not in rej
    assert all(int(v) > budget for v in rej.values())
    # rejected candidates were never timed
    assert not (set(map(int, rej)) & set(report[key]["sweep_s"]))
    # under a budget below every candidate, the narrowest one is kept
    monkeypatch.setattr(ops, "GRAM_VMEM_BUDGET_BYTES", 1)
    b2 = ops.autotune_gram_block(2, 512, 24, jnp.float32, interpret=True)
    assert b2 == min(min(c, 512) for c in ops.GRAM_BLOCK_CANDIDATES)


@pytest.mark.parametrize("p,m,w", [(4, 300, 32), (2, 512, 64), (1, 64, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_schwarz_kernel_sweep(p, m, w, dtype):
    """Interpret-mode fused Schwarz step vs the jnp oracles, both halves,
    across shapes that exercise ragged last tiles and both dtypes."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(p, m, w)), dtype)
    x = jnp.asarray(rng.normal(size=(p, w)), dtype)
    wdiv = jnp.asarray(rng.uniform(0.5, 1.0, (p, w)), dtype)
    rv = jnp.asarray(rng.uniform(0.5, 2.0, m), dtype)
    bv = jnp.asarray(rng.normal(size=m), dtype)
    muov = jnp.asarray(rng.uniform(0.0, 1.0, (p, w)), dtype)
    mask = jnp.asarray(rng.uniform(size=(p, w)) > 0.2, dtype)
    tol = 1e-5 if dtype == jnp.float32 else 1e-12

    y_i, u_i = ops.schwarz_fwd(A, x, wdiv, mode="interpret", block_m=128)
    y_r, u_r = ref.schwarz_fwd_ref(A, x, wdiv)
    sc = float(jnp.max(jnp.abs(y_r)))
    np.testing.assert_allclose(np.asarray(y_i), np.asarray(y_r),
                               atol=tol * sc, rtol=tol)
    np.testing.assert_allclose(np.asarray(u_i), np.asarray(u_r),
                               atol=tol * sc, rtol=tol)

    Ax = jnp.sum(y_r, axis=0)
    rhs_i = ops.schwarz_bwd(A, rv, bv, Ax, u_r, x, muov, mask,
                            mode="interpret", block_m=128)
    rhs_r = ref.schwarz_bwd_ref(A, rv, bv, Ax, u_r, x, muov, mask)
    sc = float(jnp.max(jnp.abs(rhs_r)))
    np.testing.assert_allclose(np.asarray(rhs_i), np.asarray(rhs_r),
                               atol=tol * sc, rtol=tol)
    # masked slots come out exactly zero on both paths
    np.testing.assert_array_equal(
        np.asarray(rhs_i)[np.asarray(mask) == 0], 0.0)


def test_schwarz_autotune_picks_and_caches_block():
    """First call per shape sweeps the block_m candidates (fwd + bwd
    timed together — one solver iteration's launches) and caches the
    winner; the tuning report exposes the chosen block + timed sweep."""
    shape = (2, 320, 16)
    b1 = ops.autotune_schwarz_block(*shape, jnp.float32, interpret=True)
    assert b1 in {min(c, shape[1]) for c in ops.SCHWARZ_BLOCK_CANDIDATES}
    b2 = ops.autotune_schwarz_block(*shape, jnp.float32, interpret=True)
    assert b2 == b1
    report = ops.schwarz_tuning_report()
    key = "p2_m320_w16_float32_interpret"
    assert key in report
    assert report[key]["block_m"] == b1
    assert set(report[key]["sweep_s"]) == \
        {min(c, shape[1]) for c in ops.SCHWARZ_BLOCK_CANDIDATES}
    # f64 under mode="auto" resolves to the jnp reference — no block
    assert ops.schwarz_block_for(shape, jnp.float64, mode="auto") is None
    # but the interpret path tunes a block even for f64 (CI parity runs)
    assert ops.schwarz_block_for(shape, jnp.float64,
                                 mode="interpret") is not None


def test_schwarz_autotune_rejects_over_vmem_candidates(monkeypatch):
    """Candidates whose fused-step tile footprint exceeds the VMEM budget
    are skipped without being timed; the narrowest survives even under
    an absurdly small budget."""
    shape = (2, 2048, 24)
    budget = (ops.schwarz_tile_bytes(64, 24)
              + ops.schwarz_tile_bytes(1024, 24)) // 2
    monkeypatch.setattr(ops, "GRAM_VMEM_BUDGET_BYTES", budget)
    b = ops.autotune_schwarz_block(*shape, jnp.float32, interpret=True)
    key = "p2_m2048_w24_float32_interpret"
    report = ops.schwarz_tuning_report()
    assert key in report
    rej = report[key]["rejected_vmem"]
    assert rej, "expected at least one over-budget candidate"
    assert str(b) not in rej
    assert all(int(v) > budget for v in rej.values())
    assert not (set(map(int, rej)) & set(report[key]["sweep_s"]))
    monkeypatch.setattr(ops, "GRAM_VMEM_BUDGET_BYTES", 1)
    b2 = ops.autotune_schwarz_block(2, 640, 24, jnp.float32,
                                    interpret=True)
    assert b2 == min(min(c, 640) for c in ops.SCHWARZ_BLOCK_CANDIDATES)


def test_gram_matches_ddkf_pack_normal_matrix():
    """The kernel computes exactly the normal matrices ddkf.pack builds."""
    rng = np.random.default_rng(1)
    obs = rng.beta(2, 5, 200)
    prob = cls.local_problem(jax.random.PRNGKey(0), 64, obs)
    dec = dd.decompose_1d(64, dd.uniform_boundaries(4))
    packed = ddkf.pack(prob, dec)
    N = ops.gram(packed.A_loc.astype(jnp.float32),
                 jnp.tile(packed.r.astype(jnp.float32), (4, 1)),
                 mode="interpret", block_m=128)
    # pack stores cholesky(N + pad-identity); reconstruct and compare
    for i in range(4):
        L = np.asarray(packed.L_loc[i], np.float64)
        got = L @ L.T
        k = int(np.asarray(packed.mask[i]).sum())
        want = np.asarray(N[i], np.float64)
        want[np.arange(k, packed.w), np.arange(k, packed.w)] += 1.0
        np.testing.assert_allclose(got, want, atol=1e-3)
