"""Multi-tenant fleet serving: slot scheduler, cohort bucketing, and the
bitwise-determinism contract — per-stream journals and analyses from a
FleetServer must be bit-identical to running each engine's sequential
``run`` loop, on every domain kind, including under a forced 8-device
fleet mesh (subprocess) where cohorts are padded with dummy slots."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.assim import AssimilationEngine, EngineConfig, FleetServer, streams
from repro.assim import fleet as fleet_mod
from repro.core import cls, dd, ddkf, dydd
from repro.obs import meters as obs_meters
from repro.runtime.scheduler import SlotScheduler

import jax


@pytest.fixture()
def fresh_meters():
    prev = obs_meters.get_meters()
    m = obs_meters.Meters()
    obs_meters.set_meters(m)
    yield m
    obs_meters.set_meters(prev)


# ---------------------------------------------------------------------------
# SlotScheduler.
# ---------------------------------------------------------------------------

def test_scheduler_fifo_capacity_and_recycling(fresh_meters):
    s = SlotScheduler(capacity=2, meters_prefix="t.")
    for name in "abcd":
        s.submit(name)
    assert s.queue_depth() == 4 and s.idle() is False
    first = s.admit()
    assert first == [(0, "a"), (1, "b")]          # FIFO, capacity-bounded
    assert s.admit() == []                        # table full
    assert s.retire(0) == "a"
    assert s.admit() == [(0, "c")]                # lowest slot recycled
    s.retire(1)
    s.retire(0)
    assert s.admit() == [(0, "d")]                # lowest-first recycle
    s.retire(0)
    assert s.idle()
    assert s.stats() == {"submitted": 4, "retired": 4,
                         "active": 0, "queued": 0}
    snap = fresh_meters.snapshot()
    assert snap["gauges"]["t.queue_depth"] == 0
    assert snap["gauges"]["t.active"] == 0
    names = [e["name"] for e in snap["events"]]
    assert names.count("t.admit") == 4 and names.count("t.retire") == 4


def test_scheduler_unbounded_and_max_new(fresh_meters):
    s = SlotScheduler()                            # capacity=None
    for i in range(5):
        s.submit(i)
    assert [p for _, p in s.admit(max_new=2)] == [0, 1]
    assert [p for _, p in s.admit()] == [2, 3, 4]
    with pytest.raises(KeyError):
        s.retire(99)
    with pytest.raises(ValueError):
        SlotScheduler(capacity=0)


def test_serve_queue_waves_use_shared_scheduler(monkeypatch):
    """The LM driver's serve_queue rides the same SlotScheduler: waves
    of at most ``slots`` requests, FIFO, every request served once."""
    from repro.launch import serve as serve_drv

    waves = []

    def fake_serve_batch(cfg, params, requests, *, max_seq, greedy=True,
                         seed=0, mesh=None):
        waves.append([r.rid for r in requests])
        for r in requests:
            r.out = [r.rid]
        return requests, {"prefill_s": 0.0, "decode_s": 0.5}

    monkeypatch.setattr(serve_drv, "serve_batch", fake_serve_batch)
    reqs = [serve_drv.Request(rid=i, prompt=np.arange(3, dtype=np.int32),
                              max_new=1) for i in range(5)]
    done, stats = serve_drv.serve_queue(None, None, reqs, slots=2,
                                        max_seq=8)
    assert waves == [[0, 1], [2, 3], [4]]
    assert sorted(r.rid for r in done) == list(range(5))
    assert stats["waves"] == 3 and stats["decode_s"] == pytest.approx(1.5)


# ---------------------------------------------------------------------------
# Cohort machinery.
# ---------------------------------------------------------------------------

def test_quantize_capacity():
    assert fleet_mod.quantize_capacity(1) == 1
    assert fleet_mod.quantize_capacity(3) == 4
    assert fleet_mod.quantize_capacity(5, mult=8) == 8
    assert fleet_mod.quantize_capacity(9, mult=8) == 16
    with pytest.raises(ValueError):
        fleet_mod.quantize_capacity(0)


def _pack_problem(n=48, p=4, m=96, seed=0, overlap=0, obs_seed=0):
    rng = np.random.default_rng(obs_seed)
    obs = np.sort(rng.beta(2, 5, size=m))
    prob = cls.local_problem(jax.random.PRNGKey(seed), n, obs)
    res = dydd.dydd_1d(obs, p)
    dec = dd.decompose_1d(n, res.boundaries, overlap=overlap)
    return ddkf.pack(prob, dec)


def test_cohort_key_separates_shapes_and_statics():
    pk1 = _pack_problem(seed=0)
    pk2 = _pack_problem(seed=1)          # same shapes, different data
    pk3 = _pack_problem(n=64, seed=0)    # different n (and w)
    k = lambda pk, **kw: fleet_mod.cohort_key(
        pk, kw.get("iters", 40), kw.get("damping", 1.0),
        kw.get("rec", False))
    assert k(pk1) == k(pk2)
    assert k(pk1) != k(pk3)
    assert k(pk1) != k(pk1, iters=60)
    assert k(pk1) != k(pk1, damping=0.7)
    assert k(pk1) != k(pk1, rec=True)


def test_stack_packed_rejects_mixed_shapes():
    with pytest.raises(ValueError, match="stack"):
        ddkf.stack_packed([])
    with pytest.raises(ValueError):
        ddkf.stack_packed([_pack_problem(n=48), _pack_problem(n=64)])


def test_solve_fleet_bitwise_vs_sequential_vmapped():
    """The fleet map is the same program per member: stacking K problems
    and solving once gives bit-identical results to K separate
    solve_vmapped calls — including with dummy padding copies whose
    results are discarded (CohortSolver's quantization)."""
    packs = [_pack_problem(seed=s) for s in range(3)]
    seq = [np.asarray(ddkf.solve_vmapped(pk, iters=40, damping=0.8))
           for pk in packs]
    res = fleet_mod.CohortSolver().solve(
        fleet_mod.cohort_key(packs[0], 40, 0.8, False), packs)
    assert res.size == 3 and res.capacity == 4       # padded to 2**j
    for a, b in zip(res.xs, seq):
        assert np.array_equal(np.asarray(a), b)


# ---------------------------------------------------------------------------
# FleetServer determinism vs sequential engines.
# ---------------------------------------------------------------------------

def _recorder(store):
    def forecast(x):
        store.append(np.asarray(x).copy())
        return x
    return forecast


def _sequential(specs):
    out = {}
    for sid, cfg, (name, m, cycles, seed) in specs:
        rec = []
        eng = AssimilationEngine(cfg, forecast=_recorder(rec))
        eng.run(streams.make_stream(name, m, cycles, seed=seed))
        out[sid] = (rec, np.asarray(eng.analysis), eng.journal)
    return out


def _fleet(specs, **server_kw):
    server = FleetServer(**server_kw)
    recs = {}
    for sid, cfg, (name, m, cycles, seed) in specs:
        recs[sid] = []
        server.add_stream(sid, cfg,
                          streams.make_stream(name, m, cycles, seed=seed),
                          forecast=_recorder(recs[sid]))
    journals = server.serve()
    return recs, journals, server


def _assert_stream_parity(specs, seq, recs, journals):
    for sid, _, (_, _, cycles, _) in specs:
        rec_s, final_s, j_s = seq[sid]
        j_f = journals[sid]
        assert len(j_f) == len(j_s) == cycles
        # Bitwise per-cycle analyses (the forecast wrapper sees every
        # carried analysis) ...
        assert len(recs[sid]) == len(rec_s)
        for a, b in zip(recs[sid], rec_s):
            assert np.array_equal(a, b), sid
        # ... and bit-identical journalled decisions/numerics (timing
        # fields naturally differ).
        for rf, rs in zip(j_f.records, j_s.records):
            assert rf.loads == rs.loads
            assert rf.loads_before == rs.loads_before
            assert rf.repartitioned == rs.repartitioned
            assert rf.migrated == rs.migrated
            assert rf.imbalance == rs.imbalance
            assert rf.residual_history == rs.residual_history
            assert rf.comm_bytes_per_cycle == rs.comm_bytes_per_cycle


def test_fleet_two_streams_bitwise_1d(fresh_meters):
    specs = [
        ("s0", EngineConfig(n=48, p=4, iters=30),
         ("drifting_swarm", 120, 3, 0)),
        ("s1", EngineConfig(n=48, p=4, iters=30),
         ("bursty_clusters", 120, 3, 1)),
    ]
    seq = _sequential(specs)
    recs, journals, server = _fleet(specs, max_active=2)
    _assert_stream_parity(specs, seq, recs, journals)
    assert server.stats["cycles"] == 6
    snap = fresh_meters.snapshot()
    assert snap["counters"]["fleet.cohort.dispatches"] >= 3
    assert "fleet.queue_depth" in snap["gauges"]


def test_fleet_mixed_domains_bitwise_with_churn(fresh_meters):
    """2D shelf + kdtree + 1D with residual recording, more streams than
    slots: admission/retirement churn and per-stream DyDD repacks leave
    every stream bit-identical to its sequential run."""
    specs = [
        ("shelf", EngineConfig(ndim=2, nx=12, ny=8, pr=2, pc=2, iters=25),
         ("rotating_swarm", 200, 3, 1)),
        ("kdtree", EngineConfig(ndim=2, nx=16, ny=12,
                                domain_kind="kdtree", p=4, iters=25),
         ("satellite_track", 240, 3, 2)),
        ("hist", EngineConfig(n=64, p=4, iters=25, record_residuals=True),
         ("storm_front", 150, 3, 4)),
        ("line", EngineConfig(n=48, p=4, iters=25),
         ("drifting_swarm", 120, 4, 5)),
    ]
    seq = _sequential(specs)
    recs, journals, server = _fleet(specs, max_active=2, pack_workers=2)
    _assert_stream_parity(specs, seq, recs, journals)
    snap = fresh_meters.snapshot()
    repacks = [e for e in snap["events"]
               if e["name"] == "fleet.dydd.repack"]
    assert repacks, "expected at least one DyDD repack in these streams"
    assert snap["counters"]["fleet.rounds"] == server.stats["rounds"]


def test_fleet_add_stream_validation():
    server = FleetServer()
    cfg = EngineConfig(n=32, p=2, iters=10)
    server.add_stream("a", cfg, [])
    with pytest.raises(ValueError, match="duplicate"):
        server.add_stream("a", cfg, [])
    with pytest.raises(ValueError, match="vmapped"):
        server.add_stream("b", EngineConfig(n=32, p=2, solver="shardmap"),
                          [])
    journals = server.serve()          # empty stream retires immediately
    assert len(journals["a"]) == 0
    assert server.stats["cycles"] == 0


# ---------------------------------------------------------------------------
# Readmit: crash -> snapshot -> slot re-acquisition -> bitwise completion.
# ---------------------------------------------------------------------------

def test_fleet_readmit_crashed_stream(fresh_meters, tmp_path):
    """A stream that crashes mid-serve (injected pack faults exhausting
    the retry budget) is retired as failed; readmit() rebuilds it from
    its latest per-stream snapshot, re-queues it through the
    SlotScheduler, and the completed journal is bitwise-identical
    (modulo wall-clock fields) to an uninterrupted run."""
    from repro.runtime import chaos as chaos_mod

    cfg = EngineConfig(n=48, p=4, iters=25)
    name, m, cycles, seed = "drifting_swarm", 120, 6, 0

    eng_ref = AssimilationEngine(cfg)
    eng_ref.run(streams.make_stream(name, m, cycles, seed=seed))
    ref_json = eng_ref.journal.deterministic_json()

    # Crash s0 at cycle 3 (the fault re-fires on every retry) with a
    # snapshot at every cycle boundary; a healthy companion stream
    # keeps the server round loop honest.
    ckpt = str(tmp_path / "s0")
    inj = chaos_mod.ChaosInjector(chaos_mod.ChaosConfig(
        pack_fault_cycles=(3,), fail_every_attempt=True))
    server = FleetServer(max_active=2, max_retries=1, retry_backoff=0.0)
    server.add_stream("s0", cfg,
                      streams.ResumableStream(name, m, cycles, seed=seed),
                      checkpoint_dir=ckpt, snapshot_every=1, chaos=inj)
    server.add_stream("side", cfg,
                      streams.make_stream("bursty_clusters", 120, 4,
                                          seed=1))
    journals = server.serve()
    assert len(journals["s0"]) == 3          # crashed before cycle 3
    assert len(journals["side"]) == 4

    with pytest.raises(ValueError, match="checkpoint_dir"):
        server.readmit("side")               # no snapshots configured
    with pytest.raises(KeyError):
        server.readmit("nope")

    server.readmit("s0")                     # fresh engine, no chaos
    with pytest.raises(ValueError, match="active or queued"):
        server.readmit("s0")                 # already back in the queue
    journals = server.serve()
    assert len(journals["s0"]) == cycles
    assert journals["s0"].deterministic_json() == ref_json

    snap = fresh_meters.snapshot()
    names = [e["name"] for e in snap["events"]]
    assert "fleet.stream_failed" in names
    assert "fleet.stream_readmitted" in names
    assert snap["counters"]["fleet.streams_readmitted"] == 1
    re_ev = [e for e in snap["events"]
             if e["name"] == "fleet.stream_readmitted"][0]
    assert re_ev["sid"] == "s0" and re_ev["resume_cycle"] == 3


# ---------------------------------------------------------------------------
# Forced 8-device fleet mesh (subprocess, like test_ddkf_multidevice).
# ---------------------------------------------------------------------------

SCRIPT_FLEET_8DEV = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.assim import AssimilationEngine, EngineConfig, FleetServer, streams
from repro.core import _compat

def recorder(store):
    def f(x):
        store.append(np.asarray(x).copy())
        return x
    return f

specs = [(f"s{i}", EngineConfig(n=48, p=4, iters=25),
          ("drifting_swarm", 120, 3, i)) for i in range(3)]

seq = {}
for sid, cfg, (name, m, cycles, seed) in specs:
    rec = []
    eng = AssimilationEngine(cfg, forecast=recorder(rec))
    eng.run(streams.make_stream(name, m, cycles, seed=seed))
    seq[sid] = (rec, eng.journal)

mesh = _compat.make_device_mesh((8,), ("fleet",))
server = FleetServer(mesh=mesh, mesh_axis="fleet")
recs = {}
for sid, cfg, (name, m, cycles, seed) in specs:
    recs[sid] = []
    server.add_stream(sid, cfg,
                      streams.make_stream(name, m, cycles, seed=seed),
                      forecast=recorder(recs[sid]))
journals = server.serve()
for sid, cfg, (name, m, cycles, seed) in specs:
    rec_s, j_s = seq[sid]
    assert len(journals[sid]) == len(j_s) == cycles
    assert len(recs[sid]) == len(rec_s)
    for a, b in zip(recs[sid], rec_s):
        assert np.array_equal(a, b), sid
    for rf, rs in zip(journals[sid].records, j_s.records):
        assert rf.loads == rs.loads and rf.migrated == rs.migrated
print("OK", server.stats["cycles"])
"""


@pytest.mark.slow
def test_fleet_8_device_cohort_bitwise():
    """3 live streams on an 8-device fleet mesh: the cohort pads to 8
    with dummy copies, shards members across devices, and still returns
    bit-identical per-stream analyses to sequential single-device
    runs."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT_FLEET_8DEV],
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
