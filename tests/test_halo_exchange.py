"""Neighbour-only halo exchange: the precomputed ppermute edge schedule
(`dd.HaloExchange`), the comm-volume model, and the overlap-aware DyDD
weighting — everything the sharded solve's `comm="neighbour"` path rides
on, validated host-side (the device-path ULP parity lives in
test_ddkf_multidevice.py under forced multi-device XLA)."""
import numpy as np
import pytest

from repro.assim import AssimilationEngine, EngineConfig
from repro.core import dd, ddkf, domain, dydd, dydd2d


def _tiling_dec(pr, pc, nx=16, ny=8, overlap=1, seed=4, balance=True):
    dom = domain.ShelfTiling2D(nx=nx, ny=ny, pr=pr, pc=pc)
    if balance:
        obs = dydd2d.make_observations_2d(400, kind="clustered", seed=seed)
        dom.rebalance(obs)
    return dom.decomposition(overlap=overlap)


# ---------------------------------------------------------------------------
# Edge discovery + graph colouring.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pr,pc", [(1, 8), (2, 4), (4, 2), (1, 2), (2, 2)])
def test_edge_schedule_rounds_are_matchings(pr, pc):
    """Every round's permutation is a directed matching: no device sends
    twice or receives twice in one ppermute round, the rounds cover both
    directed arcs of every edge exactly once, and the König colouring
    achieves exactly max-degree rounds (the optimum — every device must
    send to each of its deg neighbours in distinct rounds)."""
    dec = _tiling_dec(pr, pc, overlap=1)
    he = dec.halo_exchange
    arcs = []
    for perm in he.perms:
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        assert len(set(srcs)) == len(srcs)
        assert len(set(dsts)) == len(dsts)
        arcs.extend((int(s), int(d)) for s, d in perm)
    expect = [a for i, j in he.edges for a in ((i, j), (j, i))]
    assert sorted(arcs) == sorted(expect)
    deg = np.zeros(dec.p, np.int64)
    for i, j in he.edges:
        deg[i] += 1
        deg[j] += 1
    assert he.rounds == (int(deg.max()) if he.edges else 0)


def test_chain_schedule_is_two_rounds():
    """A 1D chain (pr=1 degenerate) schedules into two rounds regardless
    of p (interior max degree 2), with int32 pack/unpack maps shaped
    (p, rounds, h)."""
    dec = dd.decompose_1d(64, dd.uniform_boundaries(8), overlap=2)
    he = dec.halo_exchange
    assert he.edges == tuple((i, i + 1) for i in range(7))
    assert he.rounds == 2
    assert he.pack_idx.shape == (8, 2, he.h)
    assert he.unpack_idx.shape == (8, 2, he.h)
    assert he.pack_idx.dtype == np.int32
    assert he.unpack_idx.dtype == np.int32


def test_triangle_graph_needs_only_two_rounds():
    """Pairwise-overlapping triangle of subdomains: max degree 2, so the
    directed bipartite colouring schedules it in 2 rounds.  (An
    undirected edge colouring cannot — an odd cycle needs 3 colours —
    which is exactly why the schedule colours arcs, not edges.)"""
    col_sets = (np.array([0, 1, 2, 3]), np.array([2, 3, 4, 5]),
                np.array([0, 1, 4, 5]))
    dec = dd.Decomposition(n=6, col_sets=col_sets, overlap=1)
    he = dec.halo_exchange
    assert set(he.edges) == {(0, 1), (0, 2), (1, 2)}
    assert he.rounds == 2


def test_grid_schedule_includes_corner_halo_pairs():
    """A wide 2D overlap makes diagonal cells share halo∩halo columns;
    the intersection-derived edge set catches those pairs (a pure
    grid-edges schedule would silently drop their contributions)."""
    dec = _tiling_dec(2, 4, overlap=1)
    he = dec.halo_exchange
    grid = set(dydd.grid_edges(2, 4, torus=False))
    assert grid <= set(he.edges)            # grid neighbours always there
    # shared columns really are shared, ascending, in both endpoints
    sets = [np.asarray(c) for c in dec.col_sets]
    for (i, j), s, (si, sj) in zip(he.edges, he.shared, he.send_slots):
        assert (np.diff(s) > 0).all()
        np.testing.assert_array_equal(sets[i][si], s)
        np.testing.assert_array_equal(sets[j][sj], s)


def test_no_overlap_means_no_edges():
    dec = dd.decompose_1d(48, dd.uniform_boundaries(4), overlap=0)
    he = dec.halo_exchange
    assert he.edges == () and he.rounds == 0 and he.h == 0
    assert dec.halo_fraction == 0.0
    np.testing.assert_array_equal(dec.halo_sizes, np.zeros(4, np.int64))


def test_empty_core_cells_exchange_nothing():
    """A cell with an empty core owns no columns, so it acquires no
    edges and its slot map is all dump."""
    y = np.linspace(0, 1, 2)
    x = np.array([[0.0, 0.001, 1.0]])     # cell (0,0) owns no column
    col_sets = dydd2d.cell_col_sets(8, 4, y, x, overlap=2)
    dec = dd.Decomposition(n=32, col_sets=tuple(col_sets), overlap=2)
    he = dec.halo_exchange
    assert all(0 not in e for e in he.edges)
    if he.rounds:
        assert (he.pack_idx[0] == he.w).all()
        assert (he.unpack_idx[0] == he.w).all()


# ---------------------------------------------------------------------------
# Index-map round trip: the neighbour exchange reproduces the global
# multiplicity-weighted assembly exactly.
# ---------------------------------------------------------------------------

def _simulate_neighbour_exchange(dec, x_loc):
    """Host-side replay of the device exchange: pack the outgoing arc's
    slots at pack_idx, swap over each round's perm, scatter-add the
    incoming payload at unpack_idx (the dump slot absorbs padding),
    divide by the local multiplicity."""
    he = dec.halo_exchange
    sets = [np.asarray(c) for c in dec.col_sets]
    w = dec.pad_width
    mult = np.maximum(dec.column_multiplicity, 1)
    out = np.zeros_like(x_loc)
    pad = np.concatenate([x_loc, np.zeros((dec.p, 1))], axis=1)
    for i in range(dec.p):
        acc = pad[i].copy()
        for r in range(he.rounds):
            for s, d in he.perms[r]:
                if d == i:
                    np.add.at(acc, he.unpack_idx[i, r],
                              pad[s][he.pack_idx[s, r]])
        mloc = np.ones(w)
        k = sets[i].size
        mloc[:k] = mult[sets[i]]
        out[i] = acc[:w] / mloc
    return out


@pytest.mark.parametrize("make", [
    lambda: dd.decompose_1d(64, dd.uniform_boundaries(8), overlap=3),
    lambda: _tiling_dec(2, 4, overlap=1),
    lambda: _tiling_dec(2, 2, nx=12, ny=10, overlap=2),
    # triangle graph: odd cycle, exercises the alternating-path recolour
    lambda: dd.Decomposition(n=6, col_sets=(
        np.array([0, 1, 2, 3]), np.array([2, 3, 4, 5]),
        np.array([0, 1, 4, 5])), overlap=1),
])
def test_neighbour_exchange_matches_global_average(make):
    dec = make()
    rng = np.random.default_rng(7)
    sets = [np.asarray(c) for c in dec.col_sets]
    w = dec.pad_width
    x_loc = np.zeros((dec.p, w))
    for i, c in enumerate(sets):
        x_loc[i, :c.size] = rng.normal(size=c.size)
    # global reference: scatter-add everyone, divide by multiplicity
    acc = np.zeros(dec.n)
    for i, c in enumerate(sets):
        acc[c] += x_loc[i, :c.size]
    ref = acc / np.maximum(dec.column_multiplicity, 1)
    out = _simulate_neighbour_exchange(dec, x_loc)
    for i, c in enumerate(sets):
        np.testing.assert_allclose(out[i, :c.size], ref[c], atol=1e-14)
        np.testing.assert_array_equal(out[i, c.size:], 0.0)


# ---------------------------------------------------------------------------
# Comm-volume accounting.
# ---------------------------------------------------------------------------

def test_comm_model_neighbour_scales_with_overlap_not_n():
    """The acceptance property: neighbour-path state bytes grow with the
    overlap width s and are flat in n; allreduce-path bytes grow with n
    and are flat in s."""
    def state_bytes(n, s, comm):
        dec = dd.decompose_1d(n, dd.uniform_boundaries(8), overlap=s)
        model = ddkf.comm_model(n, 2 * n, 8, 8, halo=dec.halo_exchange,
                                comm=comm)
        return model["state_bytes_per_device_mean"]

    # flat in n at fixed s, linear in s at fixed n
    assert state_bytes(256, 2, "neighbour") == \
        state_bytes(1024, 2, "neighbour")
    assert state_bytes(256, 4, "neighbour") == \
        2 * state_bytes(256, 2, "neighbour")
    # the allreduce path is the opposite regime
    assert state_bytes(1024, 2, "allreduce") == \
        4 * state_bytes(256, 2, "allreduce")
    assert state_bytes(256, 4, "allreduce") == \
        state_bytes(256, 2, "allreduce")


def test_packed_edge_send_bytes():
    import jax
    from repro.core import cls
    rng = np.random.default_rng(3)
    obs = rng.beta(2, 5, 200)
    prob = cls.local_problem(jax.random.PRNGKey(0), 64, obs)
    dec = dd.decompose_1d(64, dd.uniform_boundaries(4), overlap=2)
    packed = ddkf.pack(prob, dec)
    he = dec.halo_exchange
    per_edge = packed.edge_send_bytes(he)
    itemsize = np.dtype(np.asarray(packed.A_loc).dtype).itemsize
    assert set(per_edge) == {f"{i}-{j}" for i, j in he.edges}
    for (i, j), s in zip(he.edges, he.shared):
        assert per_edge[f"{i}-{j}"] == s.size * itemsize
    stats = packed.comm_stats(halo=he, comm="neighbour")
    assert stats["per_edge_bytes"] == per_edge
    assert stats["permute_rounds"] == he.rounds


def test_solve_shardmap_guards():
    """The neighbour path validates its inputs before touching devices:
    a missing or shape-mismatched halo schedule fails loudly."""
    import jax
    from repro.core import cls, _compat
    obs = np.sort(np.random.default_rng(5).uniform(0, 1, 80))
    prob = cls.local_problem(jax.random.PRNGKey(0), 32, obs)
    dec = dd.decompose_1d(32, dd.uniform_boundaries(1), overlap=0)
    packed = ddkf.pack(prob, dec)
    mesh = _compat.make_device_mesh((1,), ("sub",))
    with pytest.raises(ValueError, match="halo_exchange"):
        ddkf.solve_shardmap(packed, mesh, comm="neighbour", halo=None)
    other = dd.decompose_1d(32, dd.uniform_boundaries(2), overlap=2)
    with pytest.raises(ValueError, match="does not match the packing"):
        ddkf.solve_shardmap(packed, mesh, comm="neighbour",
                            halo=other.halo_exchange)
    with pytest.raises(ValueError, match="comm must be"):
        ddkf.solve_shardmap(packed, mesh, comm="smoke-signals")
    with pytest.raises(ValueError, match="mvec must be"):
        ddkf.solve_shardmap(packed, mesh, mvec="bucket-brigade")


# ---------------------------------------------------------------------------
# Overlap-aware DyDD weighting.
# ---------------------------------------------------------------------------

def test_dydd_1d_none_offsets_bit_for_bit():
    rng = np.random.default_rng(0)
    obs = rng.beta(2, 5, 500)
    a = dydd.dydd_1d(obs, 6)
    b = dydd.dydd_1d(obs, 6, cost_offsets=None)
    np.testing.assert_array_equal(a.boundaries, b.boundaries)
    np.testing.assert_array_equal(a.loads_final, b.loads_final)


def test_dydd_1d_offsets_shift_loads_away_from_costly_subdomains():
    """A subdomain carrying fixed halo cost should end up with fewer
    observations: weighted loads (obs + offsets) balance instead."""
    rng = np.random.default_rng(1)
    obs = np.sort(rng.uniform(0, 1, 600))
    off = np.array([0, 120, 0, 0], np.int64)
    res = dydd.dydd_1d(obs, 4, cost_offsets=off)
    base = dydd.dydd_1d(obs, 4)
    assert res.loads_final.sum() == 600        # conservation
    assert res.loads_final[1] < base.loads_final[1]
    work = res.loads_final + off
    assert np.abs(work - work.mean()).max() <= \
        np.abs(base.loads_final + off
               - (base.loads_final + off).mean()).max()


def test_dydd_1d_offsets_validate_shape():
    with pytest.raises(ValueError, match="cost_offsets"):
        dydd.dydd_1d(np.linspace(0, 0.9, 50), 4,
                     cost_offsets=np.zeros(3))


def test_dydd_2d_none_offsets_bit_for_bit():
    obs = dydd2d.make_observations_2d(800, kind="clustered", seed=2)
    a = dydd2d.dydd_2d(obs, pr=2, pc=3)
    b = dydd2d.dydd_2d(obs, pr=2, pc=3, cost_offsets=None)
    np.testing.assert_array_equal(a.y_edges, b.y_edges)
    np.testing.assert_array_equal(a.x_edges, b.x_edges)
    np.testing.assert_array_equal(a.loads_final, b.loads_final)


def test_dydd_2d_offsets_balance_weighted_loads():
    obs = dydd2d.make_observations_2d(900, kind="uniform", seed=5)
    off = np.zeros((2, 3), np.int64)
    off[0, 0] = 150
    res = dydd2d.dydd_2d(obs, pr=2, pc=3, cost_offsets=off)
    base = dydd2d.dydd_2d(obs, pr=2, pc=3)
    assert res.loads_final.sum() == 900
    assert res.loads_final[0, 0] < base.loads_final[0, 0]


def test_domain_rebalance_forwards_offsets():
    dom = domain.Interval1D(n=64, p=4)
    rng = np.random.default_rng(3)
    obs = np.sort(rng.uniform(0, 1, 400))
    off = np.array([0, 0, 0, 100], np.float64)
    dom.rebalance(obs, cost_offsets=off)
    assert dom.counts(obs)[3] < 100 + 400 // 4
    dom2 = domain.ShelfTiling2D(nx=12, ny=8, pr=2, pc=2)
    obs2 = dydd2d.make_observations_2d(400, kind="uniform", seed=1)
    dom2.rebalance(obs2, cost_offsets=np.array([0, 0, 0, 80]))
    assert dom2.counts(obs2).sum() == 400


# ---------------------------------------------------------------------------
# Engine integration: journal fields + the weighted trigger path.
# ---------------------------------------------------------------------------

def test_engine_journals_comm_accounting():
    cfg = EngineConfig(n=64, p=4, overlap=2, iters=40, halo_weight=1.0,
                       comm="neighbour", double_buffer=False)
    eng = AssimilationEngine(cfg)
    journal = eng.run_scenario("drifting_swarm", m=200, cycles=3, seed=0)
    for rec in journal.records:
        assert rec.comm_bytes_per_cycle > 0
        assert 0.0 < rec.halo_fraction < 1.0
        assert len(rec.loads_weighted) == 4
        # weighted = loads + halo cost, so never below the raw loads
        assert all(wv >= lv for wv, lv
                   in zip(rec.loads_weighted, rec.loads))
    s = journal.summary()
    assert s["comm_bytes_per_cycle_mean"] > 0
    assert s["halo_fraction_mean"] > 0
    d = journal.to_dict()
    assert d["records"][0]["loads_weighted"] == \
        journal.records[0].loads_weighted


def test_engine_neighbour_comm_model_beats_allreduce():
    """On a small-overlap decomposition the modelled neighbour traffic is
    strictly below the allreduce traffic (the point of the path)."""
    kw = dict(n=128, p=4, overlap=1, iters=40, double_buffer=False)
    j_all = AssimilationEngine(EngineConfig(comm="allreduce", **kw)) \
        .run_scenario("drifting_swarm", m=200, cycles=2, seed=0)
    j_nei = AssimilationEngine(EngineConfig(comm="neighbour", **kw)) \
        .run_scenario("drifting_swarm", m=200, cycles=2, seed=0)
    for ra, rn in zip(j_all.records, j_nei.records):
        assert rn.comm_bytes_per_cycle < ra.comm_bytes_per_cycle
        # identical decomposition trajectory: comm mode must not change
        # the rebalance decisions (vmapped solver ignores comm entirely)
        assert ra.loads == rn.loads


def test_engine_rejects_bad_comm_config():
    with pytest.raises(ValueError, match="comm"):
        AssimilationEngine(EngineConfig(comm="carrier-pigeon"))
    with pytest.raises(ValueError, match="halo_weight"):
        AssimilationEngine(EngineConfig(halo_weight=-1.0))
