"""KDTreeDomain: Domain-protocol conformance, partition/halo geometry,
median-split rebalancing with migration accounting, the irregular
face-adjacency processor graph, and the anisotropic-network win over the
shelf tiling (the ROADMAP quadtree/k-d item)."""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

from repro.assim import AssimilationEngine, EngineConfig, streams  # noqa: E402
from repro.core import domain as domain_mod  # noqa: E402
from repro.core import kdtree as kdtree_mod  # noqa: E402


def band_obs(m=500, seed=0, width=0.02):
    """A thin diagonal band — the anisotropic configuration the shelf
    tiling wastes cells on."""
    rng = np.random.default_rng(seed)
    t = rng.uniform(0, 1, m)
    y = np.clip(t + width * rng.normal(size=m), 0, np.nextafter(1.0, 0))
    return np.stack([t, y], axis=1)


# ---------------------------------------------------------------------------
# Domain protocol suite — all three implementations.
# ---------------------------------------------------------------------------

DOMAINS = {
    "interval": lambda: domain_mod.Interval1D(n=96, p=6),
    "shelf": lambda: domain_mod.ShelfTiling2D(nx=16, ny=12, pr=2, pc=3),
    "kdtree": lambda: kdtree_mod.KDTreeDomain(nx=16, ny=12, p=6),
}


def domain_obs(dom, m=300, seed=0):
    rng = np.random.default_rng(seed)
    if dom.ndim == 1:
        return np.sort(rng.uniform(0, 1, m))
    return band_obs(m, seed)


@pytest.mark.parametrize("kind", sorted(DOMAINS))
def test_domain_protocol_suite(kind):
    """The shared Domain contract: protocol conformance, count
    conservation, core partition of the state mesh, rebalance bookkeeping
    and a connected processor graph."""
    dom = DOMAINS[kind]()
    assert isinstance(dom, domain_mod.Domain)
    obs = domain_obs(dom)
    counts = dom.counts(obs)
    assert counts.shape == (dom.p,) and counts.sum() == obs.shape[0]
    # zero-overlap decomposition partitions the mesh exactly
    dec = dom.decomposition(overlap=0)
    assert dec.p == dom.p and dec.n == dom.n
    assert (dec.column_multiplicity == 1).all()
    assert sum(len(np.asarray(c)) for c in dec.col_sets) == dom.n
    # rebalance adopts boundaries: the counts afterwards match a fresh
    # recount and the migration volume is bounded by m
    info = dom.rebalance(obs)
    assert 0 <= info.migrated <= obs.shape[0]
    assert dom.counts(obs).sum() == obs.shape[0]
    # processor graph touches every subdomain
    edges = dom.graph_edges()
    touched = set()
    for i, j in edges:
        assert 0 <= i < j < dom.p
        touched |= {i, j}
    assert touched == set(range(dom.p))
    # mesh axes multiply to p
    names, shape = dom.mesh_axes()
    assert int(np.prod(shape)) == dom.p and len(names) == len(shape)
    # positions for the observation operator stay in [0, 1)
    pos = dom.obs_positions(obs)
    assert pos.shape == (obs.shape[0],)
    assert (pos >= 0).all() and (pos < 1).all()
    assert dom.describe()["n"] == dom.n


# ---------------------------------------------------------------------------
# k-d specifics: geometry, halos, migration accounting.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [1, 2, 3, 5, 8])
def test_kdtree_partitions_mesh_for_any_p(p):
    dom = kdtree_mod.KDTreeDomain(nx=12, ny=10, p=p)
    dec = dom.decomposition(overlap=0)
    assert (dec.column_multiplicity == 1).all()
    # leaves tile [0,1]^2: areas sum to 1, every rect is proper
    r = dom.rects
    assert np.isclose(((r[:, 1] - r[:, 0]) * (r[:, 3] - r[:, 2])).sum(),
                      1.0)
    assert (r[:, 1] > r[:, 0]).all() and (r[:, 3] > r[:, 2]).all()


def test_kdtree_rebalance_adapts_to_diagonal_band():
    obs = band_obs(600, seed=1)
    dom = kdtree_mod.KDTreeDomain(nx=16, ny=12, p=8)
    before = dom.counts(obs)
    info = dom.rebalance(obs)
    after = dom.counts(obs)
    assert after.sum() == 600
    assert after.max() / after.mean() < before.max() / before.mean()
    # Cuts snap to mesh lines (col_sets align with raster columns), so
    # the split is quantized to whole-column mass: for this stream the
    # exhaustive optimum over all snapped k-d splits is max/mean =
    # 85/75 ≈ 1.133 (no snapped tree does better than 85 in its biggest
    # leaf) — the builder must land within one point of that optimum.
    assert after.max() / after.mean() < 1.15
    assert info.rounds == 3                   # depth of an 8-leaf tree
    # warm restart on the same stream is a no-op: leaf identity is
    # stable, so nothing migrates
    assert dom.rebalance(obs).migrated == 0


def test_kdtree_cuts_snap_to_mesh_lines_on_quantized_coords():
    """Regression (mesh-line snapping): every interior rectangle edge
    lies exactly on a mesh line, col_sets tile whole raster columns, and
    a stream whose coordinates are themselves grid-quantized (stations
    at cell centres and on cell boundaries — the tie-on-the-cut case)
    still counts and builds consistently."""
    nx, ny, p = 16, 12, 6
    rng = np.random.default_rng(7)
    # Quantized coordinates: half the stations on cell centres, half
    # exactly ON mesh lines (the coordinates a snapped cut can hit).
    m = 360
    cx = (rng.integers(0, nx, m) + 0.5) / nx
    cy = (rng.integers(0, ny, m) + 0.5) / ny
    lx = rng.integers(1, nx, m) / nx
    ly = rng.integers(1, ny, m) / ny
    on_line = rng.random(m) < 0.5
    obs = np.stack([np.where(on_line, lx, cx),
                    np.where(on_line, ly, cy)], axis=1)
    dom = kdtree_mod.KDTreeDomain(nx=nx, ny=ny, p=p)
    dom.rebalance(obs)
    # Interior edges on mesh lines: rect * nmesh is integral.
    r = dom.rects
    for vals, nmesh in ((r[:, :2], nx), (r[:, 2:], ny)):
        scaled = vals * nmesh
        assert np.allclose(scaled, np.rint(scaled), atol=1e-9)
    # col_sets tile the raster exactly: disjoint cores covering all n
    # columns (mesh-aligned rectangles leave no partial cells behind).
    dec = dom.decomposition(overlap=0)
    allcols = np.concatenate([np.asarray(c) for c in dec.col_sets])
    assert allcols.size == dom.n
    assert np.array_equal(np.sort(allcols), np.arange(dom.n))
    # Ties on a cut line stay consistent between counting and building:
    # counts sum to m and a warm restart is a no-op.
    counts = dom.counts(obs)
    assert counts.sum() == m
    assert dom.rebalance(obs).migrated == 0


def test_kdtree_migration_counted_against_previous_leaves():
    """Migration volume counts owner changes against the *previous* leaf
    assignment, not against a fresh uniform tree."""
    dom = kdtree_mod.KDTreeDomain(nx=16, ny=12, p=4)
    rng = np.random.default_rng(3)
    a = rng.uniform(0, 1, (400, 2))
    dom.rebalance(a)
    b = a.copy()
    b[:50] = rng.uniform(0, 1, (50, 2))    # jitter an eighth of the obs
    owners_before = dom._owners(b)         # against the *previous* leaves
    info = dom.rebalance(b)
    moved = int((dom._owners(b) != owners_before).sum())
    assert info.migrated == moved
    assert info.migrated <= 400


def test_kdtree_overlap_halo_rectangular_and_clipped():
    dom = kdtree_mod.KDTreeDomain(nx=16, ny=12, p=4)
    dom.rebalance(band_obs(400, seed=2))
    core = dom.decomposition(overlap=0)
    dec = dom.decomposition(overlap=2)
    assert dec.has_overlap
    for i in range(dom.p):
        c0 = set(np.asarray(core.col_sets[i]).tolist())
        c2 = set(np.asarray(dec.col_sets[i]).tolist())
        assert c0 <= c2                     # halo only ever adds columns
        # the halo stays inside the mesh
        assert all(0 <= c < dom.n for c in c2)
        # expanded window is still a raster rectangle: row spans are equal
        cols = np.asarray(dec.col_sets[i])
        xs = cols % dom.nx
        ys = cols // dom.nx
        assert (np.unique(xs).size * np.unique(ys).size) == cols.size
    # domain-boundary faces absorbed nothing: a leaf touching x=0 keeps
    # its left edge at column 0 area
    with pytest.raises(ValueError, match="overlap"):
        dom.decomposition(overlap=-1)


def test_kdtree_face_adjacency_graph():
    dom = kdtree_mod.KDTreeDomain(nx=16, ny=12, p=8)
    dom.rebalance(band_obs(500, seed=4))
    edges = dom.graph_edges()
    rects = dom.rects
    for i, j in edges:
        xi, xj = rects[i], rects[j]
        share_x = xi[1] == xj[0] or xj[1] == xi[0]
        share_y = xi[3] == xj[2] or xj[3] == xi[2]
        assert share_x or share_y
    # the first cut splits the domain in two: the two halves' leaf sets
    # are internally connected and joined across the cut
    assert len(edges) >= dom.p - 1


def test_kdtree_obs_positions_clamps_boundary_x():
    """x == 1.0 must stay in its own raster row (the ShelfTiling2D
    obs_positions bug, fixed for both 2D domains)."""
    kd = kdtree_mod.KDTreeDomain(nx=4, ny=4, p=4)
    sh = domain_mod.ShelfTiling2D(nx=4, ny=4, pr=2, pc=2)
    obs = np.array([[1.0, 0.0], [1.0, 0.6]])
    for dom in (kd, sh):
        pos = dom.obs_positions(obs)
        assert pos[0] < 0.25            # row 0 ends at 1/ny = 0.25
        assert 0.5 <= pos[1] < 0.75     # row 2 of 4
    np.testing.assert_allclose(kd.obs_positions(obs),
                               sh.obs_positions(obs))


def test_kdtree_cost_offsets_shift_leaf_budgets():
    """Overlap-aware rebalance: a leaf carrying fixed halo cost is budgeted
    fewer observations."""
    obs = band_obs(600, seed=5)
    base = kdtree_mod.KDTreeDomain(nx=16, ny=12, p=4)
    base.rebalance(obs)
    costly = kdtree_mod.KDTreeDomain(nx=16, ny=12, p=4)
    off = np.array([120, 0, 0, 0], np.float64)
    costly.rebalance(obs, cost_offsets=off)
    assert costly.counts(obs).sum() == 600
    assert costly.counts(obs)[0] < base.counts(obs)[0]
    with pytest.raises(ValueError, match="cost_offsets"):
        base.rebalance(obs, cost_offsets=np.zeros(3))


# ---------------------------------------------------------------------------
# Engine integration: solve parity and the anisotropic win over the shelf.
# ---------------------------------------------------------------------------

def test_kdtree_engine_matches_one_shot_solve():
    cfg = EngineConfig(ndim=2, domain_kind="kdtree", p=4, nx=12, ny=8,
                       iters=600, damping=0.7, track_reference=True)
    eng = AssimilationEngine(cfg)
    journal = eng.run_scenario("satellite_track", m=160, cycles=3, seed=0)
    for r in journal.records:
        assert r.error_vs_direct < 1e-8, (r.cycle, r.error_vs_direct)
        assert sum(r.loads) == 160
    assert eng.analysis is not None and eng.analysis.shape == (96,)


def test_kdtree_engine_overlap_same_fixed_point():
    """Schwarz halos on the irregular leaf graph reach the same fixed
    point as the non-overlapping solve."""
    kw = dict(ndim=2, domain_kind="kdtree", p=4, nx=12, ny=8, iters=600,
              damping=0.7, track_reference=True)
    eng = AssimilationEngine(EngineConfig(overlap=2, **kw))
    dec = eng.domain.decomposition(overlap=2)
    assert dec.boundaries is None and dec.has_overlap
    journal = eng.run_scenario("river_gauges", m=160, cycles=2, seed=0)
    for r in journal.records:
        assert r.error_vs_direct < 1e-8, (r.cycle, r.error_vs_direct)
    eng0 = AssimilationEngine(EngineConfig(overlap=0, **kw))
    eng0.run_scenario("river_gauges", m=160, cycles=2, seed=0)
    assert float(np.linalg.norm(np.asarray(eng.analysis)
                                - np.asarray(eng0.analysis))) < 1e-8


@pytest.mark.parametrize("name", ["satellite_track", "river_gauges"])
def test_adaptive_domains_on_anisotropic_networks(name):
    """At equal p on the quantized station-network scenarios: the
    tie-aware shelf (rank-split 2D counting) realizes the diffusion
    schedule's targets near-exactly — something the k-d tree's purely
    geometric median cuts cannot do on tied coordinates — and both
    adaptive domains end far better balanced than a frozen shelf.

    (Before tie-aware 2D counting the kdtree ended strictly below the
    shelf here; the rank split inverted that — the shelf's final
    imbalance is now the m/p rounding floor.)"""
    kw = dict(iters=30, damping=0.7, track_reference=False)
    shelf = AssimilationEngine(EngineConfig(
        ndim=2, nx=16, ny=12, pr=2, pc=4, **kw))
    static = AssimilationEngine(EngineConfig(
        ndim=2, nx=16, ny=12, pr=2, pc=4, rebalance=False, **kw))
    kd = AssimilationEngine(EngineConfig(
        ndim=2, domain_kind="kdtree", p=8, nx=16, ny=12, **kw))
    j_sh = shelf.run_scenario(name, m=300, cycles=4, seed=0)
    j_st = static.run_scenario(name, m=300, cycles=4, seed=0)
    j_kd = kd.run_scenario(name, m=300, cycles=4, seed=0)
    assert j_sh.imbalance_trajectory[-1] <= 1.05, j_sh.imbalance_trajectory
    assert j_sh.imbalance_trajectory[-1] <= j_kd.imbalance_trajectory[-1], \
        (j_sh.imbalance_trajectory, j_kd.imbalance_trajectory)
    assert j_kd.imbalance_trajectory[-1] < j_st.imbalance_trajectory[-1], \
        (j_kd.imbalance_trajectory, j_st.imbalance_trajectory)


def test_kdtree_registered_scenarios_present():
    names = streams.available(ndim=2)
    assert "satellite_track" in names and "river_gauges" in names
