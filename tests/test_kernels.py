"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes/dtypes — deliverable (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,s,d", [(1, 128, 32), (2, 256, 64),
                                    (3, 192, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
def test_flash_attention_sweep(bh, s, d, dtype, causal, window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(k1, (bh, s, d), dtype)
    k = _rand(k2, (bh, s, d), dtype)
    v = _rand(k3, (bh, s, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              mode="interpret", block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_nonuniform_blocks():
    q = _rand(jax.random.PRNGKey(1), (2, 160, 64), jnp.float32)
    out = ops.flash_attention(q, q, q, causal=True, mode="interpret",
                              block_q=32, block_k=64)
    want = ref.attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,w", [(1, 128, 64), (2, 256, 128),
                                   (3, 512, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(b, s, w, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = jax.random.uniform(k1, (b, s, w), jnp.float32, 0.7,
                           0.999).astype(dtype)
    x = (0.1 * jax.random.normal(k2, (b, s, w), jnp.float32)).astype(dtype)
    out = ops.rglru_scan(a, x, mode="interpret", block_s=64, block_w=32)
    want = ref.rglru_scan_ref(a.astype(jnp.float32),
                              x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=TOL[dtype],
                               rtol=TOL[dtype])


def test_rglru_scan_sequential_semantics():
    """Kernel output equals the plain sequential recurrence."""
    a = jnp.full((1, 5, 4), 0.5)
    x = jnp.ones((1, 5, 4))
    out = np.asarray(ops.rglru_scan(a, x, mode="interpret", block_s=5,
                                    block_w=4))
    h, want = 0.0, []
    for _ in range(5):
        h = 0.5 * h + 1.0
        want.append(h)
    np.testing.assert_allclose(out[0, :, 0], want, rtol=1e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 128, 32, 16, 32), (1, 256, 64, 32, 64), (4, 64, 16, 8, 16)])
def test_ssd_scan_sweep(bh, s, p, n, chunk):
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(keys[0], (bh, s, p), jnp.float32)
    dt = jax.random.uniform(keys[1], (bh, s), jnp.float32, 0.001, 0.1)
    A = -jax.random.uniform(keys[2], (bh,), jnp.float32, 0.5, 2.0)
    B = jax.random.normal(keys[3], (bh, s, n), jnp.float32)
    C = jax.random.normal(keys[4], (bh, s, n), jnp.float32)
    out = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, mode="interpret")
    want = ref.ssd_heads_ref(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-5, rtol=5e-4)


def test_ssd_chunk_invariance():
    """The chunked algorithm is exact: results don't depend on chunk size."""
    keys = jax.random.split(jax.random.PRNGKey(4), 5)
    bh, s, p, n = 2, 128, 16, 8
    x = jax.random.normal(keys[0], (bh, s, p), jnp.float32)
    dt = jax.random.uniform(keys[1], (bh, s), jnp.float32, 0.001, 0.1)
    A = -jax.random.uniform(keys[2], (bh,), jnp.float32, 0.5, 2.0)
    B = jax.random.normal(keys[3], (bh, s, n), jnp.float32)
    C = jax.random.normal(keys[4], (bh, s, n), jnp.float32)
    o32 = ops.ssd_scan(x, dt, A, B, C, chunk=32, mode="interpret")
    o64 = ops.ssd_scan(x, dt, A, B, C, chunk=64, mode="interpret")
    np.testing.assert_allclose(np.asarray(o32), np.asarray(o64), atol=5e-5)


def test_model_ssd_ref_matches_heads_ref():
    """The model-layout SSD (grouped B/C) agrees with the exact sequential
    recurrence after head folding."""
    from repro.models.ssd import ssd_ref as model_ref
    keys = jax.random.split(jax.random.PRNGKey(5), 5)
    b, s, nh, hd, g, n = 2, 64, 4, 8, 1, 16
    x = jax.random.normal(keys[0], (b, s, nh, hd), jnp.float32)
    dt = jax.random.uniform(keys[1], (b, s, nh), jnp.float32, 0.001, 0.1)
    A = -jax.random.uniform(keys[2], (nh,), jnp.float32, 0.5, 2.0)
    B = jax.random.normal(keys[3], (b, s, g, n), jnp.float32)
    C = jax.random.normal(keys[4], (b, s, g, n), jnp.float32)
    y_model = model_ref(x, dt, A, B, C, chunk=16)
    # fold to (BH, S, ...) and run the sequential oracle
    xf = x.transpose(0, 2, 1, 3).reshape(b * nh, s, hd)
    dtf = dt.transpose(0, 2, 1).reshape(b * nh, s)
    Af = jnp.tile(A, b)
    Bf = jnp.repeat(B[:, :, 0, :][:, None], nh, 1).reshape(b * nh, s, n)
    Cf = jnp.repeat(C[:, :, 0, :][:, None], nh, 1).reshape(b * nh, s, n)
    y_seq = ref.ssd_heads_ref(xf, dtf, Af, Bf, Cf, 16)
    y_model_f = y_model.transpose(0, 2, 1, 3).reshape(b * nh, s, hd)
    np.testing.assert_allclose(np.asarray(y_model_f), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-3)
