"""Launch-layer units: HLO collective parser, roofline math, serve driver,
train driver (tiny end-to-end), CG Laplacian scheduler."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dydd
from repro.launch import hlo_analysis


# ---------------------------------------------------------------------------
# HLO collective parsing.
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %all-gather.1 = f32[16,4096,6144]{1,0,2} all-gather(%x), channel_id=22, replica_groups=[16,16]<=[256], dimensions={0}
  %all-reduce.9 = bf16[128,256]{1,0} all-reduce(%y), replica_groups=[16,16]<=[256], to_apply=%add
  %ar.tuple = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce(%a, %b), replica_groups=[2,128]<=[256]
  %collective-permute.2 = bf16[4,128]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %rs = f32[2,8]{1,0} reduce-scatter(%w), replica_groups=[4,64]<=[256], dimensions={0}
  %not-a-collective = f32[4,4]{1,0} add(%p, %q)
"""


def test_shape_bytes():
    assert hlo_analysis._shape_bytes("f32[16,4096,6144]{1,0,2}") == \
        16 * 4096 * 6144 * 4
    assert hlo_analysis._shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert hlo_analysis._shape_bytes("(f32[8,8]{1,0}, f32[8,8]{1,0})") == \
        2 * 64 * 4


def test_collective_bytes_parser():
    stats = hlo_analysis.collective_bytes(HLO_SAMPLE)
    assert stats.counts == {"all-gather": 1, "all-reduce": 2,
                            "collective-permute": 1, "reduce-scatter": 1}
    # all-gather: b*(g-1)/g with g=16
    ag = 16 * 4096 * 6144 * 4 * 15 / 16
    assert abs(stats.bytes_by_kind["all-gather"] - ag) < 1.0
    # permute = plain result bytes
    assert stats.bytes_by_kind["collective-permute"] == 4 * 128 * 2
    # reduce-scatter = b*(g-1), g=64
    assert stats.bytes_by_kind["reduce-scatter"] == 2 * 8 * 4 * 63
    assert stats.per_device_bytes > 0


def test_group_size_parsing():
    assert hlo_analysis._group_size("replica_groups=[16,16]<=[256]") == 16
    assert hlo_analysis._group_size("replica_groups={{0,1,2,3}}") == 4


def test_roofline_terms_math():
    r = hlo_analysis.Roofline(
        flops=1e15, hbm_bytes=1e13, coll_bytes_per_device=1e9, chips=256,
        compute_s=1e15 / (256 * hlo_analysis.PEAK_FLOPS),
        memory_s=1e13 / (256 * hlo_analysis.HBM_BW),
        collective_s=1e9 / hlo_analysis.LINK_BW,
        model_flops=5e14, counts={})
    assert r.dominant == "memory"     # 47.7ms > 20ms coll > 19.8ms compute
    assert 0 < r.roofline_frac < 1
    assert r.useful_flops_frac == pytest.approx(0.5)


def test_model_flops_counts():
    from repro import configs
    cfg = configs.get_config("mixtral-8x22b")
    total = cfg.param_count(active_only=False)
    active = cfg.param_count(active_only=True)
    assert total > 2.5 * active          # 8 experts, top-2
    mf = hlo_analysis.model_flops_train(cfg, 4096, 256)
    assert mf == pytest.approx(6.0 * active * 4096 * 256)


# ---------------------------------------------------------------------------
# Matrix-free CG Laplacian solve (large-p scheduling).
# ---------------------------------------------------------------------------

def test_cg_matches_lstsq_on_small_graph():
    edges = dydd.grid_edges(4, 4, torus=True)
    rng = np.random.default_rng(0)
    loads = rng.integers(0, 100, 16).astype(np.float64)
    b = loads - loads.mean()
    L = dydd.laplacian(16, edges)
    lam_dense, *_ = np.linalg.lstsq(L, b, rcond=None)
    lam_cg = dydd._solve_laplacian_cg(
        np.asarray(edges), dydd.degrees(16, edges).astype(np.float64), b)
    # both are min-norm (mean-zero) solutions
    np.testing.assert_allclose(lam_cg - lam_cg.mean(),
                               lam_dense - lam_dense.mean(), atol=1e-6)


def test_large_torus_schedule_fast_and_balanced():
    import time
    edges = dydd.grid_edges(32, 32, torus=True)
    rng = np.random.default_rng(1)
    loads = rng.integers(0, 1000, 1024)
    t0 = time.perf_counter()
    final, _ = dydd.balance(loads, edges, max_rounds=8)
    assert time.perf_counter() - t0 < 5.0
    assert dydd.balance_ratio(final) > 0.95
    assert final.sum() == loads.sum()


# ---------------------------------------------------------------------------
# Serve driver.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_batch_driver():
    from repro import configs
    from repro.launch.serve import Request, serve_batch
    from repro.models import transformer

    cfg = configs.get_smoke_config("yi_6b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 8 + i,
                                        dtype=np.int64).astype(np.int32),
                    max_new=4 + i) for i in range(3)]
    reqs, stats = serve_batch(cfg, params, reqs, max_seq=32)
    assert [len(r.out) for r in reqs] == [4, 5, 6]
    assert stats["decode_s"] > 0


@pytest.mark.slow
def test_train_driver_resume(tmp_path):
    from repro import configs
    from repro.launch.train import train

    cfg = configs.get_smoke_config("glm4_9b")
    _, _, losses1 = train(cfg, steps=4, seq=32, global_batch=4, dp=2,
                          ckpt_dir=str(tmp_path), ckpt_every=2,
                          log_every=100)
    # resume continues from step 4 -> no further steps requested
    _, _, losses2 = train(cfg, steps=6, seq=32, global_batch=4, dp=2,
                          ckpt_dir=str(tmp_path), ckpt_every=2,
                          log_every=100)
    assert len(losses2) == 2     # resumed at 4, ran 4..5
