"""Per-arch smoke tests (reduced configs, forward/train/decode on CPU) —
deliverable (f): one smoke test per assigned architecture."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.optim import adamw
from repro.runtime import steps as steps_mod


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = 0.02 * jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.frontend == "vision_stub":
        batch["patches"] = 0.02 * jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Instantiate the reduced same-family config, run one forward and one
    train step on CPU, assert output shapes + no NaNs."""
    cfg = configs.get_smoke_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h = transformer.forward(cfg, params, batch)
    S_expect = batch["tokens"].shape[1] + (
        cfg.num_patches if cfg.frontend == "vision_stub" else 0)
    assert h.shape == (2, S_expect, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(h)))

    step = steps_mod.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3),
                                     donate=False)
    opt = adamw.adamw_init(params)
    loss, new_params, new_opt = step(params, opt, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert int(new_opt["step"]) == 1
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(new_params)[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_matches_forward(arch):
    """prefill + one serve_step == full forward on the extended sequence
    (exact KV-cache/state handoff) for every arch family."""
    cfg = configs.get_smoke_config(arch)
    if cfg.num_experts:
        # deterministic routing for the equality check (DyDD re-routing is
        # a training-time balancing choice; see test_moe.py)
        cfg = dataclasses.replace(cfg, moe_dydd_balance=False,
                                  capacity_factor=4.0)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    P = cfg.num_patches if cfg.frontend == "vision_stub" else 0
    logits_last, cache = transformer.prefill(cfg, params, batch,
                                             max_seq=P + S + 8)
    nxt = jnp.argmax(logits_last, -1)[:, None].astype(jnp.int32)
    logits2, _ = transformer.serve_step(cfg, params, cache, nxt,
                                        jnp.asarray(P + S, jnp.int32))
    ext = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    h = transformer.forward(cfg, params, ext)
    ref = transformer.logits_fn(cfg, params, h[:, -1:, :])[:, 0]
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    err = float(jnp.max(jnp.abs(logits2[:, 0] - ref))) / scale
    assert err < 1e-3, err


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_param_specs_structure_matches_params(arch):
    cfg = configs.get_smoke_config(arch)
    params = transformer.param_shapes(cfg)
    specs = transformer.param_specs(cfg)
    t1 = jax.tree_util.tree_structure(params)
    t2 = jax.tree_util.tree_structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert t1 == t2


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_dims(arch):
    """The full configs carry the exact assigned dimensions."""
    cfg = configs.get_config(arch)
    expected = {
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "gemma_7b": (28, 3072, 16, 16, 24576, 256000),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
        "glm4_9b": (40, 4096, 32, 2, 13696, 151552),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "phi3_vision_4_2b": (32, 3072, 32, 32, 8192, 32064),
        "mamba2_1_3b": (48, 2048, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_configs():
    mix = configs.get_config("mixtral-8x22b")
    assert (mix.num_experts, mix.experts_per_token) == (8, 2)
    ol = configs.get_config("olmoe-1b-7b")
    assert (ol.num_experts, ol.experts_per_token) == (64, 8)


def test_mamba2_ssm_config():
    cfg = configs.get_config("mamba2-1.3b")
    assert cfg.ssm_state == 128 and cfg.attention_free and cfg.sub_quadratic


def test_chunked_attention_exact():
    """Blocked attention (q-chunks + k-band) is numerically identical to
    the full computation, for global and local layers."""
    from repro.models import attention
    cfg = configs.get_smoke_config("gemma3_1b").scaled(attn_q_chunk=8)
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(cfg, key)
    lp = jax.tree.map(lambda x: x[0], params["blocks"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(32), (2, 32))
    for window in (0, cfg.window):
        full = attention.attention(
            cfg.scaled(attn_q_chunk=0), lp["attn"], x, positions,
            window=window)
        chunked = attention.attention(cfg, lp["attn"], x, positions,
                                      window=window)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   atol=2e-5)


def test_long_context_skip_list():
    from repro.configs import shapes
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        ok, reason = shapes.cell_supported(cfg, "long_500k")
        if cfg.name in shapes.LONG_CONTEXT_OK:
            assert ok
        else:
            assert not ok and reason


def test_param_count_sane():
    # within 25% of the advertised sizes (embeddings included)
    approx = {
        "gemma_7b": 8.5e9, "yi_6b": 6e9, "glm4_9b": 9e9,
        "mamba2_1_3b": 1.3e9, "olmoe_1b_7b": 7e9, "gemma3_1b": 1.0e9,
    }
    for arch, want in approx.items():
        n = configs.get_config(arch).param_count()
        assert 0.6 * want < n < 1.6 * want, (arch, n, want)
