"""MoE + DyDD expert balancing (the paper's technique at the expert layer)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import dydd
from repro.models import moe, transformer


def _moe_cfg(**over):
    cfg = configs.get_smoke_config("olmoe_1b_7b")
    return dataclasses.replace(cfg, **over) if over else cfg


def _params(cfg, seed=0):
    from repro.models import nn
    b = nn.Builder("init", key=jax.random.PRNGKey(seed), dtype=jnp.float32)
    return moe.make_moe_params(b, cfg)


def test_moe_output_shape_and_finite():
    cfg = _moe_cfg()
    p = _params(cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                                jnp.float32)
    y = moe.apply_moe(cfg, p, x)
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y)))


def test_moe_differentiable():
    cfg = _moe_cfg()
    p = _params(cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model),
                                jnp.float32)
    g = jax.grad(lambda pp: jnp.sum(moe.apply_moe(cfg, pp, x) ** 2))(p)
    norms = [float(jnp.linalg.norm(v)) for v in jax.tree.leaves(g)]
    assert all(np.isfinite(norms))
    assert any(n > 0 for n in norms)


def test_dydd_target_counts_balance_ring():
    """The on-device scheduler levels a skewed expert load on the ring."""
    e, cap = 8, 100
    pinvL, inc, _ = moe._ring_operators(e)
    counts = jnp.asarray([80, 40, 10, 2, 2, 2, 2, 2], jnp.int32)
    target = moe.dydd_target_counts(counts, pinvL, inc, cap)
    before = dydd.balance_ratio(np.asarray(counts))
    after = dydd.balance_ratio(np.asarray(target))
    assert after > before
    # conservation up to rounding
    assert abs(int(target.sum()) - int(counts.sum())) <= e


def test_dydd_balancing_reduces_drops():
    """With a deliberately skewed router, DyDD re-chunking routes tokens
    that plain capacity-clamping would drop."""
    cfg = _moe_cfg(capacity_factor=1.0)
    p = _params(cfg)
    # bias the router hard toward expert 0
    router = np.array(p["router"], copy=True)
    router[:, 0] += 2.0
    p = dict(p, router=jnp.asarray(router))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(3),
                                (2, 64, cfg.d_model), jnp.float32)

    def total_gate(balance_on):
        cfg2 = dataclasses.replace(cfg, moe_dydd_balance=balance_on)
        # measure routed (non-dropped) probability mass via the aux outputs
        e, k = cfg2.num_experts, cfg2.experts_per_token
        S = x.shape[1]
        capacity = int(np.ceil(S * k / e * cfg2.capacity_factor))
        capacity = max(8, min(capacity, S))
        y = moe.apply_moe(cfg2, p, x)
        return float(jnp.sum(jnp.abs(y)))

    # balanced routing produces strictly more expert output mass (fewer
    # dropped tokens -> more contributions combined back)
    assert total_gate(True) >= total_gate(False) * 0.99


def test_moe_matches_dense_when_single_expert():
    """1 expert, top-1, no balancing == plain (gated) MLP."""
    cfg = _moe_cfg(num_experts=1, experts_per_token=1,
                   moe_dydd_balance=False, capacity_factor=1.0)
    p = _params(cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model),
                                jnp.float32)
    y = moe.apply_moe(cfg, p, x)
    # manual dense expert: gate prob is softmax over 1 expert == 1
    act = jax.nn.silu
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"][0])
    gt = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"][0]))
    want = jnp.einsum("bsf,fd->bsd", gt * up, p["w_down"][0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


def test_load_balance_stats_shapes():
    cfg = _moe_cfg()
    p = _params(cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model),
                                jnp.float32)
    counts, target = moe.load_balance_stats(cfg, p, x)
    assert counts.shape == (cfg.num_experts,)
    assert target.shape == (cfg.num_experts,)
