"""Observability layer: span tracer, meters registry, engine telemetry.

Covers the telemetry PR's acceptance surface: span nesting and thread
attribution, Chrome trace_events schema, zero-overhead disabled tracing,
residual-history monotonicity on a converging solve, comm-matrix totals
against the journalled per-cycle bytes, journal round-trips with the new
fields, and the straggler monitor wired through the engine cycle loop.
"""
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro.assim import AssimilationEngine, EngineConfig
from repro.assim.metrics import CycleMetrics, Journal
from repro.core import cls, dd, ddkf
from repro.obs import meters as obs_meters
from repro.obs import trace as obs_trace
from repro.runtime.straggler import StragglerConfig


@pytest.fixture()
def fresh_meters():
    prev = obs_meters.get_meters()
    m = obs_meters.Meters()
    obs_meters.set_meters(m)
    yield m
    obs_meters.set_meters(prev)


# ---------------------------------------------------------------------------
# Tracer primitives.
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_parent():
    tr = obs_trace.Tracer()
    with obs_trace.tracing(tr):
        with obs_trace.span("outer"):
            with obs_trace.span("inner"):
                time.sleep(0.002)
    outer, = tr.spans("outer")
    inner, = tr.spans("inner")
    assert outer["args"]["depth"] == 0 and "parent" not in outer["args"]
    assert inner["args"]["depth"] == 1
    assert inner["args"]["parent"] == "outer"
    # The child closes first and lies inside the parent's window.
    assert inner["t0"] >= outer["t0"]
    assert inner["t0"] + inner["dur"] <= outer["t0"] + outer["dur"] + 1e-9
    assert outer["dur"] >= 0.002


def test_span_thread_attribution():
    """Spans land on the opening thread's track; nesting stacks are
    per-thread (a worker's span is never a child of the main thread's)."""
    tr = obs_trace.Tracer()

    def worker():
        with tr.span("work"):
            time.sleep(0.001)

    with tr.span("main-span"):
        t = threading.Thread(target=worker, name="worker-1")
        t.start()
        t.join()
    work, = tr.spans("work")
    main, = tr.spans("main-span")
    assert work["track"] == "worker-1"
    assert main["track"] != "worker-1"
    assert work["args"]["depth"] == 0       # not nested under main-span
    assert "parent" not in work["args"]


def test_span_fence_blocks_device_work():
    """A fenced span's duration includes the device work that produced
    the fenced value (block_until_ready runs before the span closes)."""
    tr = obs_trace.Tracer()
    x = np.random.default_rng(0).normal(size=(200, 200))
    with obs_trace.tracing(tr):
        with obs_trace.span("matmul") as sp:
            y = jax.numpy.asarray(x) @ jax.numpy.asarray(x)
            sp.fence(y)
    sp_rec, = tr.spans("matmul")
    assert sp_rec["dur"] > 0
    assert np.isfinite(np.asarray(y)).all()


def test_chrome_trace_schema():
    tr = obs_trace.Tracer(process_name="test-proc")
    with tr.span("a", cycle=3):
        pass
    tr.emit("dev-span", time.perf_counter() - 0.01, 0.01,
            track="device 0")
    doc = tr.to_chrome_trace()
    # Round-trips through JSON (the export is what --trace writes).
    doc = json.loads(json.dumps(doc))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert all(e["ph"] in ("X", "M") for e in evs)
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"a", "dev-span"}
    for e in xs:
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["dur"] >= 0 and e["pid"] == 0
    # Metadata: a process_name row and one thread_name row per track,
    # with device rows sorted after host threads.
    metas = [e for e in evs if e["ph"] == "M"]
    names = {e["args"]["name"]: e["tid"] for e in metas
             if e["name"] == "thread_name"}
    assert "device 0" in names
    host_tids = [tid for t, tid in names.items()
                 if not t.startswith("device")]
    assert all(names["device 0"] > tid for tid in host_tids)
    assert any(e["name"] == "process_name"
               and e["args"]["name"] == "test-proc" for e in metas)
    # X events reference declared tids only.
    assert {e["tid"] for e in xs} <= set(names.values())


def test_null_tracer_is_shared_noop():
    prev = obs_trace.set_tracer(None)
    try:
        s1 = obs_trace.span("anything", key="val")
        s2 = obs_trace.span("other")
        assert s1 is s2                      # shared singleton, no alloc
        with s1 as s:
            assert s.fence(123) == 123
            s.annotate(a=1)                  # no-op, no error
    finally:
        obs_trace.set_tracer(prev)


def test_disabled_tracing_overhead_micro_bench():
    """The disabled span path must stay allocation-free and cheap: 50k
    disabled spans in well under a second even on a loaded CI box (the
    real figure is tens of nanoseconds each)."""
    prev = obs_trace.set_tracer(None)
    try:
        n = 50_000
        t0 = time.perf_counter()
        for _ in range(n):
            with obs_trace.span("hot"):
                pass
        dt = time.perf_counter() - t0
    finally:
        obs_trace.set_tracer(prev)
    assert dt < 1.0, f"disabled tracing cost {dt / n * 1e6:.2f}us/span"


def test_tracing_context_restores_previous():
    tr = obs_trace.Tracer()
    base = obs_trace.get_tracer()
    with obs_trace.tracing(tr):
        assert obs_trace.get_tracer() is tr
    assert obs_trace.get_tracer() is base


# ---------------------------------------------------------------------------
# Meters registry.
# ---------------------------------------------------------------------------

def test_meters_counters_series_events(fresh_meters):
    m = fresh_meters
    m.inc("a")
    m.inc("a", 2.5)
    m.gauge("g", 7)
    m.observe("s", 1.0)
    m.extend("s", [2.0, 3.0])
    m.event("e", foo="bar")
    snap = m.snapshot()
    assert snap["counters"]["a"] == 3.5
    assert snap["gauges"]["g"] == 7
    assert snap["series"]["s"] == [1.0, 2.0, 3.0]
    assert snap["events"][0]["name"] == "e"
    assert snap["events"][0]["foo"] == "bar"
    json.dumps(snap)                         # JSON-serializable
    m.reset()
    assert not m.counters and not m.series and not m.events


def test_meters_thread_hammer(fresh_meters):
    """Concurrent inc/observe/event from many threads lose nothing: the
    registry serializes every mutation behind one lock (``counters[k] +=
    v`` is a read-modify-write, not atomic under the GIL), which is what
    lets the fleet's packing pool and serving loop share one Meters."""
    m = fresh_meters
    threads, per = 8, 500
    barrier = threading.Barrier(threads)

    def hammer(tid):
        barrier.wait()
        for i in range(per):
            m.inc("h.count")
            m.inc("h.weighted", 0.5)
            m.observe("h.series", float(tid))
            m.gauge(f"h.gauge.{tid}", i)
            if i % 100 == 0:
                m.event("h.event", tid=tid, i=i)

    ts = [threading.Thread(target=hammer, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = m.snapshot()
    assert snap["counters"]["h.count"] == threads * per
    assert snap["counters"]["h.weighted"] == pytest.approx(
        threads * per * 0.5)
    assert len(snap["series"]["h.series"]) == threads * per
    assert len(snap["events"]) == threads * (per // 100)
    for t in range(threads):
        assert snap["gauges"][f"h.gauge.{t}"] == per - 1


def test_comm_matrix_symmetric_and_total():
    per_edge = {"0-1": 100.0, "1-2": 50.0}
    M = obs_meters.comm_matrix(3, per_edge)
    assert M.shape == (3, 3)
    np.testing.assert_array_equal(M, M.T)
    # Each endpoint sends the edge's bytes: total = 2 * sum(edges).
    assert M.sum() == 2 * (100.0 + 50.0)
    assert M[0, 1] == 100.0 and M[1, 2] == 50.0 and M[0, 2] == 0.0


# ---------------------------------------------------------------------------
# Residual histories.
# ---------------------------------------------------------------------------

def _packed_problem(n=48, p=4, overlap=1, m=150):
    rng = np.random.default_rng(0)
    obs = np.sort(rng.beta(2, 5, m))
    prob = cls.local_problem(jax.random.PRNGKey(0), n, obs)
    dec = dd.decompose_1d(n, dd.uniform_boundaries(p), overlap=overlap)
    return ddkf.pack(prob, dec)


def test_residual_history_converges_and_matches_default_path():
    packed = _packed_problem()
    x_plain = ddkf.solve_vmapped(packed, iters=150)
    x_hist, hist = ddkf.solve_vmapped(packed, iters=150,
                                      residual_history=True)
    np.testing.assert_allclose(np.asarray(x_hist), np.asarray(x_plain),
                               rtol=0, atol=1e-12)
    hist = np.asarray(hist)
    assert hist.shape == (150,)
    # Converging Schwarz iteration: the update norm collapses by orders
    # of magnitude, and the tail is (weakly) monotone non-increasing.
    assert hist[-1] < 1e-8 * max(hist[0], 1e-30)
    tail = hist[len(hist) // 2:]
    assert np.all(np.diff(tail) <= 1e-12 + tail[:-1] * 1e-6)


# ---------------------------------------------------------------------------
# Engine telemetry end to end.
# ---------------------------------------------------------------------------

def _run_engine(tracer=None, cycles=3, **cfg_kw):
    kw = dict(n=48, p=4, iters=60, overlap=1, comm="neighbour",
              record_residuals=True, double_buffer=True)
    kw.update(cfg_kw)
    eng = AssimilationEngine(EngineConfig(**kw))
    with obs_trace.tracing(tracer):
        journal = eng.run_scenario("drifting_swarm", m=160, cycles=cycles)
    return eng, journal


def test_engine_phases_and_trace_coverage(fresh_meters):
    tr = obs_trace.Tracer()
    eng, journal = _run_engine(tracer=tr)
    for rec in journal.records:
        assert {"count", "halo", "pack", "data", "solve"} <= set(
            rec.phases)
        assert all(v >= 0 for v in rec.phases.values())
    # The cycle spans cover the measured wall-clock (acceptance: >=95%).
    wall = sum(journal.cycle_times)
    assert tr.coverage("cycle", wall) >= 0.95
    # Packing ran on the double-buffer worker thread from cycle 1 on.
    pack_tracks = {s["track"] for s in tr.spans("pack")}
    assert any(t.startswith("pack") for t in pack_tracks)
    # Summary aggregates per-phase percentiles.
    stats = journal.summary()["phases"]
    assert stats["solve"]["p99"] >= stats["solve"]["p50"] > 0
    # Meters got the engine-level counters.
    assert fresh_meters.counters["engine.cycles"] == len(journal)


def test_engine_residual_history_journalled():
    _, journal = _run_engine(cycles=2)
    for rec in journal.records:
        assert len(rec.residual_history) == 60
        assert rec.residual_history[-1] < rec.residual_history[0]


def test_comm_matrix_total_matches_journalled_bytes():
    """matrix.sum() + mvec bytes == comm_bytes_per_cycle on the
    neighbour path (the per-edge dict is the same model, itemized)."""
    _, journal = _run_engine(cycles=2)
    p = journal.meta["p"]
    for rec in journal.records:
        M = obs_meters.comm_matrix(p, rec.comm_edge_bytes_per_cycle)
        np.testing.assert_array_equal(M, M.T)
        assert np.isclose(M.sum() + rec.comm_mvec_bytes_per_cycle,
                          rec.comm_bytes_per_cycle)


def test_journal_round_trip_with_telemetry_fields():
    _, journal = _run_engine(cycles=2)
    doc = json.loads(journal.to_json())
    j2 = Journal.from_dict(doc)
    assert len(j2) == len(journal)
    for a, b in zip(journal.records, j2.records):
        assert b.phases == {k: float(v) for k, v in a.phases.items()}
        assert b.residual_history == [float(v)
                                      for v in a.residual_history]
        assert b.comm_edge_bytes_per_cycle == a.comm_edge_bytes_per_cycle
        assert b.device_solve_times == a.device_solve_times
        assert b.straggler_flags == a.straggler_flags
        assert b.loads == a.loads
    # Old-journal compatibility: records without the new keys load with
    # the defaults, and unknown future keys are ignored.
    legacy = {k: v for k, v in doc["records"][0].items()
              if k not in ("phases", "residual_history",
                           "comm_edge_bytes_per_cycle",
                           "comm_mvec_bytes_per_cycle",
                           "device_solve_times", "straggler_flags")}
    legacy["some_future_field"] = 1
    rec = CycleMetrics.from_dict(legacy)
    assert rec.phases == {} and rec.residual_history == []


def test_straggler_monitor_wired_into_cycle_loop(fresh_meters):
    """With a pathological deadline config every post-grace cycle is
    flagged; the flags land in the journal and the meters."""
    cfg = StragglerConfig(grace_steps=0, consecutive_trigger=1,
                          deadline_factor=1e-9)
    eng = AssimilationEngine(
        EngineConfig(n=48, p=4, iters=40, record_residuals=False),
        straggler_config=cfg)
    journal = eng.run_scenario("drifting_swarm", m=160, cycles=3)
    # record() seeds the EWMA on the first post-grace step, so flags
    # start at the second cycle (the vmapped solve is device 0).
    assert journal.records[0].straggler_flags == []
    for rec in journal.records[1:]:
        assert rec.straggler_flags == [0]
        assert rec.device_solve_times and len(rec.device_solve_times) == 1
    assert fresh_meters.counters["engine.straggler.flags"] == 2
    assert journal.summary()["straggler_flags_total"] == 2


def test_engine_disabled_tracing_by_default(fresh_meters):
    """No tracer installed: the engine runs clean and records phases in
    the journal anyway (the dict timing is tracer-independent)."""
    assert isinstance(obs_trace.get_tracer(), obs_trace.NullTracer)
    _, journal = _run_engine(tracer=None, cycles=2,
                             record_residuals=False)
    assert all(r.phases["solve"] > 0 for r in journal.records)
    assert all(r.residual_history == [] for r in journal.records)
