"""Edge cases of the single-snapshot observation generators (paper §6)."""
import numpy as np
import pytest

from repro.data import observations


@pytest.mark.parametrize("kind", observations.KINDS)
def test_deterministic_under_fixed_seed(kind):
    a = observations.make_observations(400, kind=kind, seed=42)
    b = observations.make_observations(400, kind=kind, seed=42)
    np.testing.assert_array_equal(a, b)
    c = observations.make_observations(400, kind=kind, seed=43)
    assert not np.array_equal(a, c)


def test_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown observation kind"):
        observations.make_observations(10, kind="volcano")


def test_empty_subdomains_with_default_p_raises():
    """p defaults to 1: asking to empty subdomain 0 would leave nowhere
    for the observations to go — must be a clear error, not a bad array."""
    with pytest.raises(ValueError, match="cannot empty every subdomain"):
        observations.make_observations(10, empty_subdomains=(0,))


def test_empty_subdomains_all_empty_raises():
    with pytest.raises(ValueError, match="cannot empty every subdomain"):
        observations.make_observations(10, empty_subdomains=(0, 1, 2), p=3)


def test_empty_subdomains_out_of_range_raises():
    with pytest.raises(ValueError, match="out of range"):
        observations.make_observations(10, empty_subdomains=(7,), p=4)


def test_empty_subdomains_are_empty():
    obs = observations.make_observations(
        600, kind="beta", seed=5, empty_subdomains=(1, 2), p=4)
    counts = np.histogram(obs, bins=4, range=(0, 1))[0]
    assert counts[1] == 0 and counts[2] == 0
    assert counts.sum() == 600
    assert (obs >= 0).all() and (obs < 1).all()
    assert (np.diff(obs) >= 0).all()   # stays sorted
