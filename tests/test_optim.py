"""Optimizer, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, strategies as st

from repro.optim import AdamWConfig, adamw_init, adamw_step, make_schedule
from repro.optim import compress
from repro.optim.adamw import clip_by_global_norm, global_norm


def test_adamw_first_step_math():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      clip_norm=1e9)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.5])}
    opt = adamw_init(params)
    new, opt2, norm = adamw_step(cfg, grads, opt, params)
    # bias-corrected first step = lr * sign-ish update
    # m_hat = g, v_hat = g^2 -> delta = g/|g| = 1
    np.testing.assert_allclose(np.asarray(new["w"]),
                               [1.0 - 0.1, -2.0 - 0.1], atol=1e-6)
    assert int(opt2["step"]) == 1


def test_weight_decay_decoupled():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.1, clip_norm=1e9)
    params = {"w": jnp.asarray([1.0])}
    grads = {"w": jnp.asarray([0.0])}
    opt = adamw_init(params)
    new, _, _ = adamw_step(cfg, grads, opt, params)
    # pure decay: w - lr*wd*w
    np.testing.assert_allclose(np.asarray(new["w"]), [1.0 - 0.01],
                               atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    fn = make_schedule("cosine", peak_lr=1.0, warmup_steps=10,
                       total_steps=110)
    assert float(fn(0)) == 0.0
    assert float(fn(5)) == pytest.approx(0.5)
    assert float(fn(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(fn(110)) == pytest.approx(0.1, rel=1e-2)  # final_frac
    lin = make_schedule("linear", 1.0, 0, 100)
    assert float(lin(50)) == pytest.approx(0.55, rel=1e-2)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_step(cfg, g, opt, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


# ---------------------------------------------------------------------------
# int8 error-feedback compression.
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 256))
def test_quantize_error_bounded(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n) * rng.uniform(0.01, 100))
    q, scale = compress.quantize(x)
    err = np.abs(np.asarray(compress.dequantize(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_accumulates_to_truth():
    """Summing dequantized error-feedback outputs over many steps of a
    CONSTANT gradient recovers the gradient (no systematic bias):
    the residual after N steps is bounded by one quantization bin, so the
    mean error decays as scale/N."""
    g = jnp.asarray([1e-4, 3e-3, -2e-5, 0.7])
    err = jnp.zeros(4)
    total = np.zeros(4)
    steps = 200
    scale_last = 0.0
    for _ in range(steps):
        q, scale, err = compress.compress_with_feedback(g, err)
        total += np.asarray(compress.dequantize(q, scale))
        scale_last = float(scale)
    np.testing.assert_allclose(total / steps, np.asarray(g),
                               atol=2 * scale_last / steps, rtol=1e-2)


def test_compressed_psum_single_axis():
    import jax
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("d",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def f(g, e):
        return compress.compressed_psum(g, e, "d")

    g = jnp.asarray([0.5, -0.25, 1.0])
    out, new_err = jax.shard_map(f, mesh=mesh, in_specs=(P(), P()),
                                 out_specs=(P(), P()), check_vma=False)(
        g, jnp.zeros(3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=1e-2)
