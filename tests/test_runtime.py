"""Runtime: straggler monitor, sharding rules, end-to-end training smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import sharding, steps as steps_mod
from repro.runtime.straggler import StragglerConfig, StragglerMonitor


# ---------------------------------------------------------------------------
# Straggler monitor (injected timings).
# ---------------------------------------------------------------------------

def test_straggler_flags_persistent_slowdown():
    events = []
    mon = StragglerMonitor(
        StragglerConfig(grace_steps=2, deadline_factor=3.0,
                        consecutive_trigger=2),
        on_straggler=lambda s, t: events.append((s, t)))
    for _ in range(10):
        mon.record(0.1)
    assert not events
    mon.record(1.0)           # one blip: not yet
    assert not events
    mon.record(1.0)           # second consecutive: trigger
    assert len(events) == 1


def test_straggler_ignores_transients():
    mon = StragglerMonitor(StragglerConfig(grace_steps=1,
                                           consecutive_trigger=2))
    flags = [mon.record(t) for t in
             [0.1, 0.1, 0.1, 5.0, 0.1, 0.1, 5.0, 0.1]]
    assert not any(flags)


def test_straggler_grace_period_absorbs_compile():
    mon = StragglerMonitor(StragglerConfig(grace_steps=3))
    assert not mon.record(60.0)   # compile step
    assert not mon.record(55.0)
    assert not mon.record(0.1)


# ---------------------------------------------------------------------------
# Sharding rules.
# ---------------------------------------------------------------------------

def test_divisibility_fallback():
    spec = sharding.param_spec((6, 48, 1, 12), None, "embed", "kv_heads",
                               None)
    # kv dim of size 1 can't shard over model=16 -> replicated
    assert spec[2] is None


def test_profile_switch():
    with sharding.profile("dp"):
        s = sharding.act_spec_shaped((256, 128), "batch", "seq")
        # batch spans every axis in dp profile (256 % (2*16*16)=512 no;
        # largest prefix: pod*data = 32 divides 256... depends on default
        # sizes) — at minimum it is sharded
        assert s[0] is not None
    s2 = sharding.act_spec_shaped((256, 128), "batch", "seq")
    assert s2[0] is not None


def test_act_rules_kv_seq_always_model():
    with sharding.profile("dp"):
        s = sharding.act_spec_shaped((32, 128, 32768, 20, 64), None,
                                     "batch", "kv_seq", None, None)
    assert s[2] == "model"


# ---------------------------------------------------------------------------
# End-to-end training smoke: tiny model actually learns.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_tiny_model_loss_decreases():
    cfg = configs.get_smoke_config("yi_6b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    step = steps_mod.make_train_step(cfg, AdamWConfig(lr=3e-3,
                                                      weight_decay=0.0),
                                     donate=False)
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    # a memorizable repeating pattern
    base = rng.integers(1, cfg.vocab_size, 33)
    tokens = jnp.asarray(np.stack([base[:32], base[1:33]]), jnp.int32)
    batch = {"tokens": tokens}
    losses = []
    for _ in range(40):
        loss, params, opt = step(params, opt, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::8]


def test_gradient_accumulation_matches_full_batch():
    cfg = configs.get_smoke_config("glm4_9b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                         jnp.int32)
    batch = {"tokens": tokens}
    opt = adamw_init(params)
    s1 = steps_mod.make_train_step(cfg, AdamWConfig(lr=1e-3,
                                                    accum_steps=1),
                                   donate=False)
    s2 = steps_mod.make_train_step(cfg, AdamWConfig(lr=1e-3,
                                                    accum_steps=2),
                                   donate=False)
    l1, p1, _ = s1(params, opt, batch)
    l2, p2, _ = s2(params, opt, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_serve_step_greedy_generation():
    cfg = configs.get_smoke_config("gemma3_1b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    _, cache = transformer.prefill(cfg, params, {"tokens": toks},
                                   max_seq=S + 8)
    serve = steps_mod.make_serve_step(cfg, donate=False)
    cur = toks[:, -1:]
    for i in range(4):
        logits, cache = serve(params, cache, cur,
                              jnp.asarray(S + i, jnp.int32))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        assert cur.shape == (B, 1)
        assert not bool(jnp.any(jnp.isnan(logits)))
