"""End-to-end behaviour tests: the paper pipeline (DyDD -> DD-KF) at the
paper's configuration scale, and LM training with DyDD-balanced data +
checkpoint/restart equivalence (fault-tolerance path)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import manager as ckpt
from repro.core import cls, dd, ddkf, dydd
from repro.data import pipeline, observations
from repro.models import transformer
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import steps as steps_mod


@pytest.mark.slow
def test_paper_pipeline_end_to_end():
    """Paper §6 structure at reduced n: non-uniform observations, DyDD to
    balance (E -> 1), DD-KF solve, error_DD-DA at machine precision."""
    n, m, p = 256, 600, 8
    obs = observations.make_observations(m, kind="beta", seed=0)
    prob = cls.local_problem(jax.random.PRNGKey(0), n, obs)

    res = dydd.dydd_1d(obs, p)
    assert res.efficiency > 0.9
    assert res.loads_final.sum() == m

    dec = dd.decompose_1d(n, res.boundaries)
    packed = ddkf.pack(prob, dec)
    x_dd = ddkf.solve_vmapped(packed, iters=150)
    x_kf = cls.solve(prob)
    err = float(jnp.linalg.norm(x_dd - x_kf))
    assert err < 1e-8, err   # paper reports ~1e-11 at n=2048


@pytest.mark.slow
def test_train_checkpoint_restart_equivalence(tmp_path):
    """Train k steps, checkpoint, keep training; separately restore and
    retrain — identical losses (deterministic restart, DESIGN.md §8)."""
    cfg = configs.get_smoke_config("gemma3_1b")
    opt_cfg = AdamWConfig(lr=1e-3)
    step = steps_mod.make_train_step(cfg, opt_cfg, donate=False)

    loader = pipeline.BalancedLoader(vocab_size=cfg.vocab_size, dp=2,
                                     batch_per_shard=2, seq=32, seed=5)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)

    def batches(ld, k):
        out = []
        for _ in range(k):
            t, l, m = ld.next_batch()
            out.append({"tokens": jnp.asarray(t),
                        "labels": jnp.asarray(l),
                        "mask": jnp.asarray(m)})
        return out

    # steps 0-2
    for b in batches(loader, 3):
        loss, params, opt = step(params, opt, b)
    ckpt.save_pytree({"params": params, "opt": opt}, str(tmp_path), step=3,
                     metadata={"loader": loader.state_dict()})

    # continue 2 more steps -> reference losses
    ref_losses = []
    for b in batches(loader, 2):
        loss, params, opt = step(params, opt, b)
        ref_losses.append(float(loss))

    # restart from the checkpoint
    like = {"params": transformer.param_shapes(cfg, dtype=jnp.float32),
            "opt": {"m": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                transformer.param_shapes(cfg, dtype=jnp.float32)),
                "v": jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                transformer.param_shapes(cfg, dtype=jnp.float32)),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}}
    tree, manifest = ckpt.restore_pytree(str(tmp_path), like=like)
    loader2 = pipeline.BalancedLoader(vocab_size=cfg.vocab_size, dp=2,
                                      batch_per_shard=2, seq=32, seed=5)
    loader2.load_state_dict(manifest["metadata"]["loader"])
    p2, o2 = tree["params"], tree["opt"]
    got_losses = []
    for b in batches(loader2, 2):
        loss, p2, o2 = step(p2, o2, b)
        got_losses.append(float(loss))
    np.testing.assert_allclose(got_losses, ref_losses, rtol=1e-6)


def test_crash_recovery_resumes_from_valid(tmp_path):
    """Simulated crash mid-write: restart ignores the torn checkpoint and
    resumes from the last verified one."""
    tree = {"w": jnp.arange(10.0)}
    ckpt.save_pytree(tree, str(tmp_path), step=1)
    p2 = ckpt.save_pytree({"w": jnp.arange(10.0) * 2}, str(tmp_path),
                          step=2)
    # "crash": corrupt newest manifest
    with open(os.path.join(p2, "manifest.json"), "w") as f:
        f.write("{not json")
    latest = ckpt.latest_checkpoint(str(tmp_path))
    assert latest.endswith("step_00000001")
    got, _ = ckpt.restore_pytree(latest, like=tree)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(10.0))


def test_dryrun_cell_helpers_importable():
    """The dry-run module guards: mesh factory is a function; shapes
    registry covers the 40 cells."""
    from repro.configs import shapes
    assert len(shapes.SHAPES) == 4
    assert len(configs.ARCHS) == 10
    n_run, n_skip = 0, 0
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        for s in shapes.SHAPES:
            ok, _ = shapes.cell_supported(cfg, s)
            n_run += ok
            n_skip += (not ok)
    assert n_run + n_skip == 40
    assert n_skip == 6   # long_500k skipped for 6 quadratic-cache archs
    # input_specs allocate nothing and are complete
    cfg = configs.get_config("whisper-large-v3")
    spec = shapes.input_specs(cfg, "train_4k")
    assert set(spec) == {"tokens", "labels", "mask", "frames"}
    assert all(isinstance(s, jax.ShapeDtypeStruct) for s in spec.values())
