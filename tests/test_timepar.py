"""Parallel-in-time (Parareal) engine: window partition, tolerance
parity of the windowed analysis chain vs the sequential engine on every
domain kind, bitwise degeneration at ``time_windows=1`` /
``pint_max_iters=0``, and window-boundary checkpoint/resume."""
import os

import numpy as np
import pytest

from repro.assim import AssimilationEngine, EngineConfig, streams
from repro.assim.timepar import (TimeParEngine, resolve_time_mesh,
                                 window_bounds)
from repro.runtime import elastic

import jax

jax.config.update("jax_enable_x64", True)


# ---------------------------------------------------------------------------
# Window partition / mesh resolution.
# ---------------------------------------------------------------------------

def test_window_bounds_partition():
    assert window_bounds(8, 4) == [0, 2, 4, 6, 8]
    assert window_bounds(7, 3) == [0, 2, 4, 7]
    assert window_bounds(5, 8) == [0, 1, 2, 3, 4, 5]   # clamped to cycles
    assert window_bounds(6, 1) == [0, 6]
    b = window_bounds(11, 4)
    assert b[0] == 0 and b[-1] == 11
    assert all(b[i] < b[i + 1] for i in range(4))      # no empty window


def test_resolve_time_mesh_single_device():
    # One visible device: the only factorization is (1, 1), and it is
    # valid for any p.
    mesh = resolve_time_mesh(4, 3)
    assert mesh is not None
    assert dict(mesh.shape) == {"time": 1, "sub": 1}


def test_config_validation():
    with pytest.raises(ValueError, match="time_windows"):
        AssimilationEngine(EngineConfig(n=32, p=2, time_windows=0))
    with pytest.raises(ValueError, match="pint_tol"):
        AssimilationEngine(EngineConfig(n=32, p=2, pint_tol=0.0))


# ---------------------------------------------------------------------------
# Tolerance parity vs the sequential engine, per domain kind.
# ---------------------------------------------------------------------------

def _sequential_chain(cfg, stream):
    eng = AssimilationEngine(cfg)
    chain = []
    eng.on_analysis = lambda cycle, x: chain.append(np.asarray(x))
    eng.run(stream)
    return chain, eng.journal


CASES = [
    ("interval", dict(n=48, p=4, iters=30),
     ("drifting_swarm", 120, 8, 0)),
    ("shelf", dict(ndim=2, nx=12, ny=8, pr=2, pc=2, iters=25),
     ("rotating_swarm", 200, 8, 1)),
    ("kdtree", dict(ndim=2, nx=16, ny=12, domain_kind="kdtree", p=4,
                    iters=25),
     ("satellite_track", 240, 8, 2)),
]


@pytest.mark.parametrize("kind,cfg_kw,spec", CASES,
                         ids=[c[0] for c in CASES])
def test_windowed_matches_sequential_within_tol(kind, cfg_kw, spec):
    name, m, cycles, seed = spec
    seq_chain, seq_journal = _sequential_chain(
        EngineConfig(**cfg_kw), streams.make_stream(name, m, cycles,
                                                    seed=seed))

    cfg = EngineConfig(time_windows=4, pint_tol=1e-8, **cfg_kw)
    tp = TimeParEngine(cfg)
    journal = tp.run(streams.make_stream(name, m, cycles, seed=seed))

    pint = journal.meta["pint"]
    assert pint["converged"] and pint["iters"] <= pint["max_iters"]
    assert pint["correction_norms"][-1] <= cfg.pint_tol
    # Strictly decreasing correction norms — the Parareal contraction.
    assert all(a > b for a, b in zip(pint["correction_norms"],
                                     pint["correction_norms"][1:]))

    # The analysis chain matches the sequential engine within tolerance
    # (boundary corrections converge to pint_tol; downstream cycles
    # amplify by at most the per-cycle Lipschitz factor < 1).
    assert len(tp.analyses) == len(seq_chain) == cycles
    diff = max(float(np.max(np.abs(a - b)))
               for a, b in zip(tp.analyses, seq_chain))
    assert diff < 1e-6, diff

    # The prepare sweep replays the sequential mutation chain exactly:
    # journalled DyDD decisions are bitwise-identical, and every record
    # carries its window id from the deterministic partition.
    bounds = window_bounds(cycles, cfg.time_windows)
    for c, (rw, rs) in enumerate(zip(journal.records,
                                     seq_journal.records)):
        assert rw.loads == rs.loads
        assert rw.repartitioned == rs.repartitioned
        assert rw.migrated == rs.migrated
        w = next(i for i in range(len(bounds) - 1)
                 if bounds[i] <= c < bounds[i + 1])
        assert rw.window == w
        assert rs.window == -1


# ---------------------------------------------------------------------------
# Warm-started fine sweeps (the work-optimal Parareal variant).
# ---------------------------------------------------------------------------

def test_warm_started_fine_sweeps_match_within_tol():
    """With ``pint_fine_iters`` set, fine solves warm-start from the
    coarse trajectory and run a reduced iteration count; coarse + fine
    iterations together buy the accuracy, so the chain still lands
    within tolerance of the (fully converged) sequential engine."""
    name, m, cycles, seed = "drifting_swarm", 120, 8, 0
    base = dict(n=48, p=4, iters=300)
    seq_chain, _ = _sequential_chain(
        EngineConfig(**base), streams.make_stream(name, m, cycles,
                                                  seed=seed))

    cfg = EngineConfig(time_windows=4, pint_tol=1e-8,
                       pint_coarse_iters=30, pint_fine_iters=150, **base)
    tp = TimeParEngine(cfg)
    journal = tp.run(streams.make_stream(name, m, cycles, seed=seed))
    pint = journal.meta["pint"]
    assert pint["warm_start"] is True
    assert pint["fine_iters"] == 150 and pint["coarse_iters"] == 30
    assert pint["converged"]
    diff = max(float(np.max(np.abs(a - b)))
               for a, b in zip(tp.analyses, seq_chain))
    assert diff < 1e-6, diff


def test_solver_warm_start_from_converged_state():
    """``x0=`` on the solve entry points: restarting from a converged
    estimate reproduces it (the Schwarz map's fixed point does not
    depend on the start), and an all-zero x0 is bitwise the historic
    cold start."""
    from repro.core import cls, dd, ddkf, dydd

    rng = np.random.default_rng(0)
    obs = np.sort(rng.beta(2, 5, size=200))
    prob = cls.local_problem(jax.random.PRNGKey(0), 64, obs)
    dec = dd.decompose_1d(64, dydd.dydd_1d(obs, 4).boundaries,
                          overlap=1)
    pk = ddkf.pack(prob, dec)
    x_full = np.asarray(ddkf.solve_vmapped(pk, iters=200))
    x_warm = np.asarray(ddkf.solve_vmapped(pk, iters=20, x0=x_full))
    assert float(np.max(np.abs(x_warm - x_full))) < 1e-10
    # Zero warm start == cold start, bitwise.
    x_cold = np.asarray(ddkf.solve_vmapped(pk, iters=40))
    x_zero = np.asarray(ddkf.solve_vmapped(pk, iters=40,
                                           x0=np.zeros(64)))
    assert np.array_equal(x_cold, x_zero)
    # Fleet path threads per-problem warm starts.
    stacked = ddkf.stack_packed([pk, pk])
    xs = np.asarray(ddkf.solve_fleet(stacked, iters=20,
                                     x0=np.stack([x_full, x_full])))
    assert float(np.max(np.abs(xs - x_full[None]))) < 1e-10


# ---------------------------------------------------------------------------
# Bitwise degeneration.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("degenerate_kw", [dict(time_windows=1),
                                           dict(pint_max_iters=0)],
                         ids=["one_window", "zero_iters"])
def test_degenerate_is_bitwise_sequential(degenerate_kw):
    name, m, cycles, seed = "bursty_clusters", 120, 5, 3
    base = dict(n=48, p=4, iters=30)
    ref = AssimilationEngine(EngineConfig(**base))
    ref.run(streams.make_stream(name, m, cycles, seed=seed))

    tp = TimeParEngine(EngineConfig(
        **base, **{"time_windows": 4, **degenerate_kw}))
    tp.run(streams.make_stream(name, m, cycles, seed=seed))
    assert "pint" not in tp.journal.meta
    assert tp.journal.deterministic_json() == \
        ref.journal.deterministic_json()
    assert np.array_equal(np.asarray(tp.analysis),
                          np.asarray(ref.analysis))


# ---------------------------------------------------------------------------
# Window-boundary checkpoints -> sequential resume.
# ---------------------------------------------------------------------------

def test_window_checkpoint_resumes_sequentially(tmp_path):
    """The windowed run snapshots at window boundaries (snapshot_every
    counts windows); restoring a mid-stream boundary checkpoint resumes
    the *sequential* engine from that boundary and lands within the
    Parareal tolerance of the windowed run's tail."""
    name, m, cycles, seed = "drifting_swarm", 120, 8, 0
    cfg = EngineConfig(n=48, p=4, iters=30, time_windows=4,
                       pint_tol=1e-10)
    ckpt = str(tmp_path / "pint")
    tp = TimeParEngine(cfg)
    tp.run(streams.ResumableStream(name, m, cycles, seed=seed),
           checkpoint_dir=ckpt, snapshot_every=1)
    # 4 windows over 8 cycles -> boundary snapshots at steps 2,4,6,8.
    present = sorted(d for d in os.listdir(ckpt) if d.startswith("step_"))
    assert present == [f"step_{s:08d}" for s in (2, 4, 6, 8)]

    eng, stream = elastic.resume_assim_engine(
        os.path.join(ckpt, "step_00000004"))
    assert stream is not None and stream.pos == 4
    assert len(eng.journal.records) == 4
    eng.run(stream)
    assert len(eng.journal.records) == cycles
    # Same DyDD decisions on the tail (host state carried the exact
    # sequential rng/domain chain) ...
    for rr, rw in zip(eng.journal.records[4:], tp.journal.records[4:]):
        assert rr.loads == rw.loads
        assert rr.repartitioned == rw.repartitioned
    # ... and the final analysis within the Parareal tolerance band.
    diff = float(np.max(np.abs(np.asarray(eng.analysis)
                               - np.asarray(tp.analysis))))
    assert diff < 1e-6, diff
